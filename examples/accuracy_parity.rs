//! Accuracy-parity experiment — the **Table 1 accuracy columns** at small
//! scale: train the same masked MLP on the same CIFAR-like task under
//! dense / unstructured / block(4,4) / RBGP4 masks at each of the paper's
//! sparsities, with identical optimizer settings, and report held-out
//! accuracy. The paper's claim under test: RBGP4 structure costs no
//! accuracy relative to unstructured or block masks at equal sparsity.
//!
//! Run: `cargo run --release --example accuracy_parity`
//! Env: RBGP_STEPS (default 250), RBGP_SEEDS (default 3 — mean over seeds).

use rbgp::data::CifarLike;
use rbgp::sparsity::memory::Pattern;
use rbgp::train_native::{pattern_mask, MaskedMlp, NativeTrainConfig};
use rbgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("RBGP_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250);
    let seeds: u64 = std::env::var("RBGP_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let (d, h, c) = (256usize, 256usize, 16usize);
    let noise = 1.1f32; // keep accuracy below ceiling so pattern differences show

    println!("== Accuracy parity (Table 1 acc columns, small-scale proxy)");
    println!("   MLP {d}->{h}->{c} on CIFAR-like synthetic, {steps} steps, mean of {seeds} seeds\n");
    println!(
        "{:>10} {:>10} {:>14} {:>12} {:>10}",
        "Sparsity%", "Dense", "Unstructured", "Block(4,4)", "RBGP4"
    );

    for &sp in &[0.5f64, 0.75, 0.875] {
        let mut row = format!("{:>10.2}", sp * 100.0);
        for pat in [
            Pattern::Dense,
            Pattern::Unstructured,
            Pattern::Block(4, 4),
            Pattern::Rbgp4,
        ] {
            let mut acc_sum = 0.0f64;
            for seed in 0..seeds {
                let mut rng = Rng::new(1000 + seed);
                let sp_eff = if pat == Pattern::Dense { 0.0 } else { sp };
                let mask = pattern_mask(pat, h, d, sp_eff, &mut rng)?;
                let mut mlp = MaskedMlp::new(d, h, c, mask, &mut rng);
                let cfg = NativeTrainConfig {
                    steps,
                    batch: 64,
                    lr: 0.05,
                    seed,
                    ..NativeTrainConfig::default()
                };
                let mut data = CifarLike::new(d, c, 77 + seed).with_noise(noise);
                let (_, acc) = mlp.train(&mut data, &cfg);
                acc_sum += acc;
            }
            row.push_str(&format!(" {:>13.2}", 100.0 * acc_sum / seeds as f64));
        }
        println!("{row}");
    }

    println!("\n(the paper's Table-1 shape: all patterns within ~1 point of each");
    println!(" other at every sparsity; absolute accuracy depends on the task)");
    println!("accuracy_parity OK");
    Ok(())
}
