//! RBGP4 configuration sweep — the Table-2/Table-3 experiments as a
//! library-driven study, plus a connectivity sweep (spectral gap of the
//! product mask vs configuration) that the paper's §4 motivates.
//!
//! Run: `cargo run --release --example sweep_rbgp4` (no artifacts needed).
//! Set RBGP_BENCH_FAST=1 for a quick pass.

use rbgp::bench_harness::{table2, table3};
use rbgp::graph::spectral;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask};
use rbgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- Table 2: sparsity distribution -------------------------------
    let measure_n = if std::env::var("RBGP_BENCH_FAST").as_deref() == Ok("1") {
        512
    } else {
        1024
    };
    println!("{}", table2::run(measure_n, 0).render());

    // --- Table 3: row repetition ---------------------------------------
    println!("{}", table3::run(measure_n, 0).render());

    // --- Connectivity sweep (§4): how does shifting sparsity between
    // G_o and G_i affect the spectral gap of the *whole* mask? ----------
    println!("## Connectivity sweep — spectral gap of the product mask\n");
    println!(
        "{:<22} {:>10} {:>10} {:>12}",
        "split (sp_o, sp_i)", "λ1", "λ2", "gap"
    );
    let mut rng = Rng::new(7);
    for (sp_o, sp_i) in [(0.0, 0.75), (0.5, 0.5), (0.75, 0.0)] {
        // Small config so the full product graph is cheap to analyze.
        let cfg = Rbgp4Config {
            go: GraphSpec::new(8, 8, sp_o),
            gr: (2, 2),
            gi: GraphSpec::new(8, 8, sp_i),
            gb: (1, 1),
        };
        let mask = Rbgp4Mask::sample(cfg, &mut rng)?;
        let g = mask.product_graph();
        let s = spectral::spectrum(&g, rng.next_u64());
        println!(
            "{:<22} {:>10.3} {:>10.3} {:>12.3}",
            format!("({sp_o}, {sp_i})"),
            s.lambda1,
            s.lambda2,
            s.gap()
        );
    }
    println!("\n(equal total sparsity — the gap stays healthy across splits,");
    println!(" which is why Table 2 can pick the fastest split freely)");
    println!("\nsweep_rbgp4 OK");
    Ok(())
}
