//! **End-to-end driver** (DESIGN.md §End-to-end validation).
//!
//! Proves all three layers compose: the L1 Pallas RBGP4MM kernel is inside
//! the L2 JAX model, which was AOT-lowered to `artifacts/*.hlo.txt` by
//! `make artifacts`; this Rust binary loads those executables via PJRT and
//! trains the sparse MLP on the synthetic CIFAR-like task for a few hundred
//! steps, logging the loss curve and held-out accuracy. Python never runs.
//!
//! Run: `make artifacts && cargo run --release --example train_cifar_like`
//! Options via env: RBGP_STEPS (default 300), RBGP_SEED, RBGP_ARTIFACTS.
//!
//! The resulting loss curve / accuracy are recorded in EXPERIMENTS.md
//! (§End-to-end training).

use rbgp::coordinator::{TrainConfig, Trainer};
use std::path::PathBuf;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::var("RBGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let steps = env_usize("RBGP_STEPS", 300);
    let seed = env_usize("RBGP_SEED", 0) as u64;

    let config = TrainConfig {
        steps,
        lr0: 0.1,
        seed,
        eval_every: (steps / 6).max(1),
        eval_batches: 4,
        ..TrainConfig::default()
    };

    println!("== RBGP end-to-end training driver");
    println!("   artifacts: {}", dir.display());
    let mut trainer = Trainer::new(&dir, config)?;
    println!(
        "   model: batch {}, {} parameter tensors (RBGP4 compact storage)",
        trainer.batch_size(),
        trainer.params.len()
    );

    let (final_loss, final_acc) = trainer.run()?;

    // Loss curve (subsampled) for EXPERIMENTS.md.
    println!("\nloss curve (step, loss):");
    let losses = &trainer.metrics.losses;
    let stride = (losses.len() / 20).max(1);
    for (s, l) in losses.iter().step_by(stride) {
        println!("  {s:>5}  {l:.4}");
    }

    let first_loss = losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN);
    println!("\nsummary: loss {first_loss:.4} → {final_loss:.4}, accuracy {:.2}%", final_acc * 100.0);
    anyhow::ensure!(
        final_loss < 0.5 * first_loss,
        "training did not converge: {first_loss} -> {final_loss}"
    );
    anyhow::ensure!(final_acc > 0.5, "accuracy {final_acc} too low");
    println!("train_cifar_like OK");
    Ok(())
}
