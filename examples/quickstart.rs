//! Quickstart: the whole RBGP pipeline in one file, no artifacts needed.
//!
//! 1. Sample Ramanujan bipartite base graphs (2-lift rejection sampling).
//! 2. Compose an RBGP4 mask `G = G_o ⊗ G_r ⊗ G_i ⊗ G_b` and verify its
//!    RCUBS structure + succinct storage.
//! 3. Run the RBGP4MM kernel against the dense oracle.
//! 4. Print the Figure-1 tiling decomposition and the Table-2-style
//!    measured speedup over dense GEMM on this machine.
//!
//! Run: `cargo run --release --example quickstart`

use rbgp::gpusim::explain_fig1;
use rbgp::kernels::dense::gemm_parallel;
use rbgp::kernels::rbgp4mm::rbgp4mm_parallel;
use rbgp::sparsity::pattern;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::rng::Rng;
use rbgp::util::threadpool::default_threads;
use rbgp::util::timing::{bench_fn, BenchConfig};

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // --- 1. Ramanujan base graphs --------------------------------------
    println!("== 1. Ramanujan graph generation (Appendix 8.1)");
    let gen = rbgp::graph::ramanujan::generate(32, 32, 0.75, &mut rng, 500)?;
    println!(
        "   32x32 @ 75%: λ2 = {:.3} ≤ bound {:.3} (Ramanujan ✓, {} attempts)",
        gen.cert.lambda2, gen.cert.bound, gen.attempts
    );

    // --- 2. RBGP4 mask ---------------------------------------------------
    println!("\n== 2. RBGP4 mask (G_o ⊗ G_r ⊗ G_i ⊗ G_b)");
    let config = Rbgp4Config {
        go: GraphSpec::new(8, 32, 0.5),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    let mask = Rbgp4Mask::sample(config, &mut rng)?;
    println!(
        "   W_s: {}x{} @ {:.1}% sparsity, {} non-zeros/row",
        mask.rows(),
        mask.cols(),
        100.0 * config.sparsity(),
        config.row_nnz()
    );
    let dense = mask.dense();
    let levels = config.blocking_levels();
    assert!(pattern::is_rcubs(&dense, mask.rows(), mask.cols(), &levels)?);
    println!("   RCUBS verified at levels {levels:?}");
    println!(
        "   succinct index: {} elems vs {} for a generic adjacency ({}x smaller)",
        mask.succinct_index_elems(),
        mask.generic_index_elems(),
        mask.generic_index_elems() / mask.succinct_index_elems()
    );

    // --- 3. RBGP4MM vs dense oracle --------------------------------------
    println!("\n== 3. RBGP4MM correctness (Algorithm 1, CPU adaptation)");
    let w = Rbgp4Matrix::random(mask, &mut rng);
    let (m, k, n) = (w.mask.rows(), w.mask.cols(), 64);
    let i = rng.normal_vec_f32(k * n, 1.0);
    let mut o = vec![0.0f32; m * n];
    let threads = default_threads();
    rbgp4mm_parallel(&w, &i, &mut o, n, threads);
    let mut oracle = vec![0.0f32; m * n];
    rbgp::kernels::dense::gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
    let max_err = o
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("   max |rbgp4mm - dense oracle| = {max_err:.2e}  (m={m}, k={k}, n={n})");
    assert!(max_err < 1e-3);

    // --- 4. Figure-1 schedule + measured speedup --------------------------
    println!("\n== 4. Tiling schedule (Figure 1) and measured speedup");
    let e = explain_fig1(&config);
    println!(
        "   tile ({}, {}) — {} of {} steps per output tile, row repetition {}x",
        e.tile_m, e.tile_k, e.steps_skipped, e.steps_dense, e.row_repetition
    );
    let nn = 1024;
    let big = Rbgp4Config {
        go: GraphSpec::new(8, 32, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    let big_mask = Rbgp4Mask::sample(big, &mut rng)?;
    let wbig = Rbgp4Matrix::random(big_mask, &mut rng);
    let ibig = rng.normal_vec_f32(nn * nn, 1.0);
    let mut obig = vec![0.0f32; nn * nn];
    let cfg = BenchConfig::from_env();
    let t_sparse = bench_fn(&cfg, || {
        rbgp4mm_parallel(&wbig, &ibig, &mut obig, nn, threads);
        std::hint::black_box(&obig);
    })
    .median;
    let wd = rng.normal_vec_f32(nn * nn, 1.0);
    let t_dense = bench_fn(&cfg, || {
        gemm_parallel(&wd, &ibig, &mut obig, nn, nn, nn, threads);
        std::hint::black_box(&obig);
    })
    .median;
    println!(
        "   SDMM {nn}³ @ 87.5% sparsity: rbgp4mm {:.2} ms vs dense {:.2} ms — {:.1}x",
        t_sparse * 1e3,
        t_dense * 1e3,
        t_dense / t_sparse
    );
    println!("\nquickstart OK");
    Ok(())
}
