//! Gradual structure induction — the paper's §7 future-work experiment.
//!
//! Compares, at equal step budget and equal *final* RBGP4 structure:
//!   (a) **predefined** — the mask is fixed before training (the paper's
//!       main method), vs.
//!   (b) **gradual**  — training starts dense and the mask tightens through
//!       a nested chain of supersets (dense → intermediate → final RBGP4).
//!
//! The paper conjectures (b) "could lead to more accurate models"; this
//! harness measures it on the CIFAR-like task across sparsities and seeds.
//!
//! Run: `cargo run --release --example gradual_sparsify`
//! Env: RBGP_STEPS (default 250), RBGP_SEEDS (default 3).

use rbgp::data::CifarLike;
use rbgp::sparsity::rbgp4::Rbgp4Mask;
use rbgp::train_native::masks::rbgp4_factorization;
use rbgp::train_native::{train_gradual, GradualSchedule, MaskedMlp, NativeTrainConfig};
use rbgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("RBGP_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250);
    let seeds: u64 = std::env::var("RBGP_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let (d, h, c) = (256usize, 256usize, 16usize);
    let noise = 1.1f32;

    println!("== Gradual RBGP4 structure induction (paper §7 future work)");
    println!("   MLP {d}->{h}->{c}, {steps} steps, mean of {seeds} seeds, schedule dense→25%→60%→final\n");
    println!(
        "{:>22} {:>14} {:>12} {:>8}",
        "final sparsity (o,i)", "predefined%", "gradual%", "Δ"
    );

    for total_sp in [0.5f64, 0.75, 0.875] {
        let cfg = rbgp4_factorization(h, d, total_sp)?;
        let (mut pre_sum, mut grad_sum) = (0.0f64, 0.0f64);
        for seed in 0..seeds {
            let tc = NativeTrainConfig {
                steps,
                batch: 64,
                lr: 0.05,
                seed,
                ..Default::default()
            };
            // (a) predefined
            let mut rng = Rng::new(900 + seed);
            let mask = Rbgp4Mask::sample(cfg, &mut rng)?.dense();
            let mut mlp = MaskedMlp::new(d, h, c, mask, &mut rng);
            let mut data = CifarLike::new(d, c, 77 + seed).with_noise(noise);
            let (_, acc) = mlp.train(&mut data, &tc);
            pre_sum += acc;
            // (b) gradual (same seeds → same data stream and final-mask RNG)
            let mut rng = Rng::new(900 + seed);
            let mut data = CifarLike::new(d, c, 77 + seed).with_noise(noise);
            let (_, acc) = train_gradual(
                d,
                h,
                c,
                cfg,
                &GradualSchedule::default(),
                &tc,
                &mut data,
                &mut rng,
            )?;
            grad_sum += acc;
        }
        let (pre, grad) = (
            100.0 * pre_sum / seeds as f64,
            100.0 * grad_sum / seeds as f64,
        );
        println!(
            "{:>22} {:>14.2} {:>12.2} {:>+8.2}",
            format!("{:.3} ({},{})", cfg.sparsity(), cfg.go.sp, cfg.gi.sp),
            pre,
            grad,
            grad - pre
        );
    }
    println!("\ngradual_sparsify OK");
    Ok(())
}
