//! Batched inference serving demo: multiple client threads fire single-
//! sample requests at the L3 coordinator, whose worker pool groups them
//! into full batches for the AOT forward executable (the Pallas-kernel
//! inference path; each worker compiles its own PJRT executable). Reports
//! throughput, latency percentiles and real batch occupancy.
//!
//! Run: `make artifacts && cargo run --release --example serve_batched`
//! (`RBGP_WORKERS=4` to scale the pool)

use rbgp::coordinator::{InferenceServer, ServerConfig};
use rbgp::data::CifarLike;
use std::path::PathBuf;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::var("RBGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let total: usize = std::env::var("RBGP_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512);
    let workers: usize = std::env::var("RBGP_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let clients = 8usize;

    println!("== RBGP batched inference server");
    let server = InferenceServer::start(
        dir,
        ServerConfig {
            max_wait: Duration::from_millis(4),
            workers,
            ..ServerConfig::default()
        },
    )?;
    println!(
        "   model: in_dim {}, classes {}, max batch {} × {} workers",
        server.in_dim,
        server.classes,
        server.batch,
        server.workers()
    );

    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = server.clone();
            scope.spawn(move || {
                let mut data = CifarLike::new(server.in_dim, server.classes, 1000 + c as u64);
                for _ in 0..total / clients {
                    let sample = data.test_batch(1);
                    let logits = server.infer(sample.x).expect("inference failed");
                    assert_eq!(logits.len(), server.classes);
                    assert!(logits.iter().all(|v| v.is_finite()));
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let (reqs, batches) = server.counters();
    let stats = server.latency_stats().expect("no latency samples");
    println!("\nserved {reqs} requests in {batches} executed batches over {wall:.2}s");
    println!(
        "   batch occupancy: {:.1}% real samples (peak queue depth {})",
        stats.occupancy * 100.0,
        server.peak_queue_depth()
    );
    println!("   throughput: {:.1} req/s", reqs as f64 / wall);
    println!(
        "   latency: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms",
        stats.p50 * 1e3,
        stats.p95 * 1e3,
        stats.p99 * 1e3,
        stats.max * 1e3
    );
    assert_eq!(reqs, total / clients * clients);
    println!("serve_batched OK");
    Ok(())
}
