//! Integration tests for the multi-worker serving subsystem over the
//! public API: many client threads hammering a worker pool, per-request
//! correctness against a single-shot forward, typed deadline/backpressure
//! errors, shared-plan-cache verification, and graceful shutdown.
//!
//! These run on the default (native) build — no artifacts, no `xla`.

use rbgp::coordinator::{
    BatchModel, InferenceServer, NativeSparseModel, Priority, ServeError, ServerConfig,
    SubmitOptions,
};
use rbgp::kernels::PlanCache;
use std::sync::Arc;
use std::time::Duration;

const CLASSES: usize = 10;
const BATCH: usize = 8;
const IN_DIM: usize = 256;

/// Deterministic per-(client, request) sample.
fn sample(client: usize, req: usize) -> Vec<f32> {
    (0..IN_DIM)
        .map(|i| {
            let v = (i * 31 + client * 7 + req * 13) % 23;
            (v as f32 - 11.0) / 11.0
        })
        .collect()
}

fn demo_server(seed: u64, cache: &Arc<PlanCache>, config: ServerConfig) -> InferenceServer {
    let cache = Arc::clone(cache);
    InferenceServer::start_model(
        move || {
            let mut m = NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, seed, Arc::clone(&cache))?;
            m.warm()?;
            Ok(Box::new(m) as Box<dyn BatchModel>)
        },
        config,
    )
    .expect("server start")
}

#[test]
fn worker_pool_matches_single_shot_forward_and_shares_plans() {
    let workers = 3;
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(
        7,
        &cache,
        ServerConfig {
            workers,
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    );
    assert_eq!(server.workers(), workers);
    assert_eq!(server.in_dim, IN_DIM);

    // Reference model on its own cache (so its plan traffic is separate).
    let mut reference =
        NativeSparseModel::rbgp4_demo(CLASSES, BATCH, 1, 7, Arc::new(PlanCache::new())).unwrap();

    // Many clients hammer the pool; every response must equal the
    // single-shot forward of its own sample (rows are independent, padding
    // is zero), regardless of which worker served it or how it batched.
    let clients = 6;
    let per_client = 16;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = server.clone();
            scope.spawn(move || {
                for r in 0..per_client {
                    let got = server.infer(sample(c, r)).unwrap();
                    assert_eq!(got.len(), CLASSES);
                }
            });
        }
    });

    // Spot-check logits equality against the reference forward.
    for (c, r) in [(0usize, 0usize), (3, 5), (5, 15)] {
        let x = sample(c, r);
        let got = server.infer(x.clone()).unwrap();
        let mut xb = vec![0.0f32; BATCH * IN_DIM];
        xb[..IN_DIM].copy_from_slice(&x);
        let want = reference.forward(&xb).unwrap();
        for (a, b) in got.iter().zip(&want[..CLASSES]) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "pool logits {a} != single-shot {b}"
            );
        }
    }

    let (requests, batches) = server.counters();
    assert_eq!(requests, clients * per_client + 3);
    assert!(batches >= requests / BATCH, "batches cover all requests");

    // The acceptance check: N workers, one Arc<PlanCache>. Exactly two
    // structure builds ever (one per layer); every other worker's warm-up
    // resolved from cache.
    let (hits, misses) = cache.stats();
    assert_eq!(misses, 2, "structure derived once for the whole pool");
    assert_eq!(hits, 2 * (workers - 1), "remaining workers warm from cache");

    // Per-worker counters add up to the totals.
    let ws = server.worker_stats();
    assert_eq!(ws.len(), workers);
    assert_eq!(ws.iter().map(|w| w.requests).sum::<usize>(), requests);
    assert_eq!(ws.iter().map(|w| w.batches).sum::<usize>(), batches);
    let stats = server.latency_stats().unwrap();
    assert_eq!(stats.count, requests);
    assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);
    server.shutdown();
}

#[test]
fn expired_deadlines_get_typed_error_not_batch_slots() {
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(
        21,
        &cache,
        ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    );
    // Zero-deadline requests are expired by the time any worker pops them.
    let opts = SubmitOptions::default().with_deadline(Duration::ZERO);
    let receivers: Vec<_> = (0..5)
        .map(|r| server.submit_with(sample(0, r), opts.clone()).unwrap())
        .collect();
    for rx in receivers {
        match rx.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    // Live traffic is unaffected.
    assert_eq!(server.infer(sample(0, 99)).unwrap().len(), CLASSES);
    let (rejected_full, rejected_deadline) = server.rejected();
    assert_eq!(rejected_full, 0);
    assert_eq!(rejected_deadline, 5);
    let (requests, _) = server.counters();
    assert_eq!(requests, 1, "expired requests are not counted as served");
    let occupied: usize = server.worker_stats().iter().map(|w| w.occupied_slots).sum();
    assert_eq!(occupied, 1, "expired requests never occupied a batch slot");
    server.shutdown();
}

#[test]
fn deadline_shorter_than_straggler_window_is_never_executed() {
    // Acceptance regression for the deadline gap: a request popped *live*
    // by a worker used to sit out the `max_wait` straggler window in
    // `pending`, expire there, and then execute anyway — returning `Ok`
    // past its deadline. The flush-time re-check must reject it instead.
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(
        77,
        &cache,
        ServerConfig {
            workers: 1,
            // Straggler window an order of magnitude longer than the
            // request deadline: the pop happens while the deadline is
            // live, the expiry happens inside the window.
            max_wait: Duration::from_millis(400),
            ..ServerConfig::default()
        },
    );
    let rx = server
        .submit_with(
            sample(0, 0),
            SubmitOptions::default().with_deadline(Duration::from_millis(40)),
        )
        .unwrap();
    match rx.recv().unwrap() {
        Err(ServeError::DeadlineExceeded { waited }) => {
            assert!(
                waited >= Duration::from_millis(40),
                "rejected before its deadline? waited {waited:?}"
            );
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let (requests, batches) = server.counters();
    assert_eq!(requests, 0, "an expired request must never be served");
    assert_eq!(batches, 0, "nothing to flush once the lone request expired");
    assert_eq!(server.rejected(), (0, 1));
    let occupied: usize = server.worker_stats().iter().map(|w| w.occupied_slots).sum();
    assert_eq!(occupied, 0, "expired requests never occupy a batch slot");
    // The pool is still healthy for live traffic afterwards.
    assert_eq!(server.infer(sample(0, 1)).unwrap().len(), CLASSES);
    server.shutdown();
}

#[test]
fn priorities_and_default_deadline_are_accepted() {
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(
        33,
        &cache,
        ServerConfig {
            workers: 2,
            // Generous default deadline: everything should still be served.
            default_deadline: Some(Duration::from_secs(30)),
            ..ServerConfig::default()
        },
    );
    for (r, priority) in [Priority::High, Priority::Normal, Priority::Low]
        .into_iter()
        .enumerate()
    {
        let got = server
            .infer_with(sample(1, r), SubmitOptions::default().with_priority(priority))
            .unwrap();
        assert_eq!(got.len(), CLASSES);
    }
    assert_eq!(server.rejected(), (0, 0));
    assert_eq!(server.counters().0, 3);
    server.shutdown();
}

#[test]
fn shutdown_drains_then_rejects() {
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(
        5,
        &cache,
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    );
    // Queue a burst, then shut down: every submitted request must still be
    // answered (drain), and later submits must fail with the typed error.
    let receivers: Vec<_> = (0..20)
        .map(|r| server.submit(sample(2, r)).unwrap())
        .collect();
    server.shutdown();
    for rx in receivers {
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), CLASSES);
    }
    assert!(matches!(
        server.submit(sample(2, 999)),
        Err(ServeError::Stopped)
    ));
    assert!(matches!(server.infer(sample(2, 1000)), Err(ServeError::Stopped)));
    // Stats remain readable after shutdown.
    assert_eq!(server.counters().0, 20);
    assert!(server.latency_stats().is_some());
}

#[test]
fn wrong_width_is_rejected_synchronously() {
    let cache = Arc::new(PlanCache::new());
    let server = demo_server(9, &cache, ServerConfig::default());
    match server.submit(vec![0.0; 3]) {
        Err(ServeError::WrongInputWidth { got: 3, want }) => assert_eq!(want, IN_DIM),
        other => panic!("expected WrongInputWidth, got {:?}", other.map(|_| ())),
    }
    server.shutdown();
}
