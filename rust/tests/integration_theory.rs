//! Cross-module integration tests for the paper's theory claims: Theorem 1,
//! the Figure-3 succinctness example, connectivity of RBGP masks, and the
//! cost-model ↔ measured-kernel agreement on orderings.

use rbgp::gpusim::{estimate, Device, KernelKind, SdmmShape};
use rbgp::graph::{product_many, ramanujan, spectral};
use rbgp::kernels::dense::gemm_blocked;
use rbgp::kernels::rbgp4mm::rbgp4mm;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::util::rng::Rng;
use rbgp::util::timing::{bench_fn, BenchConfig};

/// Theorem 1: ideal-gap / product-gap ratio approaches 1 as n grows.
#[test]
fn theorem1_ratio_improves_with_n() {
    let mut rng = Rng::new(2026);
    let sp = 0.5;
    let mut ratios = Vec::new();
    for n in [8usize, 16, 32] {
        let d = ((1.0 - sp) * n as f64).round() as usize;
        let g1 = ramanujan::generate_best_effort(n, n, sp, &mut rng, 64)
            .unwrap()
            .0
            .graph;
        let g2 = ramanujan::generate_best_effort(n, n, sp, &mut rng, 64)
            .unwrap()
            .0
            .graph;
        let p = product_many(&[&g1, &g2]).unwrap();
        let s = spectral::spectrum(&p, rng.next_u64());
        let d2 = (d * d) as f64;
        let ideal = d2 - 2.0 * (d2 - 1.0).sqrt();
        ratios.push(ideal / s.gap());
        // λ1 of the product is exactly d² (biregular product).
        assert!((s.lambda1 - d2).abs() < 1e-9 * d2);
        // λ2(product) = d · λ2(base_max) ≤ product of bound-level λ2's —
        // the gap is within a constant of ideal at every size.
        assert!(
            ratios.last().unwrap() > &0.3,
            "gap ratio collapsed at n={n}: {ratios:?}"
        );
    }
    // The ratio approaches 1 *from above* as n grows (Theorem 1's limit).
    assert!(
        ratios[2] < ratios[0],
        "ratio did not improve with n: {ratios:?}"
    );
    assert!(*ratios.last().unwrap() >= 1.0 - 1e-9, "ratio below 1: {ratios:?}");
}

/// The eigenvalue-product identity used in Theorem 1's proof.
#[test]
fn product_lambda2_is_product_of_spectra() {
    let mut rng = Rng::new(9);
    let g1 = ramanujan::generate_best_effort(16, 16, 0.5, &mut rng, 64)
        .unwrap()
        .0
        .graph;
    let g2 = ramanujan::generate_best_effort(16, 16, 0.5, &mut rng, 64)
        .unwrap()
        .0
        .graph;
    let s1 = spectral::spectrum(&g1, 1);
    let s2 = spectral::spectrum(&g2, 2);
    let p = product_many(&[&g1, &g2]).unwrap();
    let sp = spectral::spectrum(&p, 3);
    // λ2(G) = max(λ1·λ2', λ2·λ1') for the product of two bipartite graphs.
    let expect = (s1.lambda1 * s2.lambda2).max(s1.lambda2 * s2.lambda1);
    assert!(
        (sp.lambda2 - expect).abs() < 1e-4 * expect.max(1.0),
        "λ2(product) {} vs expected {}",
        sp.lambda2,
        expect
    );
}

/// RBGP masks with sparse-but-Ramanujan base graphs stay connected —
/// the §4 information-flow property.
#[test]
fn rbgp4_mask_is_connected() {
    let mut rng = Rng::new(55);
    let cfg = Rbgp4Config {
        // Degrees must exceed 2: at d = 2 the Ramanujan bound is vacuous
        // (λ2 ≤ 2 = λ1) and disconnected unions of cycles can pass it.
        go: GraphSpec::new(8, 8, 0.5),
        gr: (2, 2),
        gi: GraphSpec::new(8, 8, 0.5),
        gb: (1, 1),
    };
    let mask = Rbgp4Mask::sample(cfg, &mut rng).unwrap();
    assert!(mask.product_graph().is_connected());
}

/// The measured CPU kernels and the V100 cost model must agree on the
/// *direction* of the Table-2 headline: at high sparsity RBGP4 beats dense.
#[test]
fn measured_and_model_agree_rbgp4_beats_dense_at_high_sparsity() {
    let n = 512usize;
    let cfg = Rbgp4Config {
        go: GraphSpec::new(4, 16, 0.75),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, 0.5),
        gb: (1, 1),
    };
    assert_eq!((cfg.rows(), cfg.cols()), (n, n));
    let mut rng = Rng::new(77);
    let mask = Rbgp4Mask::sample(cfg, &mut rng).unwrap();
    let w = Rbgp4Matrix::random(mask, &mut rng);
    let i = rng.normal_vec_f32(n * n, 1.0);
    let mut o = vec![0.0f32; n * n];
    let bench = BenchConfig {
        warmup_iters: 1,
        samples: 5,
        max_total: std::time::Duration::from_secs(10),
    };
    let t_sparse = bench_fn(&bench, || {
        rbgp4mm(&w, &i, &mut o, n);
        std::hint::black_box(&o);
    })
    .median;
    let wd = rng.normal_vec_f32(n * n, 1.0);
    let t_dense = bench_fn(&bench, || {
        gemm_blocked(&wd, &i, &mut o, n, n, n);
        std::hint::black_box(&o);
    })
    .median;
    assert!(
        t_sparse < t_dense,
        "measured: rbgp4mm {t_sparse} !< dense {t_dense} at 87.5% sparsity"
    );
    let dev = Device::v100();
    let shape = SdmmShape { m: n, k: n, n };
    let m_sparse = estimate(&dev, shape, &KernelKind::Rbgp4 { config: cfg }).t_total;
    let m_dense = estimate(&dev, shape, &KernelKind::DenseCublas).t_total;
    assert!(m_sparse < m_dense, "model disagrees with measurement");
}

/// Figure 3's exact numbers through the public API.
#[test]
fn figure3_exact_succinctness() {
    let mut rng = Rng::new(1);
    let g1 = rbgp::graph::BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
    let g2 = rbgp::graph::BipartiteGraph::identity(2);
    let g3 = rbgp::graph::BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
    let g4 = rbgp::graph::BipartiteGraph::complete(2, 2);
    let p = product_many(&[&g1, &g2, &g3, &g4]).unwrap();
    assert_eq!(p.num_edges(), 512);
    let base = g1.num_edges() + g2.num_edges() + g3.num_edges() + g4.num_edges();
    assert_eq!(base, 22);
    assert!(512 / base >= 23);
}
