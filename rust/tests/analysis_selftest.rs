//! Repo-wide self-test for `rbgp analyze`: the same pass CI runs as a
//! blocking step must come back clean over this crate's own sources, so
//! a plain `cargo test` catches new invariant violations before CI does.

use std::path::PathBuf;

use rbgp::analysis::{analyze_tree, AnalysisOptions, Report};

fn manifest_roots() -> Vec<PathBuf> {
    let base = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    ["src", "benches", "tests"]
        .iter()
        .map(|d| base.join(d))
        .filter(|p| p.is_dir())
        .collect()
}

fn run_pass() -> Report {
    analyze_tree(&AnalysisOptions {
        roots: manifest_roots(),
        deny: Vec::new(),
    })
    .expect("analysis pass runs over the crate tree")
}

#[test]
fn repo_tree_has_no_unannotated_findings() {
    let report = run_pass();
    assert!(
        report.files_scanned > 20,
        "expected the whole crate, scanned only {} files",
        report.files_scanned
    );
    let denied: Vec<String> = report
        .denied(&[])
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        denied.is_empty(),
        "unannotated findings (fix or add `// analyze: allow(rule, reason=\"…\")`):\n{}",
        denied.join("\n")
    );
}

#[test]
fn every_waiver_carries_a_reason() {
    let report = run_pass();
    assert!(
        report.allowed_count() > 0,
        "the tree carries annotated debt; an empty waiver set means the scan missed it"
    );
    for f in report.findings.iter().filter(|f| f.allowed.is_some()) {
        let reason = f.allowed.as_deref().unwrap_or("");
        assert!(
            !reason.trim().is_empty(),
            "{}:{} [{}] waived without a reason",
            f.file,
            f.line,
            f.rule
        );
    }
}

#[test]
fn unsafe_inventory_is_fully_justified() {
    let report = run_pass();
    assert!(
        !report.unsafe_inventory.is_empty(),
        "the packed-panel kernel has unsafe sites; an empty inventory means the scan missed them"
    );
    for site in &report.unsafe_inventory {
        assert!(
            site.safety.is_some(),
            "{}:{} `{}` lacks an adjacent // SAFETY: comment",
            site.file,
            site.line,
            site.kind
        );
    }
}

#[test]
fn report_artifact_says_clean() {
    let report = run_pass();
    let json = report.to_json(&[]).to_string_pretty();
    assert!(json.contains("\"clean\": true"), "report not clean:\n{json}");
    assert!(json.contains("\"unsafe_inventory\""));
    assert!(json.contains("\"lock_graph_edges\""));
}
