//! Integration tests for zero-downtime model rollout over the public API:
//! an alias (`prod`) fronting a trained checkpoint, shadow mode recording
//! nonzero logit divergence against a staged v2 without ever answering
//! from it, deterministic canary routing, and the one-call
//! [`InferenceServer::rollout`] — atomic flip, drain, retire — under
//! sustained High/Normal/Low traffic with **zero dropped or errored
//! requests** and bit-identical v1 answers until the flip.
//!
//! These run on the default (native) build — no artifacts, no `xla`.

use rbgp::coordinator::{
    InferenceServer, NativeCheckpoint, NativeSparseModel, NativeTrainer, Priority, ServeError,
    ServerConfig, SubmitOptions,
};
use rbgp::kernels::plan::SparseMatrix;
use rbgp::kernels::PlanCache;
use rbgp::sparsity::memory::Pattern;
use rbgp::train_native::NativeTrainConfig;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const IN_DIM: usize = 64;
const HIDDEN: usize = 64;
const CLASSES: usize = 4;
const BATCH: usize = 8;

fn quick_config(seed: u64, steps: usize) -> NativeTrainConfig {
    NativeTrainConfig {
        steps,
        batch: 16,
        lr: 0.05,
        seed,
        ..NativeTrainConfig::default()
    }
}

/// Train a small RBGP4-masked model for a few steps and snapshot it.
fn trained_checkpoint(seed: u64) -> NativeCheckpoint {
    let mut t = NativeTrainer::new(
        IN_DIM,
        HIDDEN,
        CLASSES,
        Pattern::Rbgp4,
        0.75,
        quick_config(seed, 5),
    )
    .unwrap()
    .with_threads(1);
    for s in 0..5 {
        t.step(s);
    }
    t.checkpoint()
}

/// Deterministic per-index sample.
fn sample(i: usize) -> Vec<f32> {
    (0..IN_DIM)
        .map(|d| {
            let v = (d * 31 + i * 13 + 7) % 23;
            (v as f32 - 11.0) / 11.0
        })
        .collect()
}

/// Reusable single-model reference: forwards each sample in slot 0 of a
/// zero-padded batch, exactly as the pool's batcher does. One private
/// plan cache per reference so the pool's cache accounting stays clean.
struct Reference(NativeSparseModel);

impl Reference {
    fn new(ckpt: &NativeCheckpoint) -> Reference {
        Reference(
            ckpt.serving_model(BATCH, 1, Arc::new(PlanCache::new()))
                .unwrap(),
        )
    }

    fn logits(&mut self, x: &[f32]) -> Vec<f32> {
        let mut xb = vec![0.0f32; BATCH * IN_DIM];
        xb[..IN_DIM].copy_from_slice(x);
        self.0.forward(&xb).unwrap()[..CLASSES].to_vec()
    }
}

/// Poll until `f` holds (the pool flushes asynchronously) or fail loudly.
fn wait_for(what: &str, f: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn prod(priority: Priority) -> SubmitOptions {
    SubmitOptions::default()
        .with_model("prod")
        .with_priority(priority)
}

#[test]
fn full_rollout_under_sustained_traffic_drops_nothing() {
    let c1 = trained_checkpoint(21);
    let c2 = trained_checkpoint(22);
    assert_ne!(c1.structure_hash(), c2.structure_hash());
    let mut ref1 = Reference::new(&c1);
    let mut ref2 = Reference::new(&c2);

    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "v1",
        c1.serving_factory(BATCH, 1, Arc::clone(&cache)),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server.set_alias("prod", "v1").unwrap();

    // Phase A — alias-only traffic is bit-identical to v1: an alias is a
    // rename, not a reroute.
    for i in 0..20 {
        let x = sample(i);
        assert_eq!(
            server.infer_with(x.clone(), prod(Priority::Normal)).unwrap(),
            ref1.logits(&x),
            "pre-rollout alias answers must be bit-identical to v1"
        );
    }

    // Phase B — stage v2 in shadow: clients still get exactly v1, while
    // mirrored execution measures a real (nonzero) divergence.
    server
        .register_model("v2", c2.serving_factory(BATCH, 1, Arc::clone(&cache)))
        .unwrap();
    server.set_shadow("prod", "v2").unwrap();
    for i in 0..20 {
        let x = sample(i);
        assert_eq!(
            server.infer_with(x.clone(), prod(Priority::Normal)).unwrap(),
            ref1.logits(&x),
            "shadow mode must never change the client answer"
        );
    }
    wait_for("shadow mirrors to flush", || {
        server
            .alias_stats()
            .iter()
            .any(|a| a.alias == "prod" && a.shadow_samples + a.shadow_dropped >= 20)
    });
    {
        let stats = server.alias_stats();
        let a = stats.iter().find(|a| a.alias == "prod").unwrap();
        assert!(a.shadow_samples > 0, "no mirror ever completed: {a:?}");
        assert!(
            a.shadow_max > 0.0 && a.shadow_mean > 0.0,
            "two differently-seeded checkpoints must diverge: {a:?}"
        );
        assert_eq!(a.shadow_hist.iter().sum::<usize>(), a.shadow_samples);
        assert_eq!(a.canary, 0, "shadow mode routes nothing to v2");
    }
    server.clear_shadow("prod").unwrap();

    // Phase C — canary 10%: every answer comes from exactly one of the two
    // checkpoints, the split is deterministic in the payload, and the
    // observed fraction is sane for 200 distinct samples.
    server.set_canary("prod", "v2", 10).unwrap();
    let mut canaried = 0usize;
    for i in 0..200 {
        let x = sample(i);
        let got = server.infer_with(x.clone(), prod(Priority::Normal)).unwrap();
        let (r1, r2) = (ref1.logits(&x), ref2.logits(&x));
        assert!(
            got == r1 || got == r2,
            "canary answer matches neither checkpoint (sample {i})"
        );
        if got == r2 && r1 != r2 {
            canaried += 1;
        }
        // Determinism: replaying the identical payload lands on the same
        // leg, bit for bit.
        assert_eq!(
            server.infer_with(x.clone(), prod(Priority::Normal)).unwrap(),
            got,
            "canary assignment must be deterministic in the payload"
        );
    }
    assert!(canaried > 0, "a 10% canary over 200 samples routed nothing");
    assert!(
        (canaried as f64) / 200.0 < 0.5,
        "10% canary routed {canaried}/200 — hash split is broken"
    );
    let a = server
        .alias_stats()
        .into_iter()
        .find(|a| a.alias == "prod")
        .unwrap();
    assert!(a.canary >= canaried, "canary counter undercounts: {a:?}");
    assert!(a.latency.is_some(), "per-alias latency must be recorded");

    // Phase D — the rollout itself, under sustained mixed-priority
    // traffic. Every in-flight and subsequent request must be answered
    // with one of the two checkpoints' exact logits; nothing may be
    // dropped, rejected, or errored.
    let stop = Arc::new(AtomicBool::new(false));
    let errors = Arc::new(AtomicUsize::new(0));
    let answered = Arc::new(AtomicUsize::new(0));
    // Precompute (x, ref1, ref2) so client threads never build models.
    let pool: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..12)
        .map(|i| {
            let x = sample(i);
            let (r1, r2) = (ref1.logits(&x), ref2.logits(&x));
            (x, r1, r2)
        })
        .collect();
    let report = std::thread::scope(|scope| {
        for (t, priority) in [Priority::High, Priority::Normal, Priority::Low]
            .into_iter()
            .enumerate()
        {
            let server = server.clone();
            let stop = Arc::clone(&stop);
            let errors = Arc::clone(&errors);
            let answered = Arc::clone(&answered);
            let pool = &pool;
            scope.spawn(move || {
                let mut i = t;
                while !stop.load(Ordering::Acquire) {
                    let (x, r1, r2) = &pool[i % pool.len()];
                    match server.infer_with(x.clone(), prod(priority)) {
                        Ok(got) if got == *r1 || got == *r2 => {
                            answered.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(_) | Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    i += 1;
                }
            });
        }
        // Let the fleet build up real in-flight traffic, then roll out.
        let before = answered.load(Ordering::Relaxed) + 50;
        wait_for("sustained traffic", || {
            answered.load(Ordering::Relaxed) >= before
        });
        let report = server.rollout("prod", "v2").unwrap();
        // Keep traffic flowing on the flipped alias before stopping.
        let after = answered.load(Ordering::Relaxed) + 50;
        wait_for("post-flip traffic", || {
            answered.load(Ordering::Relaxed) >= after
        });
        stop.store(true, Ordering::Release);
        report
    });

    // The retire evicted exactly v1's orphaned hidden namespace; the dense
    // classifier structure is shared with v2 and retained.
    let dense_w2 = SparseMatrix::dense(vec![0.0; CLASSES * HIDDEN], CLASSES, HIDDEN);
    assert_eq!(report.model, "v1");
    assert_eq!(report.evicted_structures, vec![c1.structure_hash()]);
    assert_eq!(report.retained_structures, vec![dense_w2.structure_hash()]);
    assert!(report.evicted_plans >= 1);
    assert_eq!(cache.structure_plan_count(c1.structure_hash()), 0);

    // The zero-downtime invariant, verbatim.
    assert_eq!(errors.load(Ordering::Relaxed), 0, "rollout dropped answers");
    assert_eq!(server.rejected(), (0, 0), "no queue-full or deadline drops");
    assert_eq!(server.rejected_quota(), 0, "no quota drops");

    // Phase E — after the flip: prod is bit-identical v2, v1 is gone.
    for i in 0..20 {
        let x = sample(i);
        assert_eq!(
            server.infer_with(x.clone(), prod(Priority::Normal)).unwrap(),
            ref2.logits(&x),
            "post-rollout alias answers must be bit-identical to v2"
        );
    }
    match server.infer_with(sample(0), SubmitOptions::default().with_model("v1")) {
        Err(ServeError::UnknownModel { model }) => assert_eq!(model, "v1"),
        other => panic!("expected UnknownModel for retired v1, got {other:?}"),
    }
    assert_eq!(server.alias_target("prod").as_deref(), Some("v2"));
    assert_eq!(server.models(), vec!["v2".to_string()]);
    server.shutdown();
}

#[test]
fn alias_operations_validate_targets_and_geometry() {
    let c1 = trained_checkpoint(23);
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "v1",
        c1.serving_factory(BATCH, 1, Arc::clone(&cache)),
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    // Targets must exist; alias and model-id namespaces are disjoint.
    assert!(server.set_alias("prod", "ghost").is_err(), "unknown target");
    assert!(
        server.set_alias("v1", "v1").is_err(),
        "an alias may not shadow a model id"
    );
    server.set_alias("prod", "v1").unwrap();
    assert!(
        server
            .register_model("prod", || anyhow::bail!("never built"))
            .is_err(),
        "a model id may not shadow an alias"
    );
    assert!(server.remove_alias("nope").is_err());
    assert!(server.set_canary("nope", "v1", 10).is_err());
    assert!(server.set_shadow("nope", "v1").is_err());
    assert!(server.promote("prod", "ghost").is_err());
    assert!(
        server.rollout("v1", "v1").is_err(),
        "rollout requires an alias, not a model id"
    );
    assert!(
        server.rollout("prod", "v1").is_err(),
        "rollout to the current primary is a no-op error"
    );

    // Canary and shadow legs must match the primary's geometry: a model
    // with a different class count is rejected up front, not at flush.
    let mut t = NativeTrainer::new(
        IN_DIM,
        HIDDEN,
        2 * CLASSES,
        Pattern::Rbgp4,
        0.75,
        quick_config(24, 2),
    )
    .unwrap()
    .with_threads(1);
    t.step(0);
    let wide = t.checkpoint();
    server
        .register_model("wide", wide.serving_factory(BATCH, 1, Arc::clone(&cache)))
        .unwrap();
    assert!(
        server.set_canary("prod", "wide", 10).is_err(),
        "geometry-mismatched canary must be rejected"
    );
    assert!(
        server.set_shadow("prod", "wide").is_err(),
        "geometry-mismatched shadow must be rejected"
    );
    // Percent bounds are validated against a *valid* target.
    let c2 = trained_checkpoint(25);
    server
        .register_model("v2", c2.serving_factory(BATCH, 1, Arc::clone(&cache)))
        .unwrap();
    assert!(server.set_canary("prod", "v2", 0).is_err());
    assert!(server.set_canary("prod", "v2", 101).is_err());
    server.set_canary("prod", "v2", 100).unwrap();
    let info = server
        .aliases()
        .into_iter()
        .find(|a| a.alias == "prod")
        .unwrap();
    assert_eq!(info.target, "v1");
    assert_eq!(info.canary, Some(("v2".to_string(), 100)));
    assert_eq!(info.shadow, None);
    // A 100% canary routes everything to v2 — but the alias target (what
    // a promote retires) is still v1 until the flip.
    let x = sample(3);
    let mut ref2 = Reference::new(&c2);
    assert_eq!(
        server.infer_with(x.clone(), prod(Priority::Normal)).unwrap(),
        ref2.logits(&x)
    );
    server.shutdown();
}
