//! Integration tests for the multi-model serving registry over the public
//! API: several checkpoints served concurrently from one worker pool, all
//! sharing one plan cache (builds scale with distinct structures, not
//! models × workers); bit-identical logits against single-model serving;
//! batches that never mix models; and `unregister_model` draining a model
//! and evicting exactly its plan namespaces.
//!
//! These run on the default (native) build — no artifacts, no `xla`.

use rbgp::coordinator::{
    BatchModel, InferenceServer, ModelQuota, NativeCheckpoint, NativeTrainer, Priority,
    ServeError, ServerConfig, SubmitOptions, DEFAULT_MODEL,
};
use rbgp::kernels::plan::SparseMatrix;
use rbgp::kernels::PlanCache;
use rbgp::sparsity::memory::Pattern;
use rbgp::util::lock_recover;
use rbgp::train_native::{GradualSchedule, NativeTrainConfig};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

const IN_DIM: usize = 64;
const HIDDEN: usize = 64;
const CLASSES: usize = 4;
const BATCH: usize = 8;

fn quick_config(seed: u64, steps: usize) -> NativeTrainConfig {
    NativeTrainConfig {
        steps,
        batch: 16,
        lr: 0.05,
        seed,
        ..NativeTrainConfig::default()
    }
}

/// Train a small RBGP4-masked model for a few steps and snapshot it.
fn trained_checkpoint(seed: u64) -> NativeCheckpoint {
    let mut t = NativeTrainer::new(
        IN_DIM,
        HIDDEN,
        CLASSES,
        Pattern::Rbgp4,
        0.75,
        quick_config(seed, 5),
    )
    .unwrap()
    .with_threads(1);
    for s in 0..5 {
        t.step(s);
    }
    t.checkpoint()
}

/// Deterministic per-(client, request) sample.
fn sample(client: usize, req: usize) -> Vec<f32> {
    (0..IN_DIM)
        .map(|i| {
            let v = (i * 31 + client * 7 + req * 13) % 23;
            (v as f32 - 11.0) / 11.0
        })
        .collect()
}

/// Single-model reference logits on a private cache: forward the sample in
/// slot 0 of a zero-padded batch, exactly as the pool's batcher does.
fn reference_logits(ckpt: &NativeCheckpoint, x: &[f32]) -> Vec<f32> {
    let mut model = ckpt
        .serving_model(BATCH, 1, Arc::new(PlanCache::new()))
        .unwrap();
    let mut xb = vec![0.0f32; BATCH * IN_DIM];
    xb[..IN_DIM].copy_from_slice(x);
    model.forward(&xb).unwrap()[..CLASSES].to_vec()
}

#[test]
fn two_models_share_one_pool_and_one_cache_with_bit_identical_logits() {
    let ca = trained_checkpoint(1);
    let cb = trained_checkpoint(2);
    assert_ne!(
        ca.structure_hash(),
        cb.structure_hash(),
        "different seeds sample different masks"
    );

    let cache = Arc::new(PlanCache::new());
    let workers = 2;
    let server = InferenceServer::start_model_as(
        "a",
        ca.serving_factory(BATCH, 1, Arc::clone(&cache)),
        ServerConfig {
            workers,
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("b", cb.serving_factory(BATCH, 1, Arc::clone(&cache)))
        .unwrap();
    assert_eq!(server.models(), vec!["a".to_string(), "b".to_string()]);

    // Mixed concurrent traffic: every response must be bit-identical to
    // the single-model forward of its own checkpoint, regardless of which
    // worker served it or what else was in flight.
    let clients = 4;
    let per_client = 12;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = server.clone();
            let (ca, cb) = (&ca, &cb);
            scope.spawn(move || {
                for r in 0..per_client {
                    let x = sample(c, r);
                    let (id, ckpt) = if (c + r) % 2 == 0 { ("a", ca) } else { ("b", cb) };
                    let got = server
                        .infer_with(x.clone(), SubmitOptions::default().with_model(id))
                        .unwrap();
                    assert_eq!(
                        got,
                        reference_logits(ckpt, &x),
                        "model '{id}' logits diverged from single-model serving"
                    );
                }
            });
        }
    });

    // A request without a model id routes to the default ("a").
    let x = sample(9, 9);
    assert_eq!(server.infer(x.clone()).unwrap(), reference_logits(&ca, &x));

    // The acceptance invariant: cache builds == number of distinct
    // structures (two RBGP4 hidden layers + the shared dense classifier),
    // NOT models × workers × layers.
    let (hits, misses) = cache.stats();
    assert_eq!(misses, 3, "one build per structure, pool- and model-wide");
    // Guaranteed floor: the second worker's startup build of "a" (2 layer
    // plans) and the register-time probe of "b" resolving the shared dense
    // classifier all hit; lazy worker builds of "b" only add more.
    assert!(hits >= 3, "warm resolves must hit the cache (got {hits} hits)");
    assert_eq!(cache.structures().len(), 3);

    // Per-model counters cover the traffic exactly.
    let stats = server.model_stats();
    assert_eq!(stats.len(), 2);
    let total: usize = stats.iter().map(|m| m.requests).sum();
    assert_eq!(total, clients * per_client + 1);
    assert!(stats.iter().all(|m| m.batches >= 1));
    server.shutdown();
}

#[test]
fn unregister_drains_and_evicts_exactly_the_retired_namespace() {
    let ca = trained_checkpoint(3);
    let cb = trained_checkpoint(4);
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model_as(
        "a",
        ca.serving_factory(BATCH, 1, Arc::clone(&cache)),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("b", cb.serving_factory(BATCH, 1, Arc::clone(&cache)))
        .unwrap();

    // Serve some traffic on both so worker instances exist everywhere.
    for r in 0..8 {
        let x = sample(0, r);
        server
            .infer_with(x.clone(), SubmitOptions::default().with_model("b"))
            .unwrap();
        server
            .infer_with(x, SubmitOptions::default().with_model("a"))
            .unwrap();
    }

    let dense_w2 = SparseMatrix::dense(vec![0.0; CLASSES * HIDDEN], CLASSES, HIDDEN);
    let plans_a = cache.structure_plan_count(ca.structure_hash());
    let plans_w2 = cache.structure_plan_count(dense_w2.structure_hash());
    assert!(plans_a >= 1 && plans_w2 >= 1);
    let (_, evicted_before) = cache.eviction_stats();

    let report = server.unregister_model("b").unwrap();
    assert_eq!(report.model, "b");
    // Exactly b's hidden-layer namespace dies; the dense classifier
    // structure is shared with the surviving model and must be retained.
    assert_eq!(report.evicted_structures, vec![cb.structure_hash()]);
    assert_eq!(report.retained_structures, vec![dense_w2.structure_hash()]);
    assert!(report.evicted_plans >= 1);
    assert_eq!(
        cache.structure_plan_count(cb.structure_hash()),
        0,
        "zero plans may linger for the retired structure"
    );
    assert_eq!(cache.structure_plan_count(ca.structure_hash()), plans_a);
    assert_eq!(cache.structure_plan_count(dense_w2.structure_hash()), plans_w2);
    let (_, evicted_after) = cache.eviction_stats();
    assert_eq!(
        evicted_after - evicted_before,
        report.evicted_plans,
        "report counters agree with the cache's own eviction accounting"
    );

    // b is gone; a is untouched.
    match server.infer_with(sample(0, 0), SubmitOptions::default().with_model("b")) {
        Err(ServeError::UnknownModel { model }) => assert_eq!(model, "b"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    let x = sample(1, 1);
    assert_eq!(
        server
            .infer_with(x.clone(), SubmitOptions::default().with_model("a"))
            .unwrap(),
        reference_logits(&ca, &x)
    );
    server.shutdown();
}

/// A model that fails loudly if any foreign sample lands in its batch:
/// every occupied row must start with this model's tag (padding rows are
/// all-zero). Proves the batcher never co-flushes two models.
struct TagModel {
    tag: f32,
    batch: usize,
}

impl BatchModel for TagModel {
    fn batch(&self) -> usize {
        self.batch
    }
    fn in_dim(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        for &v in x {
            anyhow::ensure!(
                v == 0.0 || v == self.tag,
                "mixed-model flush: saw sample {v}, expected tag {} or padding",
                self.tag
            );
        }
        Ok(x.to_vec())
    }
}

#[test]
fn mixed_model_traffic_is_never_co_flushed() {
    let server = InferenceServer::start_model_as(
        "t1",
        || Ok(Box::new(TagModel { tag: 1.0, batch: 4 }) as Box<dyn BatchModel>),
        ServerConfig {
            workers: 2,
            // A real straggler window, so batches actually aggregate
            // concurrent mixed-model submits.
            max_wait: Duration::from_millis(3),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("t2", || {
            Ok(Box::new(TagModel { tag: 2.0, batch: 4 }) as Box<dyn BatchModel>)
        })
        .unwrap();

    let clients = 6;
    let per_client = 24;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = server.clone();
            scope.spawn(move || {
                for r in 0..per_client {
                    let (id, tag) = if (c + r) % 2 == 0 { ("t1", 1.0) } else { ("t2", 2.0) };
                    let got = server
                        .infer_with(vec![tag], SubmitOptions::default().with_model(id))
                        .unwrap();
                    assert_eq!(got, vec![tag]);
                }
            });
        }
    });
    let stats = server.model_stats();
    assert_eq!(stats.len(), 2);
    for m in &stats {
        assert_eq!(m.requests, clients * per_client / 2, "{stats:?}");
        assert_eq!(m.errors, 0, "a co-flush would have errored: {stats:?}");
    }
    // Batching actually happened (not one request per flush everywhere),
    // otherwise this test proves nothing about flush composition.
    let (requests, batches) = server.counters();
    assert_eq!(requests, clients * per_client);
    assert!(batches <= requests, "{batches} batches for {requests} requests");
    server.shutdown();
}

/// A model that panics when fed its poison pill — simulates a worker
/// crashing mid-flush under mixed multi-model traffic.
struct PillModel;

impl BatchModel for PillModel {
    fn batch(&self) -> usize {
        1
    }
    fn in_dim(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        assert!(x[0] < 0.5, "poison pill");
        Ok(x.to_vec())
    }
}

#[test]
fn panicking_model_under_mixed_traffic_does_not_strand_index_entries() {
    let server = InferenceServer::start_model_as(
        "t1",
        || Ok(Box::new(TagModel { tag: 1.0, batch: 2 }) as Box<dyn BatchModel>),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("boom", || Ok(Box::new(PillModel) as Box<dyn BatchModel>))
        .unwrap();

    // Healthy mixed traffic on both models first.
    for _ in 0..4 {
        assert_eq!(
            server
                .infer_with(vec![1.0], SubmitOptions::default().with_model("t1"))
                .unwrap(),
            vec![1.0]
        );
        assert_eq!(
            server
                .infer_with(vec![0.0], SubmitOptions::default().with_model("boom"))
                .unwrap(),
            vec![0.0]
        );
    }
    // The pill kills one of the two workers mid-flush; its client sees the
    // typed dropped-request error, not a hang.
    assert!(matches!(
        server.infer_with(vec![1.0], SubmitOptions::default().with_model("boom")),
        Err(ServeError::Stopped)
    ));
    // The surviving worker keeps serving BOTH models: the dead worker's
    // unwind dropped its claims, and no entry was stranded in either the
    // primary FIFOs or the per-model index.
    for _ in 0..4 {
        assert_eq!(
            server
                .infer_with(vec![1.0], SubmitOptions::default().with_model("t1"))
                .unwrap(),
            vec![1.0]
        );
        assert_eq!(
            server
                .infer_with(vec![0.0], SubmitOptions::default().with_model("boom"))
                .unwrap(),
            vec![0.0]
        );
    }
    assert_eq!(server.queue_depth(), 0, "no stranded entries");
    assert_eq!(server.model_queue_depth("t1"), 0);
    assert_eq!(server.model_queue_depth("boom"), 0);
    // Unregistering the panicky model drains instantly (claims == 0) and
    // its eviction accounting is exact: these models are not plan-cached,
    // so exactly nothing is evicted.
    let report = server.unregister_model("boom").unwrap();
    assert_eq!(report.drained_requests, 0, "panic unwind dropped all claims");
    assert!(report.evicted_structures.is_empty());
    assert!(report.retained_structures.is_empty());
    assert_eq!(report.evicted_plans, 0);
    assert_eq!(server.models(), vec!["t1".to_string()]);
    assert_eq!(
        server
            .infer_with(vec![1.0], SubmitOptions::default().with_model("t1"))
            .unwrap(),
        vec![1.0]
    );
    server.shutdown();
}

/// A tagging model that blocks inside `forward` until its gate channel
/// drops — lets tests pin the (single) worker and build queue backlogs
/// deterministically.
struct GatedTagModel {
    gate: mpsc::Receiver<()>,
    batch: usize,
    log: Arc<Mutex<Vec<f32>>>,
}

impl BatchModel for GatedTagModel {
    fn batch(&self) -> usize {
        self.batch
    }
    fn in_dim(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        lock_recover(&self.log).extend_from_slice(x);
        let _ = self.gate.recv(); // blocks until the test drops the gate
        Ok(x.to_vec())
    }
}

fn gated_server(
    batch: usize,
    config: ServerConfig,
) -> (InferenceServer, mpsc::Sender<()>, Arc<Mutex<Vec<f32>>>) {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let log = Arc::new(Mutex::new(Vec::new()));
    let slot = Arc::new(Mutex::new(Some(gate_rx)));
    let factory_log = Arc::clone(&log);
    let server = InferenceServer::start_model_as(
        "slow",
        move || {
            let gate = lock_recover(&slot).take().expect("single worker");
            Ok(Box::new(GatedTagModel {
                gate,
                batch,
                log: Arc::clone(&factory_log),
            }) as Box<dyn BatchModel>)
        },
        config,
    )
    .unwrap();
    (server, gate_tx, log)
}

#[test]
fn unregister_during_steal_drains_cleanly() {
    // Single worker, batch-4 gated model "slow", long straggler window:
    // the worker pops slow#1 and sits waiting for slow stragglers — until
    // model "bye"'s backlog fires the steal hint.
    let (server, gate_tx, _log) = gated_server(
        4,
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(400),
            ..ServerConfig::default()
        },
    );
    server
        .register_model("bye", || {
            Ok(Box::new(TagModel { tag: 2.0, batch: 4 }) as Box<dyn BatchModel>)
        })
        .unwrap();

    let rx_slow = server
        .submit_with(vec![1.0], SubmitOptions::default().with_model("slow"))
        .unwrap();
    // Backlog for "bye" while the worker is inside slow's straggler
    // window: the steal hint makes it flush slow#1 alone (well before the
    // 400 ms window closes) and block on the gate.
    let rx_bye: Vec<_> = (0..3)
        .map(|_| {
            server
                .submit_with(vec![2.0], SubmitOptions::default().with_model("bye"))
                .unwrap()
        })
        .collect();
    // Retire "bye" while its three requests are still queued: the drain
    // must block on exactly those claims.
    let unregister = std::thread::spawn({
        let server = server.clone();
        move || server.unregister_model("bye").unwrap()
    });
    // Retire has begun once the public model list shrinks; new "bye"
    // submits are already rejected while the drain runs.
    while server.models().len() == 2 {
        std::thread::yield_now();
    }
    assert!(matches!(
        server.infer_with(vec![2.0], SubmitOptions::default().with_model("bye")),
        Err(ServeError::UnknownModel { .. })
    ));
    // A second slow request: after draining the byes the worker steals
    // back to "slow" instead of idling out bye's straggler window.
    let rx_slow2 = server
        .submit_with(vec![1.0], SubmitOptions::default().with_model("slow"))
        .unwrap();
    // The byes cannot be served while the gate pins the worker; give the
    // unregister thread ample time to snapshot its in-flight count, then
    // release the worker: everything drains, the unregister completes.
    std::thread::sleep(Duration::from_millis(50));
    drop(gate_tx);
    let report = unregister.join().unwrap();
    assert_eq!(report.model, "bye");
    assert_eq!(report.drained_requests, 3, "exactly the queued bye claims");
    assert!(report.evicted_structures.is_empty(), "TagModel is not plan-cached");
    assert_eq!(report.evicted_plans, 0);
    assert_eq!(rx_slow.recv().unwrap().unwrap(), vec![1.0]);
    for rx in rx_bye {
        assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0], "drained, not dropped");
    }
    assert_eq!(rx_slow2.recv().unwrap().unwrap(), vec![1.0]);
    assert_eq!(server.model_queue_depth("bye"), 0, "index left empty");
    assert_eq!(server.queue_depth(), 0);
    assert_eq!(server.models(), vec!["slow".to_string()]);
    assert!(server.steals() >= 1, "the cut straggler window is a recorded steal");
    // Per-model history survives the unregister, with no co-flush errors.
    let stats = server.model_stats();
    let bye = stats.iter().find(|m| m.model == "bye").unwrap();
    assert_eq!((bye.requests, bye.errors), (3, 0), "{stats:?}");
    server.shutdown();
}

#[test]
fn cold_model_is_served_within_starvation_bounds_under_hot_skew() {
    // ~99:1 skew: closed-loop High-priority traffic on "hot" from two
    // clients against a single worker, then one Low request on "cold".
    // Age promotion (Low → Normal → High at `period` steps) must surface
    // the cold request in bounded time; strict priority would hold it for
    // the whole flood.
    let period = Duration::from_millis(40);
    let server = InferenceServer::start_model_as(
        "hot",
        || Ok(Box::new(TagModel { tag: 1.0, batch: 4 }) as Box<dyn BatchModel>),
        ServerConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            max_starvation: Some(period),
            queue_cap: 64,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("cold", || {
            Ok(Box::new(TagModel { tag: 2.0, batch: 2 }) as Box<dyn BatchModel>)
        })
        .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        for _ in 0..2 {
            let server = server.clone();
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let opts = SubmitOptions::default()
                        .with_model("hot")
                        .with_priority(Priority::High);
                    match server.infer_with(vec![1.0], opts) {
                        Ok(got) => assert_eq!(got, vec![1.0]),
                        Err(ServeError::QueueFull { .. }) => std::thread::yield_now(),
                        Err(e) => panic!("hot traffic failed: {e}"),
                    }
                }
            });
        }
        // Let the hot flood establish itself, then send the cold request.
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        let rx = server
            .submit_with(
                vec![2.0],
                SubmitOptions::default().with_model("cold").with_priority(Priority::Low),
            )
            .unwrap();
        let outcome = rx.recv_timeout(Duration::from_secs(5));
        let waited = t0.elapsed();
        stop.store(true, Ordering::Release);
        let got = outcome
            .unwrap_or_else(|_| panic!("cold model starved for {waited:?} under hot skew"))
            .unwrap();
        assert_eq!(got, vec![2.0]);
        // Low → High promotion takes 2 × 40 ms; leave a generous service
        // margin on top. The hot flood runs on long after this bound.
        assert!(
            waited < Duration::from_secs(2),
            "cold request exceeded the starvation bound: {waited:?}"
        );
    });
    // Steals and promotion never co-flushed the two models: TagModel
    // errors loudly on any foreign (or padded-foreign) sample.
    let stats = server.model_stats();
    for m in &stats {
        assert_eq!(m.errors, 0, "co-flush detected: {stats:?}");
    }
    let cold = stats.iter().find(|m| m.model == "cold").unwrap();
    assert_eq!(cold.requests, 1);
    server.shutdown();
}

#[test]
fn saturated_hot_model_never_blocks_cold_submits() {
    // Single gated batch-1 worker, hot quota 4 on a cap-8 queue: the hot
    // backlog saturates its quota while the shared queue keeps room.
    let (server, gate_tx, log) = gated_server(
        1,
        ServerConfig {
            workers: 1,
            queue_cap: 8,
            max_wait: Duration::from_millis(1),
            model_quota: ModelQuota::Absolute(4),
            ..ServerConfig::default()
        },
    );
    server
        .register_model_with_quota("cold", ModelQuota::Absolute(2), || {
            Ok(Box::new(TagModel { tag: 2.0, batch: 2 }) as Box<dyn BatchModel>)
        })
        .unwrap();

    // Occupy the worker (its pop leaves the queue, not the backlog).
    let rx0 = server
        .submit_with(vec![0.5], SubmitOptions::default().with_model("slow"))
        .unwrap();
    while lock_recover(&log).is_empty() {
        std::thread::yield_now();
    }
    // Fill the hot model's quota with queued requests.
    let queued: Vec<_> = (0..4)
        .map(|_| {
            server
                .submit_with(vec![0.5], SubmitOptions::default().with_model("slow"))
                .unwrap()
        })
        .collect();
    assert_eq!(server.model_queue_depth("slow"), 4);
    // Saturated: the hot model gets the typed per-model rejection …
    match server.submit_with(vec![0.5], SubmitOptions::default().with_model("slow")) {
        Err(ServeError::ModelQuotaExceeded { model, quota }) => {
            assert_eq!((model.as_str(), quota), ("slow", 4));
        }
        other => panic!(
            "expected ModelQuotaExceeded, got {:?}",
            other.map(|_| ())
        ),
    }
    // … while the cold model's submit sails through: the quota kept the
    // shared queue (cap 8) from being exhausted by the hot model.
    let rx_cold = server
        .submit_with(vec![2.0], SubmitOptions::default().with_model("cold"))
        .unwrap();
    assert_eq!(server.model_queue_depth("cold"), 1);
    assert_eq!(server.rejected_quota(), 1);
    assert_eq!(server.rejected(), (0, 0), "never surfaced as QueueFull");
    let stats = server.model_stats();
    let hot = stats.iter().find(|m| m.model == "slow").unwrap();
    assert_eq!(hot.rejected_quota, 1, "{stats:?}");
    // Release the worker: every accepted request is served, and the
    // drained quota admits hot traffic again.
    drop(gate_tx);
    assert_eq!(rx0.recv().unwrap().unwrap(), vec![0.5]);
    for rx in queued {
        assert_eq!(rx.recv().unwrap().unwrap(), vec![0.5]);
    }
    assert_eq!(rx_cold.recv().unwrap().unwrap(), vec![2.0]);
    assert_eq!(server.model_queue_depth("slow"), 0);
    assert_eq!(
        server
            .infer_with(vec![0.5], SubmitOptions::default().with_model("slow"))
            .unwrap(),
        vec![0.5]
    );
    server.shutdown();
}

#[test]
fn gradual_milestone_checkpoints_serve_side_by_side() {
    // A gradual run's pre-milestone (dense-mask) and final (RBGP4)
    // snapshots are different plan-cache namespaces of one trainer; both
    // are served from one pool sharing the trainer's cache.
    let schedule = GradualSchedule::from_fractions(vec![0.4]).unwrap();
    let mut t = NativeTrainer::new_gradual(
        IN_DIM,
        HIDDEN,
        CLASSES,
        0.75,
        &schedule,
        quick_config(11, 20),
    )
    .unwrap()
    .with_threads(1);
    let dense_ckpt = t.checkpoint(); // before any milestone: dense mask
    for s in 0..20 {
        t.step_gradual(s).unwrap();
    }
    let final_ckpt = t.checkpoint();
    assert_ne!(dense_ckpt.structure_hash(), final_ckpt.structure_hash());
    assert_eq!(final_ckpt.structure_hash(), t.structure_hash());

    let cache = Arc::clone(t.cache());
    let server = InferenceServer::start_model_as(
        "final",
        t.checkpoint_factory(&final_ckpt, BATCH, 1),
        ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    server
        .register_model("milestone-0", t.checkpoint_factory(&dense_ckpt, BATCH, 1))
        .unwrap();

    for r in 0..6 {
        let x = sample(2, r);
        assert_eq!(
            server
                .infer_with(x.clone(), SubmitOptions::default().with_model("final"))
                .unwrap(),
            reference_logits(&final_ckpt, &x)
        );
        assert_eq!(
            server
                .infer_with(x.clone(), SubmitOptions::default().with_model("milestone-0"))
                .unwrap(),
            reference_logits(&dense_ckpt, &x)
        );
    }

    // Retiring the milestone model leaves zero plans in its namespace and
    // does not disturb the final structure the trainer still uses.
    let final_plans = cache.structure_plan_count(final_ckpt.structure_hash());
    let report = server.unregister_model("milestone-0").unwrap();
    assert_eq!(report.evicted_structures, vec![dense_ckpt.structure_hash()]);
    assert_eq!(cache.structure_plan_count(dense_ckpt.structure_hash()), 0);
    assert_eq!(
        cache.structure_plan_count(final_ckpt.structure_hash()),
        final_plans
    );
    assert_eq!(server.models(), vec!["final".to_string()]);
    server.shutdown();
}

#[test]
fn default_model_id_constant_routes_unnamed_traffic() {
    let ca = trained_checkpoint(8);
    let cache = Arc::new(PlanCache::new());
    let server = InferenceServer::start_model(
        ca.serving_factory(BATCH, 1, Arc::clone(&cache)),
        ServerConfig::default(),
    )
    .unwrap();
    assert_eq!(server.models(), vec![DEFAULT_MODEL.to_string()]);
    let x = sample(0, 0);
    // Explicitly addressing the default id equals the unnamed route.
    let named = server
        .infer_with(x.clone(), SubmitOptions::default().with_model(DEFAULT_MODEL))
        .unwrap();
    assert_eq!(named, server.infer(x).unwrap());
    server.shutdown();
}
