//! Integration tests over the runtime + coordinator against real AOT
//! artifacts. These require the `xla` feature (PJRT) and `make artifacts`;
//! each test skips (with a message) when artifacts are absent so
//! `cargo test --features xla` stays green in a fresh checkout. Without
//! the feature this file compiles to nothing — the native plan-based
//! coordinator paths are covered by the in-crate unit tests.
#![cfg(feature = "xla")]

use rbgp::coordinator::{InferenceServer, ServerConfig, TrainConfig, Trainer};
use rbgp::runtime::executor::{Executor, HostTensor};
use rbgp::runtime::ArtifactMeta;
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn forward_artifact_is_deterministic_and_finite() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::compile(&dir, "forward").unwrap();
    let meta = &exe.artifact.meta;
    let inputs: Vec<HostTensor> = meta
        .inputs
        .iter()
        .map(|sig| HostTensor::new(vec![0.01; sig.elements()], &sig.shape))
        .collect();
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    assert_eq!(a[0].data, b[0].data, "same inputs → same logits");
    assert!(a[0].data.iter().all(|v| v.is_finite()));
    let batch = meta.batch().unwrap();
    let classes = meta.raw.req_usize("classes").unwrap();
    assert_eq!(a[0].data.len(), batch * classes);
}

#[test]
fn train_step_artifact_reduces_loss_on_fixed_batch() {
    let Some(dir) = artifacts() else { return };
    let config = TrainConfig {
        steps: 8,
        lr0: 0.05,
        lr_decay: 1.0,
        milestones: vec![],
        seed: 123,
        eval_every: 0,
        eval_batches: 1,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&dir, config).unwrap();
    let mut losses = Vec::new();
    for s in 0..8 {
        losses.push(trainer.step(s).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    // Fresh batches each step, but 8 steps at lr .05 on this task must cut
    // the loss substantially (the E2E example reaches ~0 by step 20).
    assert!(
        losses[7] < 0.8 * losses[0],
        "loss did not drop: {losses:?}"
    );
}

#[test]
fn trainer_eval_improves_over_chance() {
    let Some(dir) = artifacts() else { return };
    let config = TrainConfig {
        steps: 12,
        lr0: 0.05,
        lr_decay: 1.0,
        milestones: vec![],
        seed: 7,
        eval_every: 0,
        eval_batches: 2,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&dir, config).unwrap();
    let before = trainer.evaluate(2).unwrap();
    for s in 0..12 {
        trainer.step(s).unwrap();
    }
    let after = trainer.evaluate(2).unwrap();
    assert!(
        after > before + 0.2,
        "accuracy {before:.3} → {after:.3} did not improve"
    );
}

#[test]
fn kd_train_step_runs_when_present() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("train_step_kd.hlo.txt").exists() {
        eprintln!("skipping: no KD artifact");
        return;
    }
    let config = TrainConfig {
        steps: 2,
        distill: true,
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&dir, config).unwrap();
    let l0 = trainer.step(0).unwrap();
    let l1 = trainer.step(1).unwrap();
    assert!(l0.is_finite() && l1.is_finite());
}

#[test]
fn server_roundtrip_with_concurrent_clients() {
    let Some(dir) = artifacts() else { return };
    let server = InferenceServer::start(dir, ServerConfig::default()).unwrap();
    let n = 24;
    std::thread::scope(|scope| {
        for c in 0..3 {
            let server = server.clone();
            scope.spawn(move || {
                for r in 0..n / 3 {
                    let x = vec![0.1 * (c as f32 + 1.0) + r as f32 * 1e-3; server.in_dim];
                    let logits = server.infer(x).unwrap();
                    assert_eq!(logits.len(), server.classes);
                    assert!(logits.iter().all(|v| v.is_finite()));
                }
            });
        }
    });
    let (reqs, batches) = server.counters();
    assert_eq!(reqs, n);
    assert!(batches <= n, "batching never exceeds request count");
    assert!(server.latency_stats().unwrap().p50 > 0.0);
}

#[test]
fn server_rejects_wrong_dim() {
    let Some(dir) = artifacts() else { return };
    let server = InferenceServer::start(dir, ServerConfig::default()).unwrap();
    assert!(server.submit(vec![0.0; 3]).is_err());
}

#[test]
fn metadata_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let manifest = ArtifactMeta::load(&dir.join("forward.json")).unwrap();
    assert_eq!(manifest.kind, "forward");
    let step = ArtifactMeta::load(&dir.join("train_step.json")).unwrap();
    assert_eq!(step.param_order, manifest.param_order);
    // train inputs = params + velocities + x, y, lr
    assert_eq!(
        step.inputs.len(),
        2 * step.param_order.len() + 3,
        "train_step signature"
    );
    assert_eq!(
        step.outputs.len(),
        2 * step.param_order.len() + 1,
        "train_step outputs"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_trained_params() {
    let Some(dir) = artifacts() else { return };
    let config = TrainConfig {
        steps: 3,
        lr0: 0.05,
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&dir, config.clone()).unwrap();
    for s in 0..3 {
        trainer.step(s).unwrap();
    }
    let tmp = std::env::temp_dir().join("rbgp_ckpt_test.json");
    trainer.save_checkpoint(&tmp).unwrap();
    let trained = trainer.params.clone();
    let mut fresh = Trainer::new(&dir, config).unwrap();
    assert_ne!(fresh.params[1].data, trained[1].data, "fresh != trained");
    fresh.load_checkpoint(&tmp).unwrap();
    for (a, b) in fresh.params.iter().zip(&trained) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }
    let _ = std::fs::remove_file(tmp);
}
