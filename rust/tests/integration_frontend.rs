//! Integration tests for the TCP front-end over the public API: real
//! sockets speaking the length-prefixed binary protocol against a live
//! worker pool. Covers echo conformance versus in-process submits, the
//! full reachable status-code surface under saturation (queue, model
//! quota, tenant quota, deadline, unknown model, wrong width, bad
//! frame), out-of-order completion on one connection, and drain-clean
//! shutdown with connections still open.
//!
//! These run on the default (native) build — no artifacts, no `xla`.

use rbgp::coordinator::frontend::protocol;
use rbgp::coordinator::{
    BatchModel, Frontend, FrontendClient, FrontendConfig, InferenceServer, ModelQuota, Priority,
    Request, Response, ServerConfig, Status,
};
use rbgp::util::lock_recover;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

const IN_DIM: usize = 8;

/// Identity model: logits are the sample itself, so a network response
/// can be compared bit-for-bit against the in-process result.
struct EchoModel {
    batch: usize,
    in_dim: usize,
}

impl BatchModel for EchoModel {
    fn batch(&self) -> usize {
        self.batch
    }
    fn in_dim(&self) -> usize {
        self.in_dim
    }
    fn classes(&self) -> usize {
        self.in_dim
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        Ok(x.to_vec())
    }
}

/// Width-1 model that blocks inside `forward` until the gate channel
/// drops — pins the single worker so tests build queue backlogs
/// deterministically. Logs each batch so tests can tell when the worker
/// is actually inside `forward`.
struct GatedModel {
    gate: mpsc::Receiver<()>,
    log: Arc<Mutex<Vec<f32>>>,
}

impl BatchModel for GatedModel {
    fn batch(&self) -> usize {
        1
    }
    fn in_dim(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        lock_recover(&self.log).extend_from_slice(x);
        let _ = self.gate.recv(); // blocks until the test drops the gate
        Ok(x.to_vec())
    }
}

fn echo_server(workers: usize) -> InferenceServer {
    InferenceServer::start_model(
        || Ok(Box::new(EchoModel { batch: 4, in_dim: IN_DIM }) as Box<dyn BatchModel>),
        ServerConfig { workers, max_wait: Duration::from_millis(1), ..ServerConfig::default() },
    )
    .expect("server start")
}

fn gated_server(config: ServerConfig) -> (InferenceServer, mpsc::Sender<()>, Arc<Mutex<Vec<f32>>>) {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let log = Arc::new(Mutex::new(Vec::new()));
    let slot = Arc::new(Mutex::new(Some(gate_rx)));
    let factory_log = Arc::clone(&log);
    let server = InferenceServer::start_model_as(
        "slow",
        move || {
            let gate = lock_recover(&slot).take().expect("single worker");
            Ok(Box::new(GatedModel { gate, log: Arc::clone(&factory_log) }) as Box<dyn BatchModel>)
        },
        config,
    )
    .expect("server start");
    (server, gate_tx, log)
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn request(req_id: u64, priority: Priority, payload: Vec<f32>) -> Request {
    Request { req_id, priority, deadline_ms: 0, tenant: "free".to_string(), model: None, payload }
}

/// Read responses off one connection until every wanted id has arrived
/// (responses interleave out of request order).
fn collect(client: &mut FrontendClient, want: &[u64]) -> HashMap<u64, Response> {
    let mut got = HashMap::new();
    while want.iter().any(|id| !got.contains_key(id)) {
        let resp = client.recv().expect("response frame");
        got.insert(resp.req_id, resp);
    }
    got
}

#[test]
fn network_echo_matches_in_process_submit() {
    let server = echo_server(2);
    let fe = Frontend::start(server.clone(), FrontendConfig::default()).expect("frontend start");
    let mut client = FrontendClient::connect(fe.local_addr()).expect("connect");
    for r in 0..16 {
        let payload: Vec<f32> = (0..IN_DIM).map(|i| (i + r) as f32 / 7.0 - 1.0).collect();
        let resp = client
            .infer(payload.clone(), None, Priority::Normal, "team-a", 0)
            .expect("round trip");
        assert_eq!(resp.status, Status::Ok, "echo request failed: {}", resp.detail);
        // The network path and the in-process path must produce the same
        // logits for the same sample — the socket adds transport, not math.
        let local = server.infer(payload).expect("in-process infer");
        assert_eq!(resp.payload, local);
    }
    let (accepted, rejected, shed) = server.frontend_totals();
    assert_eq!(accepted, 16);
    assert_eq!((rejected, shed), (0, 0));
    fe.shutdown();
    server.shutdown();
}

#[test]
fn every_reachable_error_surfaces_as_its_status_code() {
    // Single gated worker, tiny queue, a quota'd second model and a
    // capped tenant class: every reachable rejection fires and each must
    // come back as its own distinct protocol status.
    let (server, gate_tx, log) = gated_server(ServerConfig {
        workers: 1,
        queue_cap: 4,
        max_wait: Duration::from_millis(1),
        ..ServerConfig::default()
    });
    server
        .register_model_with_quota("quoted", ModelQuota::Absolute(1), || {
            Ok(Box::new(EchoModel { batch: 1, in_dim: 1 }) as Box<dyn BatchModel>)
        })
        .expect("register quoted");
    let fe = Frontend::start(
        server.clone(),
        FrontendConfig {
            tenants: vec![("limited".to_string(), ModelQuota::Absolute(1))],
            ..FrontendConfig::default()
        },
    )
    .expect("frontend start");
    let accepted = |n: usize| {
        let server = server.clone();
        move || server.frontend_totals().0 == n
    };
    let rejected = |n: usize| {
        let server = server.clone();
        move || server.frontend_totals().1 == n
    };

    let mut a = FrontendClient::connect(fe.local_addr()).expect("connect a");
    // Plug: occupies the lone worker inside `forward`, so everything
    // after it queues (or rejects) deterministically.
    a.send(&request(1, Priority::Normal, vec![1.0])).expect("send plug");
    wait_until("worker inside forward", || !lock_recover(&log).is_empty());

    // Synchronous rejections while the queue is still empty.
    a.send(&request(2, Priority::Normal, vec![0.5; 3])).expect("send wrong width");
    a.send(&Request { model: Some("nope".to_string()), ..request(3, Priority::Normal, vec![1.0]) })
        .expect("send unknown model");
    wait_until("both synchronous rejects", rejected(2));

    // Tenant class "limited" caps at 1 in flight: the second request on
    // tenant B is rejected at the front door, before the shared queue.
    let mut b = FrontendClient::connect(fe.local_addr()).expect("connect b");
    let tenant_b = |req_id| Request { tenant: "limited".to_string(), ..request(req_id, Priority::Normal, vec![2.0]) };
    b.send(&tenant_b(100)).expect("send b1");
    wait_until("tenant request admitted", accepted(2));
    b.send(&tenant_b(101)).expect("send b2");
    wait_until("tenant quota reject", rejected(3));

    // Model quota: "quoted" allows one queued request; the second is
    // back-pressured for that model only (the queue still has space).
    a.send(&Request { model: Some("quoted".to_string()), ..request(4, Priority::Normal, vec![3.0]) })
        .expect("send quoted 1");
    wait_until("quoted request admitted", accepted(3));
    a.send(&Request { model: Some("quoted".to_string()), ..request(5, Priority::Normal, vec![4.0]) })
        .expect("send quoted 2");
    wait_until("model quota reject", rejected(4));

    // Fill the shared queue to its cap, one request carrying a 1 ms
    // deadline that will lapse long before the gate opens.
    a.send(&Request { deadline_ms: 1, ..request(6, Priority::Normal, vec![5.0]) })
        .expect("send deadline");
    a.send(&request(7, Priority::Normal, vec![6.0])).expect("send filler");
    wait_until("queue full", accepted(5));
    a.send(&request(8, Priority::Normal, vec![7.0])).expect("send overflow");
    wait_until("queue-full reject", rejected(5));

    // A frame that parses as a length prefix but whose body is garbage:
    // typed BadFrame response (req_id 0 — the id was unreadable).
    let mut c = std::net::TcpStream::connect(fe.local_addr()).expect("connect c");
    c.write_all(&[2, 0, 0, 0, 0xFF, 0xFF]).expect("send garbage");
    let mut len = [0u8; 4];
    c.read_exact(&mut len).expect("bad-frame response length");
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    c.read_exact(&mut body).expect("bad-frame response body");
    let bad = protocol::decode_response(&body).expect("decode bad-frame response");
    assert_eq!((bad.req_id, bad.status), (0, Status::BadFrame), "detail: {}", bad.detail);

    // Let the 1 ms deadline lapse, then open the gate and drain.
    std::thread::sleep(Duration::from_millis(30));
    drop(gate_tx);

    let got = collect(&mut a, &[1, 2, 3, 4, 5, 6, 7, 8]);
    let status = |id: u64| got.get(&id).map(|r| r.status).expect("collected");
    assert_eq!(status(1), Status::Ok);
    assert_eq!(status(2), Status::WrongInputWidth);
    assert_eq!(status(3), Status::UnknownModel);
    assert_eq!(status(4), Status::Ok);
    assert_eq!(status(5), Status::ModelQuotaExceeded);
    assert_eq!(status(6), Status::DeadlineExceeded);
    assert_eq!(status(7), Status::Ok);
    assert_eq!(status(8), Status::QueueFull);
    // Error details ride along for the humans.
    assert!(got.get(&5).map(|r| r.detail.contains("quota")).unwrap_or(false));

    let got_b = collect(&mut b, &[100, 101]);
    assert_eq!(got_b.get(&100).map(|r| r.status), Some(Status::Ok));
    assert_eq!(got_b.get(&101).map(|r| r.status), Some(Status::TenantQuotaExceeded));

    let (accepted, rejected, shed) = server.frontend_totals();
    assert_eq!(accepted, 5, "plug + tenant + quoted + deadline + filler");
    assert_eq!(rejected, 6, "width, unknown, tenant, quota, queue-full, bad frame");
    assert_eq!(shed, 0);
    fe.shutdown();
    server.shutdown();
}

#[test]
fn responses_complete_out_of_order_on_one_connection() {
    let (server, gate_tx, log) = gated_server(ServerConfig {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..ServerConfig::default()
    });
    let fe = Frontend::start(server.clone(), FrontendConfig::default()).expect("frontend start");

    // Pin the worker from a separate connection so the test connection's
    // two requests are both queued before anything pops.
    let mut plug = FrontendClient::connect(fe.local_addr()).expect("connect plug");
    plug.send(&request(1, Priority::Normal, vec![0.0])).expect("send plug");
    wait_until("worker inside forward", || !lock_recover(&log).is_empty());

    let mut client = FrontendClient::connect(fe.local_addr()).expect("connect");
    client.send(&request(10, Priority::Low, vec![1.0])).expect("send low");
    client.send(&request(11, Priority::High, vec![2.0])).expect("send high");
    wait_until("both queued", || server.frontend_totals().0 == 3);
    drop(gate_tx);

    // The High request was sent second but pops first: its response must
    // arrive on the wire before the Low one — same connection, reordered.
    let first = client.recv().expect("first response");
    assert_eq!((first.req_id, first.status), (11, Status::Ok));
    assert_eq!(first.payload, vec![2.0]);
    let second = client.recv().expect("second response");
    assert_eq!((second.req_id, second.status), (10, Status::Ok));
    assert_eq!(second.payload, vec![1.0]);

    assert_eq!(collect(&mut plug, &[1]).get(&1).map(|r| r.status), Some(Status::Ok));
    fe.shutdown();
    server.shutdown();
}

#[test]
fn shutdown_drains_open_connections() {
    let server = echo_server(2);
    let fe = Frontend::start(server.clone(), FrontendConfig::default()).expect("frontend start");
    let mut client = FrontendClient::connect(fe.local_addr()).expect("connect");
    let payloads: Vec<Vec<f32>> =
        (0..8).map(|r| (0..IN_DIM).map(|i| (r * IN_DIM + i) as f32).collect()).collect();
    for (r, p) in payloads.iter().enumerate() {
        client.send(&request(r as u64 + 1, Priority::Normal, p.clone())).expect("send");
    }
    // Shut down with all eight in flight and the connection wide open:
    // the drain must answer every admitted request and flush it out
    // before the reactor exits.
    wait_until("all admitted", || server.frontend_totals().0 == 8);
    fe.shutdown();
    let got = collect(&mut client, &[1, 2, 3, 4, 5, 6, 7, 8]);
    for (r, p) in payloads.iter().enumerate() {
        let resp = got.get(&(r as u64 + 1)).expect("drained response");
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(&resp.payload, p, "drained response carries the right logits");
    }
    // The reactor is gone; the socket is closed, not wedged.
    assert!(client.recv().is_err(), "connection closes after the drain");
    server.shutdown();
}
