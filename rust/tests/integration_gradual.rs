//! Integration tests for gradual structure induction through
//! `NativeTrainer`: the full mutable-structure lifecycle (nested mask
//! chain → structure hash → plan generation → eviction), the determinism
//! regression, and train→serve conformance for mid-schedule checkpoints.
//!
//! These run on the default (native) build — no artifacts, no `xla`.

use rbgp::coordinator::{MilestoneRecord, NativeTrainer, ServerConfig};
use rbgp::kernels::SparseMatrix;
use rbgp::train_native::{is_nested, GradualSchedule, NativeTrainConfig};

const IN_DIM: usize = 64;
const HIDDEN: usize = 64;
const CLASSES: usize = 4;
const SPARSITY: f64 = 0.75;

fn train_config(steps: usize, seed: u64) -> NativeTrainConfig {
    NativeTrainConfig {
        steps,
        batch: 16,
        lr: 0.05,
        seed,
        ..NativeTrainConfig::default()
    }
}

/// Deterministic probe sample `i` (independent of the trainer's data RNG).
fn sample(i: usize) -> Vec<f32> {
    (0..IN_DIM)
        .map(|j| (((i * 13 + j * 31) % 23) as f32 - 11.0) / 11.0)
        .collect()
}

#[test]
fn gradual_run_reaches_exact_final_structure_with_zero_stale_plans() {
    let schedule = GradualSchedule::from_fractions(vec![0.3, 0.6]).unwrap();
    let mut t = NativeTrainer::new_gradual(
        IN_DIM,
        HIDDEN,
        CLASSES,
        SPARSITY,
        &schedule,
        train_config(80, 7),
    )
    .unwrap()
    .with_threads(1);
    let initial_hash = t.structure_hash();

    let report = t.run_gradual().unwrap();

    // The mask chain is nested (every mask a superset of its successor)
    // and one milestone fired per schedule fraction, each with finite loss.
    let chain = t.gradual_chain().unwrap();
    assert_eq!(chain.len(), schedule.milestones());
    assert!(is_nested(chain), "mask chain must be nested");
    assert_eq!(report.milestones.len(), schedule.milestones());
    for r in &report.milestones {
        assert!(r.loss.is_finite(), "milestone {} loss not finite", r.milestone);
        assert!(r.plan_rebuild_s >= 0.0);
    }
    assert!(
        report.milestones[0].sparsity < report.milestones[1].sparsity,
        "sparsity must tighten across milestones"
    );

    // The final mask is an *exact* RBGP4 mask: equal to the sampled target,
    // biregular (every row carries exactly row_nnz non-zeros), at the
    // config's block sparsity.
    let final_mask = t.gradual_final_mask().unwrap().clone();
    let cfg = final_mask.config;
    assert_eq!(t.mlp.mask, final_mask.dense(), "final mask is the RBGP4 target");
    for u in 0..HIDDEN {
        let nnz = t.mlp.mask[u * IN_DIM..(u + 1) * IN_DIM]
            .iter()
            .filter(|&&v| v != 0.0)
            .count();
        assert_eq!(nnz, cfg.row_nnz(), "row {u} must be biregular");
    }
    assert!(
        (t.mlp.mask_sparsity() - cfg.sparsity()).abs() < 1e-9,
        "final sparsity {} != config {}",
        t.mlp.mask_sparsity(),
        cfg.sparsity()
    );

    // Cache end state: plans exist only for the final hidden-layer
    // structure plus the (shape-stable) dense classifier — nothing from
    // dead milestones survives.
    let w2_hash =
        SparseMatrix::dense(vec![0.0; CLASSES * HIDDEN], CLASSES, HIDDEN).structure_hash();
    let mut expected = vec![t.structure_hash(), w2_hash];
    expected.sort_unstable();
    assert_eq!(t.cache().structures(), expected, "only live structures cached");

    // Eviction counters match the milestone count exactly: one re-key per
    // milestone, each evicting the outgoing structure's plans.
    let (invalidations, evicted) = t.cache().eviction_stats();
    assert_eq!(invalidations, report.milestones.len(), "one re-key per milestone");
    assert_eq!(
        evicted,
        report.milestones.iter().map(|r| r.evicted_plans).sum::<usize>(),
        "eviction counter equals the per-milestone sum"
    );
    assert!(
        report.milestones.iter().all(|r| r.evicted_plans >= 1),
        "every re-key had warmed plans to evict"
    );

    // Every dead structure hash is distinct and retains zero plans.
    let m0 = report.milestones[0].structure_hash;
    let m1 = report.milestones[1].structure_hash;
    assert_ne!(initial_hash, m0, "hash must change at milestone 0");
    assert_ne!(m0, m1, "hash must change at milestone 1");
    assert_eq!(t.cache().structure_plan_count(initial_hash), 0, "stale start plans");
    assert_eq!(t.cache().structure_plan_count(m0), 0, "stale milestone-0 plans");
    assert!(t.cache().structure_plan_count(m1) >= 1, "final structure stays warm");
}

#[test]
fn mid_schedule_checkpoint_serves_the_current_structure() {
    let schedule = GradualSchedule::from_fractions(vec![0.4, 0.8]).unwrap();
    let mut t = NativeTrainer::new_gradual(
        IN_DIM,
        HIDDEN,
        CLASSES,
        SPARSITY,
        &schedule,
        train_config(50, 3),
    )
    .unwrap()
    .with_threads(1);

    // Train until the first milestone fires, then stop mid-schedule.
    let mut fired: Option<MilestoneRecord> = None;
    for s in 0..t.config.steps {
        let (_, records) = t.step_gradual(s).unwrap();
        if let Some(r) = records.into_iter().next() {
            fired = Some(r);
            break;
        }
    }
    let record = fired.expect("first milestone fires mid-run");
    assert_eq!(t.gradual_milestones_applied(), Some(1), "paused mid-schedule");
    assert_eq!(
        t.structure_hash(),
        record.structure_hash,
        "checkpoint is at the milestone's structure"
    );

    // Trainer-side logits through the evaluate/serving path (single shot).
    let batch = t.config.batch;
    let xs: Vec<Vec<f32>> = (0..batch).map(sample).collect();
    let xb: Vec<f32> = xs.iter().flatten().copied().collect();
    let mut model = t.serving_model(batch, 1).unwrap();
    let want = model.forward(&xb).unwrap();

    // Serving the checkpoint through the worker pool resolves the *current*
    // structure's plans from the trainer's cache — zero new builds.
    let (hits_before, misses_before) = t.cache().stats();
    let server = t
        .serve(
            batch,
            1,
            ServerConfig {
                workers: 2,
                ..ServerConfig::default()
            },
        )
        .unwrap();
    let (hits_after, misses_after) = t.cache().stats();
    assert_eq!(
        misses_after, misses_before,
        "mid-schedule serving must not rebuild structure"
    );
    assert_eq!(
        hits_after,
        hits_before + 4,
        "both workers warm both layer plans from cache"
    );

    // Train→serve conformance: pool logits equal the single-shot forward
    // bit-for-bit (same plans, same kernels, columns are independent).
    for (i, x) in xs.iter().enumerate() {
        let got = server.infer(x.clone()).unwrap();
        assert_eq!(
            got.as_slice(),
            &want[i * CLASSES..(i + 1) * CLASSES],
            "sample {i}: served logits must equal trainer-side logits"
        );
    }
    server.shutdown();
}

#[allow(clippy::type_complexity)]
fn gradual_run_once(seed: u64) -> (Vec<u32>, rbgp::coordinator::GradualReport, u64, Vec<f32>) {
    let schedule = GradualSchedule::from_fractions(vec![0.25, 0.5, 0.75]).unwrap();
    let mut t = NativeTrainer::new_gradual(
        IN_DIM,
        HIDDEN,
        CLASSES,
        SPARSITY,
        &schedule,
        train_config(60, seed),
    )
    .unwrap()
    .with_threads(2);
    let report = t.run_gradual().unwrap();
    let bits = t.mlp.flat_params().iter().map(|v| v.to_bits()).collect();
    let hash = t.structure_hash();
    // Logits of a fixed probe batch through the serving path.
    let batch = t.config.batch;
    let xb: Vec<f32> = (0..batch).flat_map(sample).collect();
    let logits = t.serving_model(batch, 2).unwrap().forward(&xb).unwrap();
    (bits, report, hash, logits)
}

#[test]
fn gradual_runs_are_deterministic_and_conformant() {
    let (bits_a, report_a, hash_a, logits_a) = gradual_run_once(42);
    let (bits_b, report_b, hash_b, logits_b) = gradual_run_once(42);

    // Bit-identical final weights and identical milestone traces.
    assert_eq!(bits_a, bits_b, "final weights must be bit-identical");
    assert_eq!(hash_a, hash_b, "final structure hash must agree");
    assert_eq!(report_a.milestones.len(), report_b.milestones.len());
    for (a, b) in report_a.milestones.iter().zip(&report_b.milestones) {
        assert_eq!(a.milestone, b.milestone);
        assert_eq!(a.step, b.step, "milestones must fire at the same steps");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss trace must match");
        assert_eq!(a.sparsity.to_bits(), b.sparsity.to_bits());
        assert_eq!(a.structure_hash, b.structure_hash);
        assert_eq!(a.evicted_plans, b.evicted_plans);
    }
    assert_eq!(report_a.final_loss.to_bits(), report_b.final_loss.to_bits());
    assert_eq!(report_a.accuracy.to_bits(), report_b.accuracy.to_bits());
    // Serving logits are part of the contract too.
    assert_eq!(
        logits_a.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
        logits_b.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
        "serving logits must be bit-identical across runs"
    );

    // The witness is meaningful: a different seed changes the weights.
    let (bits_c, _, _, _) = gradual_run_once(43);
    assert_ne!(bits_a, bits_c, "different seeds must differ");
}
