//! Property suite for the serving queue's dual-view core
//! (`coordinator::serving::queue`): under random interleavings of
//! submit / pop / model-filtered pop (with age promotion and expired
//! deadlines mixed in), the queue must
//!
//! * pop in exactly the order a brute-force oracle over the same entries
//!   predicts — the per-model index and the primary FIFOs are two views
//!   of one set, never two sets;
//! * keep **exact conservation**: every accepted submit is answered
//!   exactly once — served, deadline-rejected, or failed at close —
//!   and every rejected submit is answered zero times;
//! * enforce admission quotas exactly: a model's queued count never
//!   exceeds its quota, never goes negative (the count is audited against
//!   the live entries by `check_invariants`), and quota rejections are
//!   predicted exactly by the oracle in the sequential tests and bounded
//!   observably under 1/4/8-thread races in the concurrent ones.
//!
//! All cases are generated from the seeded in-house harness
//! (`util::prop::check`, replayable via `RBGP_PROP_SEED`); the concurrent
//! tests assert only interleaving-independent invariants, so they are
//! deterministic pass/fail under any scheduler.

use rbgp::coordinator::serving::queue::{Priority, QueuedRequest, RequestQueue};
use rbgp::coordinator::serving::registry::ModelClaim;
use rbgp::coordinator::ServeError;
use rbgp::util::prop::{check, gen};
use rbgp::util::rng::Rng;
use rbgp::{prop_assert, prop_assert_eq};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

type Rx = mpsc::Receiver<Result<Vec<f32>, ServeError>>;

const MODELS: [&str; 3] = ["a", "b", "c"];

/// Age-promotion period for the oracle tests. Ages are manufactured by
/// backdating `enqueued`, so the period only needs to dwarf a single
/// case's wall time (milliseconds) for `floor(waited / period)` to stay
/// exactly the manufactured age.
const PERIOD: Duration = Duration::from_secs(20);

fn priority_of(class: usize) -> Priority {
    match class {
        0 => Priority::High,
        1 => Priority::Normal,
        _ => Priority::Low,
    }
}

/// The reference model of one queued entry.
struct OracleEntry {
    seq: u64,
    class: usize,
    model: usize,
    id: u32,
    age: usize,
}

/// Brute-force reference pop: the earliest entry per class (restricted to
/// `model` if given), ranked by `(class - age, seq)` — exactly the
/// contract `RequestQueue::take_next` implements through its dual views.
fn oracle_pop(
    entries: &mut Vec<OracleEntry>,
    model: Option<usize>,
    promote: bool,
) -> Option<OracleEntry> {
    let mut best: Option<(usize, u64)> = None;
    let mut best_idx: Option<usize> = None;
    for class in 0..3 {
        let cand = entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.class == class && model.is_none_or(|m| e.model == m))
            .min_by_key(|(_, e)| e.seq);
        if let Some((idx, e)) = cand {
            let eff = if promote { class.saturating_sub(e.age) } else { class };
            if best.is_none_or(|b| (eff, e.seq) < b) {
                best = Some((eff, e.seq));
                best_idx = Some(idx);
            }
        }
    }
    best_idx.map(|i| entries.remove(i))
}

/// Build a request for `model`, backdated by `age` promotion periods
/// (clamped to 0 when the monotonic clock is too young to backdate — a
/// freshly booted VM) and optionally carrying an already-expired deadline.
fn make_req(model: &str, id: u32, age: &mut usize, expired: bool) -> (QueuedRequest, Rx) {
    let now = Instant::now();
    let enqueued = match now.checked_sub(PERIOD * *age as u32) {
        Some(t) => t,
        None => {
            *age = 0;
            now
        }
    };
    let (tx, rx) = mpsc::channel();
    (
        QueuedRequest {
            x: vec![id as f32],
            enqueued,
            deadline: expired.then_some(now),
            respond: tx,
            claim: ModelClaim::detached(model, 1, 1, 1),
            route: None,
        },
        rx,
    )
}

/// Answer a popped request the way a worker would (expired deadlines get
/// the typed error) and check it against the oracle's prediction.
fn compare(
    got: Option<QueuedRequest>,
    want: Option<OracleEntry>,
    popped: &mut HashSet<u32>,
) -> Result<(), String> {
    match (got, want) {
        (None, None) => Ok(()),
        (Some(r), Some(w)) => {
            prop_assert_eq!(r.x[0] as u32, w.id, "pop order diverged from the oracle");
            popped.insert(w.id);
            if r.deadline.is_some_and(|dl| Instant::now() >= dl) {
                let _ = r.respond.send(Err(ServeError::DeadlineExceeded {
                    waited: r.enqueued.elapsed(),
                }));
            } else {
                let _ = r.respond.send(Ok(r.x.clone()));
            }
            Ok(())
        }
        (got, want) => Err(format!(
            "queue and oracle disagree on emptiness: queue {:?}, oracle {:?}",
            got.map(|r| r.x[0]),
            want.map(|w| w.id)
        )),
    }
}

/// One randomized interleaving checked against the oracle, op by op.
fn run_oracle_case(rng: &mut Rng, promote: bool) -> Result<(), String> {
    let cap = gen::range(rng, 4, 10);
    let quota = gen::range(rng, 2, 4);
    let q = RequestQueue::new(cap, promote.then_some(PERIOD));
    let mut oracle: Vec<OracleEntry> = Vec::new();
    let mut receivers: Vec<(u32, bool, Rx)> = Vec::new();
    let mut popped: HashSet<u32> = HashSet::new();
    let mut next_id = 0u32;
    let mut next_seq = 0u64;

    let ops = gen::range(rng, 40, 80);
    for op in 0..ops {
        let dice = rng.below(100);
        if dice < 55 {
            // Submit: the oracle predicts accept / quota-reject /
            // full-reject exactly.
            let model = rng.below_usize(MODELS.len());
            let class = rng.below_usize(3);
            let mut age = if promote { rng.below_usize(3) } else { 0 };
            let expired = rng.below(10) == 0;
            let (req, rx) = make_req(MODELS[model], next_id, &mut age, expired);
            let res = q.push(req, priority_of(class), Some(quota));
            let model_queued = oracle.iter().filter(|e| e.model == model).count();
            if model_queued >= quota {
                prop_assert!(
                    matches!(res, Err(ServeError::ModelQuotaExceeded { .. })),
                    "expected ModelQuotaExceeded at {model_queued}/{quota} queued, got {:?}",
                    res.as_ref().map(|_| ())
                );
            } else if oracle.len() >= cap {
                prop_assert!(
                    matches!(res, Err(ServeError::QueueFull { .. })),
                    "expected QueueFull at depth {}/{cap}, got {:?}",
                    oracle.len(),
                    res.as_ref().map(|_| ())
                );
            } else {
                prop_assert!(
                    res.is_ok(),
                    "expected accept ({model_queued}/{quota} queued, depth {}/{cap}), got {:?}",
                    oracle.len(),
                    res.as_ref().map(|_| ())
                );
                oracle.push(OracleEntry {
                    seq: next_seq,
                    class,
                    model,
                    id: next_id,
                    age,
                });
                next_seq += 1;
                receivers.push((next_id, expired, rx));
            }
            next_id += 1;
        } else if dice < 80 {
            let got = q.pop_until(Instant::now());
            let want = oracle_pop(&mut oracle, None, promote);
            compare(got, want, &mut popped)?;
        } else {
            let m = rng.below_usize(MODELS.len());
            let got = q.pop_model_until(MODELS[m], Instant::now());
            let want = oracle_pop(&mut oracle, Some(m), promote);
            compare(got, want, &mut popped)?;
        }
        if op % 8 == 0 {
            q.check_invariants();
        }
    }

    // Bijection: depth and per-model backlogs agree with the oracle.
    prop_assert_eq!(q.len(), oracle.len(), "queue depth diverged from the oracle");
    for (mi, m) in MODELS.iter().enumerate() {
        prop_assert_eq!(
            q.model_backlog(m),
            oracle.iter().filter(|e| e.model == mi).count(),
            "model '{m}' backlog diverged from the oracle"
        );
    }
    q.check_invariants();

    // Conservation: fail the remainder at close; every accepted submit
    // was answered exactly once with the outcome its history dictates.
    q.close_and_fail_pending();
    for (id, expired, rx) in receivers {
        let first = rx
            .try_recv()
            .map_err(|e| format!("request {id} was never answered: {e}"))?;
        match (popped.contains(&id), expired, first) {
            (true, false, Ok(x)) => {
                prop_assert_eq!(x[0] as u32, id, "answer routed to the wrong receiver");
            }
            (true, true, Err(ServeError::DeadlineExceeded { .. })) => {}
            (false, _, Err(ServeError::Stopped)) => {}
            (was_popped, was_expired, other) => {
                return Err(format!(
                    "request {id}: unexpected outcome {other:?} \
                     (popped={was_popped}, expired={was_expired})"
                ));
            }
        }
        prop_assert!(rx.try_recv().is_err(), "request {id} was answered twice");
    }
    Ok(())
}

#[test]
fn prop_pop_order_matches_oracle_strict_priority() {
    check("queue == oracle, strict priority + quotas", 25, |rng| {
        run_oracle_case(rng, false)
    });
}

#[test]
fn prop_pop_order_matches_oracle_with_age_promotion() {
    check("queue == oracle, age promotion + quotas", 25, |rng| {
        run_oracle_case(rng, true)
    });
}

/// Concurrent half of the suite: producers and a mixed popper fleet
/// (global + model-filtered) race on one queue while a sampler thread
/// continuously observes the quota and capacity bounds. Asserts only
/// interleaving-independent facts: bounds always hold, the drained queue
/// is empty and internally consistent, and conservation is exact.
fn run_concurrent_case(popper_threads: usize, base_seed: u64) {
    const QUOTA: usize = 5;
    const CAP: usize = 12;
    const PRODUCERS: usize = 2;
    const PUSHES_PER_PRODUCER: usize = 400;

    let q = Arc::new(RequestQueue::new(CAP, Some(Duration::from_millis(10))));
    let answered = Arc::new(AtomicUsize::new(0));
    let stop_sampler = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        let mut popper_handles = Vec::new();
        for t in 0..popper_threads {
            let q = Arc::clone(&q);
            let answered = Arc::clone(&answered);
            popper_handles.push(scope.spawn(move || {
                if t % 2 == 0 {
                    // Global popper: drains everything, exits on
                    // closed-and-drained.
                    while let Some(r) = q.pop_blocking() {
                        let _ = r.respond.send(Ok(r.x.clone()));
                        answered.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    // Model-filtered popper: exercises the per-model index
                    // under contention.
                    let model = MODELS[t % MODELS.len()];
                    loop {
                        let until = Instant::now() + Duration::from_millis(2);
                        match q.pop_model_until(model, until) {
                            Some(r) => {
                                let _ = r.respond.send(Ok(r.x.clone()));
                                answered.fetch_add(1, Ordering::Relaxed);
                            }
                            None => {
                                if q.is_closed() && q.model_backlog(model) == 0 {
                                    break;
                                }
                            }
                        }
                    }
                }
            }));
        }

        let sampler = {
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop_sampler);
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    for m in MODELS {
                        let backlog = q.model_backlog(m);
                        assert!(
                            backlog <= QUOTA,
                            "model '{m}' backlog {backlog} exceeded quota {QUOTA} mid-race"
                        );
                    }
                    assert!(q.len() <= CAP, "queue depth exceeded its capacity mid-race");
                    std::thread::yield_now();
                }
            })
        };

        let mut producer_handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = Arc::clone(&q);
            producer_handles.push(scope.spawn(move || {
                let mut rng = Rng::new(base_seed + p as u64);
                let mut accepted: Vec<(f32, Rx)> = Vec::new();
                let mut rejected = 0usize;
                for i in 0..PUSHES_PER_PRODUCER {
                    let id = (p * PUSHES_PER_PRODUCER + i) as f32;
                    let model = MODELS[rng.below_usize(MODELS.len())];
                    let class = priority_of(rng.below_usize(3));
                    let (tx, rx) = mpsc::channel();
                    let req = QueuedRequest {
                        x: vec![id],
                        enqueued: Instant::now(),
                        deadline: None,
                        respond: tx,
                        claim: ModelClaim::detached(model, 1, 1, 1),
                        route: None,
                    };
                    match q.push(req, class, Some(QUOTA)) {
                        Ok(depth) => {
                            assert!(depth <= CAP, "push reported a depth past capacity");
                            accepted.push((id, rx));
                        }
                        Err(ServeError::ModelQuotaExceeded { model: m, quota }) => {
                            assert_eq!((m.as_str(), quota), (model, QUOTA));
                            rejected += 1;
                        }
                        Err(ServeError::QueueFull { cap }) => {
                            assert_eq!(cap, CAP);
                            rejected += 1;
                        }
                        Err(e) => panic!("unexpected push error: {e:?}"),
                    }
                    if rng.below(4) == 0 {
                        std::thread::yield_now();
                    }
                }
                (accepted, rejected)
            }));
        }

        let mut all_accepted: Vec<(f32, Rx)> = Vec::new();
        let mut total_rejected = 0usize;
        for h in producer_handles {
            let (accepted, rejected) = h.join().unwrap();
            all_accepted.extend(accepted);
            total_rejected += rejected;
        }
        q.close();
        for h in popper_handles {
            h.join().unwrap();
        }
        stop_sampler.store(true, Ordering::Release);
        sampler.join().unwrap();

        q.check_invariants();
        assert_eq!(q.len(), 0, "closed queue must drain to empty");
        assert!(q.model_backlogs().is_empty(), "no model may retain backlog");
        assert_eq!(
            all_accepted.len() + total_rejected,
            PRODUCERS * PUSHES_PER_PRODUCER,
            "every push accounted for exactly once"
        );
        assert_eq!(
            answered.load(Ordering::Relaxed),
            all_accepted.len(),
            "every accepted entry popped exactly once"
        );
        for (id, rx) in &all_accepted {
            match rx.try_recv() {
                Ok(Ok(x)) => assert_eq!(x[0], *id, "answer routed to the wrong receiver"),
                other => panic!("request {id} lost or failed: {other:?}"),
            }
            assert!(rx.try_recv().is_err(), "request {id} answered twice");
        }
    });
}

/// Rollout satellite: submits resolved through **two aliases onto one
/// concrete model** must behave exactly like direct submits to that model
/// — the quota and the per-model backlog are charged to the concrete id
/// (never an alias name), the shared in-flight count stays exact through
/// every accept/pop/answer, and conservation holds at close. Alias claims
/// are modeled the way registry resolution produces them: duplicate
/// claims on one concrete entry.
#[test]
fn prop_alias_resolved_submits_charge_the_concrete_model() {
    use rbgp::coordinator::serving::queue::RouteTag;
    const ALIASES: [&str; 2] = ["blue", "green"];
    check("two aliases, one concrete model", 25, |rng| {
        let quota = gen::range(rng, 2, 5);
        let cap = quota + gen::range(rng, 2, 6); // quota binds before capacity
        let q = RequestQueue::new(cap, None);
        let base = ModelClaim::detached("m", 1, 1, 1);
        let baseline = base.in_flight();
        let mut receivers: Vec<Rx> = Vec::new();
        let mut popped: Vec<QueuedRequest> = Vec::new();
        let mut queued = 0usize;
        let mut next_id = 0u32;
        let ops = gen::range(rng, 30, 60);
        for _ in 0..ops {
            if rng.below(100) < 60 {
                let alias = ALIASES[rng.below_usize(ALIASES.len())];
                let (tx, rx) = mpsc::channel();
                let req = QueuedRequest {
                    x: vec![next_id as f32],
                    enqueued: Instant::now(),
                    deadline: None,
                    respond: tx,
                    claim: base.duplicate(),
                    route: Some(RouteTag::Alias {
                        alias: alias.to_string(),
                        canary: false,
                        shadow: None,
                    }),
                };
                next_id += 1;
                match q.push(req, Priority::Normal, Some(quota)) {
                    Ok(_) => {
                        queued += 1;
                        receivers.push(rx);
                        prop_assert!(queued <= quota, "accepted past the shared quota");
                    }
                    Err(ServeError::ModelQuotaExceeded { model, quota: got }) => {
                        prop_assert_eq!(
                            model.as_str(),
                            "m",
                            "quota rejection must name the concrete model, not '{alias}'"
                        );
                        prop_assert_eq!(got, quota, "wrong quota reported");
                        prop_assert_eq!(
                            queued,
                            quota,
                            "rejected below the cap: aliases must pool one quota"
                        );
                    }
                    Err(e) => return Err(format!("unexpected push error: {e:?}")),
                }
                prop_assert_eq!(
                    q.model_backlog("m"),
                    queued,
                    "backlog must be charged to the concrete model"
                );
                prop_assert_eq!(
                    q.model_backlog("blue") + q.model_backlog("green"),
                    0,
                    "alias names must never appear as queue models"
                );
            } else if let Some(r) = q.pop_until(Instant::now()) {
                queued -= 1;
                popped.push(r);
            }
            prop_assert_eq!(
                base.in_flight(),
                baseline + queued + popped.len(),
                "shared in-flight accounting drifted"
            );
        }
        // Answer what was popped, fail the rest at close: conservation.
        for r in popped.drain(..) {
            let _ = r.respond.send(Ok(r.x.clone()));
        }
        q.close_and_fail_pending();
        prop_assert_eq!(
            base.in_flight(),
            baseline,
            "every aliased claim must return to the concrete entry"
        );
        let total = receivers.len();
        let mut answered = 0usize;
        let mut failed = 0usize;
        for rx in receivers {
            match rx.try_recv().map_err(|e| format!("request lost: {e}"))? {
                Ok(_) => answered += 1,
                Err(ServeError::Stopped) => failed += 1,
                other => return Err(format!("unexpected outcome: {other:?}")),
            }
            prop_assert!(rx.try_recv().is_err(), "a request was answered twice");
        }
        prop_assert_eq!(answered + failed, total, "conservation across aliases");
        Ok(())
    });
}

/// Quota re-resolution satellite (sequential oracle): the registry
/// re-resolves fair-share limits whenever membership changes, which the
/// queue sees as a *different* `Some(limit)` on later pushes. Admission
/// must track exactly the limit in force at each push: backlog already
/// queued above a shrunken limit is grandfathered (accepted entries are
/// never evicted), but new pushes reject until pops bring the backlog
/// under the new limit — and a later widening admits again.
#[test]
fn prop_quota_reresolution_tracks_the_limit_at_push_time() {
    check("quota re-resolution, sequential", 25, |rng| {
        let lo = gen::range(rng, 1, 4);
        let hi = lo + gen::range(rng, 1, 4);
        let cap = hi + gen::range(rng, 2, 6); // quota binds before capacity
        let q = RequestQueue::new(cap, None);
        let mut receivers: Vec<Rx> = Vec::new();
        let mut id = 0u32;
        let mut submit = |q: &RequestQueue, limit: usize| {
            let mut age = 0;
            let (req, rx) = make_req("a", id, &mut age, false);
            id += 1;
            (q.push(req, Priority::Normal, Some(limit)), rx)
        };

        // Fill to the wide limit, then the wide limit itself rejects.
        for _ in 0..hi {
            let (res, rx) = submit(&q, hi);
            prop_assert!(res.is_ok(), "push below the wide limit must be accepted");
            receivers.push(rx);
        }
        let (res, _) = submit(&q, hi);
        prop_assert!(
            matches!(res, Err(ServeError::ModelQuotaExceeded { quota, .. }) if quota == hi),
            "push at the wide limit must reject with that limit"
        );

        // Membership grows → the share shrinks to `lo`. The backlog of
        // `hi` is grandfathered but every new push sees the narrow limit.
        let (res, _) = submit(&q, lo);
        prop_assert!(
            matches!(res, Err(ServeError::ModelQuotaExceeded { quota, .. }) if quota == lo),
            "a shrunken limit must reject immediately (backlog {hi} > {lo})"
        );

        // Pop below the narrow limit: exactly one slot opens.
        for _ in 0..(hi - lo + 1) {
            let r = q.pop_until(Instant::now()).ok_or("queue drained early")?;
            let _ = r.respond.send(Ok(r.x.clone()));
        }
        prop_assert_eq!(q.model_backlog("a"), lo - 1, "backlog after the draw-down");
        let (res, rx) = submit(&q, lo);
        prop_assert!(res.is_ok(), "one slot under the narrow limit must admit");
        receivers.push(rx);
        let (res, _) = submit(&q, lo);
        prop_assert!(
            matches!(res, Err(ServeError::ModelQuotaExceeded { quota, .. }) if quota == lo),
            "the narrow limit must bind again at {lo} queued"
        );

        // Membership shrinks back → the share widens: admits up to `hi`.
        for _ in 0..(hi - lo) {
            let (res, rx) = submit(&q, hi);
            prop_assert!(res.is_ok(), "re-widened limit must admit back up to {hi}");
            receivers.push(rx);
        }
        let (res, _) = submit(&q, hi);
        prop_assert!(
            matches!(res, Err(ServeError::ModelQuotaExceeded { quota, .. }) if quota == hi),
            "re-widened limit must still bind at {hi}"
        );
        prop_assert_eq!(q.model_backlog("a"), hi, "final backlog");

        // Conservation across the whole shrink/grow history: the popped
        // draw-down was answered Ok, everything still queued fails at
        // close, rejected pushes are answered zero times (their channels
        // just disconnect).
        q.close_and_fail_pending();
        let (mut served, mut failed) = (0usize, 0usize);
        let total = receivers.len();
        for rx in receivers {
            match rx.try_recv().map_err(|e| format!("request lost: {e}"))? {
                Ok(_) => served += 1,
                Err(ServeError::Stopped) => failed += 1,
                other => return Err(format!("unexpected outcome: {other:?}")),
            }
            prop_assert!(rx.try_recv().is_err(), "a request was answered twice");
        }
        prop_assert_eq!(served, hi - lo + 1, "exactly the draw-down was served");
        prop_assert_eq!(served + failed, total, "conservation across re-resolution");
        Ok(())
    });
}

/// Quota re-resolution satellite (concurrent): a membership thread keeps
/// re-resolving the limit (wide ⇄ narrow) while producers push with
/// whatever limit is in force at their submit — the race the registry's
/// under-lock re-resolution closes at the serving layer. The queue's own
/// guarantees must hold under any interleaving: the model backlog never
/// exceeds the widest limit ever in force, every quota rejection names a
/// limit that was genuinely live, and conservation stays exact.
#[test]
fn prop_concurrent_quota_reresolution_bounds_backlog() {
    const LO: usize = 2;
    const HI: usize = 6;
    const CAP: usize = 16; // > HI: quota, not capacity, is the binding bound
    const PRODUCERS: usize = 2;
    const PUSHES_PER_PRODUCER: usize = 300;

    let q = Arc::new(RequestQueue::new(CAP, None));
    let limit = Arc::new(AtomicUsize::new(HI));
    let answered = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        let popper = {
            let q = Arc::clone(&q);
            let answered = Arc::clone(&answered);
            scope.spawn(move || {
                while let Some(r) = q.pop_blocking() {
                    let _ = r.respond.send(Ok(r.x.clone()));
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            })
        };
        // Membership churn: flip the resolved limit as fast as possible.
        let flipper = {
            let limit = Arc::clone(&limit);
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                let mut wide = false;
                while !stop.load(Ordering::Acquire) {
                    limit.store(if wide { HI } else { LO }, Ordering::Relaxed);
                    wide = !wide;
                    std::thread::yield_now();
                }
            })
        };
        let sampler = {
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let backlog = q.model_backlog("a");
                    assert!(
                        backlog <= HI,
                        "backlog {backlog} exceeded the widest limit {HI} mid-race"
                    );
                    std::thread::yield_now();
                }
            })
        };

        let mut producer_handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = Arc::clone(&q);
            let limit = Arc::clone(&limit);
            producer_handles.push(scope.spawn(move || {
                let mut rng = Rng::new(0xFA15_BACC + p as u64);
                let mut accepted: Vec<(f32, Rx)> = Vec::new();
                for i in 0..PUSHES_PER_PRODUCER {
                    let id = (p * PUSHES_PER_PRODUCER + i) as f32;
                    let (tx, rx) = mpsc::channel();
                    let req = QueuedRequest {
                        x: vec![id],
                        enqueued: Instant::now(),
                        deadline: None,
                        respond: tx,
                        claim: ModelClaim::detached("a", 1, 1, 1),
                        route: None,
                    };
                    // Read the limit the way a submit path would: whatever
                    // the latest re-resolution published.
                    let live = limit.load(Ordering::Relaxed);
                    match q.push(req, priority_of(rng.below_usize(3)), Some(live)) {
                        Ok(_) => accepted.push((id, rx)),
                        Err(ServeError::ModelQuotaExceeded { quota, .. }) => {
                            assert_eq!(quota, live, "rejection must cite the limit it enforced");
                        }
                        Err(e) => panic!("unexpected push error: {e:?}"),
                    }
                    if rng.below(4) == 0 {
                        std::thread::yield_now();
                    }
                }
                accepted
            }));
        }

        let mut all_accepted: Vec<(f32, Rx)> = Vec::new();
        for h in producer_handles {
            all_accepted.extend(h.join().unwrap());
        }
        q.close();
        popper.join().unwrap();
        stop.store(true, Ordering::Release);
        flipper.join().unwrap();
        sampler.join().unwrap();

        q.check_invariants();
        assert_eq!(q.len(), 0, "closed queue must drain to empty");
        assert_eq!(
            answered.load(Ordering::Relaxed),
            all_accepted.len(),
            "every accepted entry popped exactly once"
        );
        for (id, rx) in &all_accepted {
            match rx.try_recv() {
                Ok(Ok(x)) => assert_eq!(x[0], *id, "answer routed to the wrong receiver"),
                other => panic!("request {id} lost or failed: {other:?}"),
            }
            assert!(rx.try_recv().is_err(), "request {id} answered twice");
        }
    });
}

#[test]
fn prop_concurrent_conservation_and_quota_1_thread() {
    run_concurrent_case(1, 0xC0FFEE01);
}

#[test]
fn prop_concurrent_conservation_and_quota_4_threads() {
    run_concurrent_case(4, 0xC0FFEE04);
}

#[test]
fn prop_concurrent_conservation_and_quota_8_threads() {
    run_concurrent_case(8, 0xC0FFEE08);
}
