//! Integration test for drift-triggered background re-tuning through the
//! public serving API: a model whose achieved/tuned throughput ratio
//! ([`BatchModel::drift`]) drops below the configured threshold must be
//! re-tuned by an *idle* worker — and the plan swap must never reject,
//! error, or lose a single in-flight request.
//!
//! The backend is a scripted model (drift and re-tune observable through
//! shared counters) so the trigger condition is deterministic instead of
//! depending on real kernel timing noise. Responses carry a plan-epoch
//! marker (+1000 per re-tune) so the swap itself is visible in served
//! logits, not just in counters.

use rbgp::coordinator::{BatchModel, InferenceServer, ServerConfig};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const IN_DIM: usize = 4;
const BATCH: usize = 2;

/// Scripted backend: reports a drifted throughput ratio once the shared
/// flag flips, until its own `retune` runs. Each worker owns one instance
/// (as with real backends), so with W workers exactly W re-tunes happen.
struct DriftingModel {
    /// Shared switch the test flips to start reporting drift.
    drifted: Arc<AtomicBool>,
    /// Pool-wide count of completed re-tunes (all instances).
    retunes: Arc<AtomicUsize>,
    /// This instance's plan generation: 0 until its re-tune swaps plans.
    epoch: usize,
}

impl BatchModel for DriftingModel {
    fn batch(&self) -> usize {
        BATCH
    }
    fn in_dim(&self) -> usize {
        IN_DIM
    }
    fn classes(&self) -> usize {
        1
    }
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        // Logit = first feature + 1000·epoch: responses served from the
        // post-swap "plan" are distinguishable from pre-swap ones.
        Ok((0..BATCH)
            .map(|j| x[j * IN_DIM] + 1000.0 * self.epoch as f32)
            .collect())
    }
    fn drift(&self) -> Option<f64> {
        if self.epoch == 0 && self.drifted.load(Ordering::Acquire) {
            Some(0.3) // below any sane threshold
        } else {
            Some(1.0) // healthy: achieved == tuned expectation
        }
    }
    fn retune(&mut self) -> anyhow::Result<()> {
        // Simulate a schedule search taking real time: requests arriving
        // meanwhile must still be served (by a non-idle peer) or queued —
        // never rejected.
        std::thread::sleep(Duration::from_millis(50));
        self.epoch += 1;
        self.retunes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

#[test]
fn drift_retune_swaps_plans_without_rejecting_traffic() {
    let workers = 2;
    let drifted = Arc::new(AtomicBool::new(false));
    let retunes = Arc::new(AtomicUsize::new(0));
    let server = {
        let drifted = Arc::clone(&drifted);
        let retunes = Arc::clone(&retunes);
        InferenceServer::start_model(
            move || {
                Ok(Box::new(DriftingModel {
                    drifted: Arc::clone(&drifted),
                    retunes: Arc::clone(&retunes),
                    epoch: 0,
                }) as Box<dyn BatchModel>)
            },
            ServerConfig {
                workers,
                max_wait: Duration::from_millis(1),
                retune_threshold: Some(0.7),
                ..ServerConfig::default()
            },
        )
        .expect("server start")
    };

    let sample = |r: usize| {
        let mut x = vec![0.0f32; IN_DIM];
        x[0] = r as f32;
        x
    };

    // Phase 1 — healthy model under traffic: the drift check must never
    // fire on a model at its tuned expectation, however long it idles.
    let warmup = 20;
    for r in 0..warmup {
        let got = server.infer(sample(r)).unwrap();
        assert_eq!(got, vec![r as f32], "healthy model serves unmarked logits");
    }
    assert_eq!(server.retunes(), 0, "no re-tune without drift");

    // Phase 2 — drift begins, traffic keeps flowing: bursts of blocking
    // requests separated by idle windows longer than the worker's idle
    // tick, so drifted instances get re-tuned *between* serving work.
    // Every response across the whole timeline must be Ok.
    drifted.store(true, Ordering::Release);
    let bursts = 4;
    let per_burst = 25;
    let mut served = Vec::new();
    for _ in 0..bursts {
        for r in 0..per_burst {
            let got = server.infer(sample(r)).unwrap();
            assert_eq!(got.len(), 1);
            served.push(got[0]);
        }
        // Idle window (> the 500 ms idle tick): workers with no request
        // in hand run the drift check and swap plans here.
        std::thread::sleep(Duration::from_millis(700));
    }

    // Every worker instance re-tuned exactly once, then reported healthy.
    assert_eq!(
        retunes.load(Ordering::Relaxed),
        workers,
        "each worker's drifted instance re-tunes once and only once"
    );
    assert_eq!(server.retunes(), workers, "server-level re-tune counter agrees");

    // The swap is visible in served logits: early responses came from
    // epoch-0 plans, later ones carry the +1000 post-swap marker.
    assert!(
        served.iter().any(|&v| v < 1000.0),
        "some traffic was served from the pre-swap plans"
    );
    assert!(
        served.iter().any(|&v| v >= 1000.0),
        "traffic after the swap is served from the fresh plans"
    );

    // The non-blocking contract: nothing was rejected, errored, or lost
    // while plans were searched and swapped.
    assert_eq!(server.rejected(), (0, 0), "no request rejected during re-tune");
    let (requests, _) = server.counters();
    assert_eq!(
        requests,
        warmup + bursts * per_burst,
        "every submitted request was served"
    );
    assert!(
        server.worker_stats().iter().all(|w| w.errors == 0),
        "no worker errored across the swap"
    );
    let ms = server.model_stats();
    assert_eq!(ms.len(), 1);
    assert_eq!(ms[0].retunes, workers, "per-model re-tune accounting");
    assert_eq!(ms[0].errors, 0);

    // With both instances swapped, steady-state traffic is all-fresh and
    // still healthy — drift reporting recovered, so no further re-tunes.
    for r in 0..10 {
        let got = server.infer(sample(r)).unwrap();
        assert_eq!(got, vec![r as f32 + 1000.0], "post-swap plans serve all traffic");
    }
    assert_eq!(server.retunes(), workers, "recovered models are left alone");
    server.shutdown();
}

/// `retune_threshold: None` disables the drift check entirely: a model may
/// report arbitrarily bad drift and never be re-tuned.
#[test]
fn disabled_threshold_never_retunes() {
    let drifted = Arc::new(AtomicBool::new(true));
    let retunes = Arc::new(AtomicUsize::new(0));
    let server = {
        let drifted = Arc::clone(&drifted);
        let retunes = Arc::clone(&retunes);
        InferenceServer::start_model(
            move || {
                Ok(Box::new(DriftingModel {
                    drifted: Arc::clone(&drifted),
                    retunes: Arc::clone(&retunes),
                    epoch: 0,
                }) as Box<dyn BatchModel>)
            },
            ServerConfig {
                workers: 1,
                max_wait: Duration::from_millis(1),
                retune_threshold: None,
                ..ServerConfig::default()
            },
        )
        .expect("server start")
    };
    assert_eq!(server.infer(vec![0.0; IN_DIM]).unwrap().len(), 1);
    // Long enough for at least one idle tick to fire.
    let deadline = Instant::now() + Duration::from_millis(1200);
    while Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(retunes.load(Ordering::Relaxed), 0, "disabled check must not fire");
    }
    assert_eq!(server.retunes(), 0);
    server.shutdown();
}
