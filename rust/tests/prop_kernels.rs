//! Property-based tests over the kernel and sparsity substrates using the
//! in-house harness (`util::prop`): randomized RBGP4 configs, shapes, and
//! seeds, each case checked against the dense oracle or a structural
//! invariant.

use rbgp::graph::product_many;
use rbgp::graph::BipartiteGraph;
use rbgp::kernels::autotune::{candidate_plans, search_reps, TuneCache, TuneMode};
use rbgp::kernels::bsr_sdmm::bsr_sdmm;
use rbgp::kernels::csr_sdmm::csr_sdmm;
use rbgp::kernels::dense::gemm_naive;
use rbgp::kernels::plan::{PlanCache, PlanRequest, SparseMatrix};
use rbgp::kernels::registry::KernelRegistry;
use rbgp::kernels::rbgp4mm::{rbgp4mm, rbgp4mm_parallel};
use rbgp::sparsity::bsr::BsrMatrix;
use rbgp::sparsity::csr::CsrMatrix;
use rbgp::sparsity::pattern;
use rbgp::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use rbgp::train_native::{is_nested, mask_nnz, nested_masks_from};
use rbgp::util::prop::{check, gen};
use rbgp::util::rng::Rng;
use rbgp::{prop_assert, prop_assert_eq};

/// A feasible dyadic sparsity for an (nu × nv) base graph.
fn feasible_sp(rng: &mut Rng, nu: usize, nv: usize) -> f64 {
    let mut opts = vec![0.0];
    for (k, sp) in [(1u32, 0.5), (2, 0.75)] {
        if nu % (1 << k) == 0 && nv % (1 << k) == 0 {
            opts.push(sp);
        }
    }
    opts[rng.below_usize(opts.len())]
}

fn random_config(rng: &mut Rng) -> Rbgp4Config {
    let go_u = gen::pow2(rng, 2, 8);
    let go_v = gen::pow2(rng, 2, 8);
    let gi_u = gen::pow2(rng, 4, 8);
    let gi_v = gen::pow2(rng, 4, 8);
    Rbgp4Config {
        go: GraphSpec::new(go_u, go_v, feasible_sp(rng, go_u, go_v)),
        gr: (gen::pow2(rng, 1, 4), gen::pow2(rng, 1, 2)),
        gi: GraphSpec::new(gi_u, gi_v, feasible_sp(rng, gi_u, gi_v)),
        gb: (gen::pow2(rng, 1, 2), gen::pow2(rng, 1, 2)),
    }
}

fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + y.abs()) {
            return Err(format!("idx {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_rbgp4mm_matches_dense_oracle() {
    check("rbgp4mm == dense oracle", 30, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let w = Rbgp4Matrix::random(mask, rng);
        let (m, k) = (w.mask.rows(), w.mask.cols());
        let n = gen::range(rng, 1, 40);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o = vec![0.0; m * n];
        rbgp4mm(&w, &i, &mut o, n);
        let mut oracle = vec![0.0; m * n];
        gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
        close(&o, &oracle, 1e-3)?;
        // Parallel agrees too (tolerance: different summation order).
        let mut op = vec![0.0; m * n];
        rbgp4mm_parallel(&w, &i, &mut op, n, 1 + rng.below_usize(8));
        close(&op, &oracle, 1e-3)
    });
}

#[test]
fn prop_csr_bsr_match_dense_oracle() {
    check("csr/bsr == dense oracle", 30, |rng| {
        let m = 4 * gen::range(rng, 2, 12);
        let k = 4 * gen::range(rng, 2, 12);
        let n = gen::range(rng, 1, 24);
        let sp = [0.5, 0.75][rng.below_usize(2)];
        let i = rng.normal_vec_f32(k * n, 1.0);

        let csr = CsrMatrix::random_row_uniform(m, k, sp, rng);
        let mut o = vec![0.0; m * n];
        csr_sdmm(&csr, &i, &mut o, n);
        let mut oracle = vec![0.0; m * n];
        gemm_naive(&csr.to_dense(), &i, &mut oracle, m, k, n);
        close(&o, &oracle, 1e-3)?;

        let bsr = BsrMatrix::random_block_uniform(m, k, 4, 4, sp, rng);
        let mut o2 = vec![0.0; m * n];
        bsr_sdmm(&bsr, &i, &mut o2, n);
        let mut oracle2 = vec![0.0; m * n];
        gemm_naive(&bsr.to_dense(), &i, &mut oracle2, m, k, n);
        close(&o2, &oracle2, 1e-3)
    });
}

/// The acceptance property of the plan layer: every registered kernel
/// family, invoked through the `SparseKernel` trait from cached plans at
/// 1, 4 and 7 threads, matches the dense naive oracle — over randomized
/// RBGP4 configs and batch sizes including n = 1 and non-multiples of the
/// panel tile.
#[test]
fn prop_trait_kernels_match_oracle_across_threads() {
    let registry = KernelRegistry::builtin();
    check("SparseKernel plans == dense oracle", 12, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let rbgp = Rbgp4Matrix::random(mask, rng);
        let (m, k) = (rbgp.mask.rows(), rbgp.mask.cols());
        // n = 1 and odd sizes exercise the degenerate / non-tile-multiple
        // panel paths.
        let n = [1usize, 3, gen::range(rng, 2, 40)][rng.below_usize(3)];
        let i = rng.normal_vec_f32(k * n, 1.0);

        // All four families at this shape (random_config keeps m, k
        // multiples of 4, so the 4×4 BSR grid always exists).
        let matrices = [
            SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k),
            SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.75, rng)),
            SparseMatrix::Bsr(BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, rng)),
            SparseMatrix::Rbgp4(rbgp),
        ];
        let cache = PlanCache::new();
        for w in &matrices {
            let kernel = registry.for_matrix(w).map_err(|e| e.to_string())?;
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
            for threads in [1usize, 4, 7] {
                // Direct trait path.
                let mut plan = kernel
                    .build_plan(w, &PlanRequest::new(n, threads))
                    .map_err(|e| e.to_string())?;
                let mut o = vec![0.0; m * n];
                kernel
                    .execute(w, &mut plan, &i, &mut o, n)
                    .map_err(|e| e.to_string())?;
                close(&o, &oracle, 1e-3)
                    .map_err(|e| format!("{} t={threads}: {e}", kernel.name()))?;
                // Cached path (second execution re-uses the plan).
                let mut o2 = vec![0.0; m * n];
                cache
                    .execute(&registry, w, &i, &mut o2, n, threads)
                    .map_err(|e| e.to_string())?;
                cache
                    .execute(&registry, w, &i, &mut o2, n, threads)
                    .map_err(|e| e.to_string())?;
                close(&o2, &oracle, 1e-3)
                    .map_err(|e| format!("{} cached t={threads}: {e}", kernel.name()))?;
            }
            // The naive trait path is the oracle for its own family.
            let mut o3 = vec![0.0; m * n];
            kernel
                .execute_naive(w, &i, &mut o3, n)
                .map_err(|e| e.to_string())?;
            close(&o3, &oracle, 1e-3)
                .map_err(|e| format!("{} naive: {e}", kernel.name()))?;
        }
        // Re-executions above must have come from the cache: one build per
        // (family, batch-class, threads), everything else a hit.
        let (hits, misses) = cache.stats();
        prop_assert!(
            misses == matrices.len() * 3,
            "expected {} plan builds, saw {misses} ({hits} hits)",
            matrices.len() * 3
        );
        prop_assert!(hits >= misses, "every plan must be re-used at least once");
        Ok(())
    });
}

/// The autotuner's safety contract: tuning may only choose *schedules*,
/// never numerics. Over randomized configs/shapes and 1/4/8 threads, every
/// candidate plan in the Full search space — the winner a Quick tuned
/// build actually selects — and a plan *loaded* from a persistent
/// [`TuneCache`] by a fresh handle (zero search reps) must all produce
/// output bit-identical to the untuned (Off / fixed-heuristic) plan.
#[test]
fn prop_tuned_candidates_bit_identical_to_untuned_plan() {
    let registry = KernelRegistry::builtin();
    let cache_path = std::env::temp_dir().join(format!(
        "rbgp_prop_tune_cache_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&cache_path);
    check("tuned candidates == untuned plan, bitwise", 8, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let rbgp = Rbgp4Matrix::random(mask, rng);
        let (m, k) = (rbgp.mask.rows(), rbgp.mask.cols());
        // n = 1 hits the degenerate stride/col-block clamps.
        let n = [1usize, gen::range(rng, 2, 24)][rng.below_usize(2)];
        let i = rng.normal_vec_f32(k * n, 1.0);
        let matrices = [
            SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k),
            SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.75, rng)),
            SparseMatrix::Bsr(BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, rng)),
            SparseMatrix::Rbgp4(rbgp),
        ];
        for w in &matrices {
            let kernel = registry.for_matrix(w).map_err(|e| e.to_string())?;
            for threads in [1usize, 4, 8] {
                let off = PlanRequest::new(n, threads).with_tune(TuneMode::Off);
                let mut plan = kernel.build_plan(w, &off).map_err(|e| e.to_string())?;
                prop_assert!(
                    plan.tuned.is_none(),
                    "{} t={threads}: Off build must not record a TunedConfig",
                    kernel.name()
                );
                let mut reference = vec![0.0; m * n];
                kernel
                    .execute(w, &mut plan, &i, &mut reference, n)
                    .map_err(|e| e.to_string())?;
                // Every candidate in the widest (Full) search space.
                let full = PlanRequest::new(n, threads).with_tune(TuneMode::Full);
                for (label, mut cand) in candidate_plans(w, &full) {
                    let mut o = vec![9.0; m * n];
                    kernel
                        .execute(w, &mut cand, &i, &mut o, n)
                        .map_err(|e| e.to_string())?;
                    prop_assert_eq!(
                        o,
                        reference,
                        "{} t={threads} candidate '{label}'",
                        kernel.name()
                    );
                }
                // And the winner a measured Quick search actually picks
                // (selection is timing-nondeterministic; output must not
                // be) — recording into a persistent TuneCache as it goes.
                let rec = TuneCache::open(&cache_path);
                let mut tuned = kernel
                    .build_plan(w, &PlanRequest::new(n, threads).with_tune_cache(rec))
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    tuned.tuned.is_some(),
                    "{} t={threads}: Quick build must record a TunedConfig",
                    kernel.name()
                );
                let mut o = vec![9.0; m * n];
                kernel
                    .execute(w, &mut tuned, &i, &mut o, n)
                    .map_err(|e| e.to_string())?;
                prop_assert_eq!(o, reference, "{} t={threads} tuned winner", kernel.name());
                // A fresh handle on the same file *loads* the winner
                // instead of re-searching; the cache-loaded plan must
                // stay bit-identical to the untuned heuristic as well.
                let before = search_reps();
                let mut warm = kernel
                    .build_plan(
                        w,
                        &PlanRequest::new(n, threads).with_tune_cache(TuneCache::open(&cache_path)),
                    )
                    .map_err(|e| e.to_string())?;
                prop_assert_eq!(
                    search_reps() - before,
                    0,
                    "{} t={threads}: warm cache must build with zero search reps",
                    kernel.name()
                );
                prop_assert!(
                    warm.tuned.is_some(),
                    "{} t={threads}: cache-loaded build must carry the TunedConfig",
                    kernel.name()
                );
                let mut ow = vec![9.0; m * n];
                kernel
                    .execute(w, &mut warm, &i, &mut ow, n)
                    .map_err(|e| e.to_string())?;
                prop_assert_eq!(ow, reference, "{} t={threads} cache-loaded plan", kernel.name());
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_file(&cache_path);
}

#[test]
fn prop_mask_is_rcubs_with_correct_counts() {
    check("RBGP4 mask structure", 20, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let dense = mask.dense();
        let (rows, cols) = (mask.rows(), mask.cols());
        // Exactly row_nnz non-zeros per row (biregular product).
        for u in 0..rows {
            let nnz = dense[u * cols..(u + 1) * cols]
                .iter()
                .filter(|&&v| v != 0.0)
                .count();
            prop_assert_eq!(nnz, cfg.row_nnz(), "row {u} nnz");
        }
        // RCUBS at the config's blocking levels.
        let levels = cfg.blocking_levels();
        prop_assert!(
            pattern::is_rcubs(&dense, rows, cols, &levels).map_err(|e| e.to_string())?,
            "not RCUBS at {levels:?}"
        );
        // Compact round trip is lossless.
        let w = Rbgp4Matrix::random(mask.clone(), rng);
        let back = Rbgp4Matrix::from_dense(mask, &w.to_dense()).map_err(|e| e.to_string())?;
        prop_assert_eq!(&w.data, &back.data, "compact roundtrip");
        Ok(())
    });
}

#[test]
fn prop_product_edge_count_and_degrees_multiply() {
    check("⊗ multiplies edges and degrees", 25, |rng| {
        let mk = |rng: &mut Rng| -> Result<BipartiteGraph, String> {
            // Powers of two with dl a multiple of nv/nu guarantee
            // integral right degree.
            let nu = gen::pow2(rng, 2, 8);
            let nv = gen::pow2(rng, 2, 8);
            let dl = ((nv / nu).max(1) * gen::pow2(rng, 1, 2)).min(nv);
            BipartiteGraph::random_biregular(nu, nv, dl, rng).map_err(|e| e.to_string())
        };
        let g1 = mk(rng)?;
        let g2 = mk(rng)?;
        let p = product_many(&[&g1, &g2]).map_err(|e| e.to_string())?;
        prop_assert_eq!(p.num_edges(), g1.num_edges() * g2.num_edges(), "edges");
        let (d1l, d1r) = g1.degrees().map_err(|e| e.to_string())?;
        let (d2l, d2r) = g2.degrees().map_err(|e| e.to_string())?;
        prop_assert_eq!(
            p.degrees().map_err(|e| e.to_string())?,
            (d1l * d2l, d1r * d2r),
            "degrees"
        );
        Ok(())
    });
}

#[test]
fn prop_lift_preserves_biregularity() {
    check("2-lift invariants", 25, |rng| {
        let nu = gen::pow2(rng, 2, 8);
        let nv = gen::pow2(rng, 2, 8);
        let dl = [1usize, 2][rng.below_usize(2)].min(nv);
        if (nu * dl) % nv != 0 {
            return Ok(()); // infeasible draw, skip
        }
        let g = BipartiteGraph::random_biregular(nu, nv, dl, rng).map_err(|e| e.to_string())?;
        let gl = rbgp::graph::lift::lift2(&g, rng);
        prop_assert_eq!(gl.nu, 2 * g.nu, "nu doubles");
        prop_assert_eq!(gl.num_edges(), 2 * g.num_edges(), "edges double");
        prop_assert_eq!(
            gl.degrees().map_err(|e| e.to_string())?,
            g.degrees().map_err(|e| e.to_string())?,
            "degrees preserved"
        );
        Ok(())
    });
}

#[test]
fn prop_succinct_index_always_smaller() {
    check("succinct index < generic adjacency", 20, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        prop_assert!(
            mask.succinct_index_elems() <= mask.generic_index_elems(),
            "succinct {} > generic {}",
            mask.succinct_index_elems(),
            mask.generic_index_elems()
        );
        Ok(())
    });
}

/// Gradual-induction chain invariants over randomized RBGP4 configs and
/// seeds: nested by construction, monotone nnz, strict supersets whenever
/// the shape has the capacity for distinct levels, exact final mask.
#[test]
fn prop_gradual_chain_nested_with_monotone_nnz() {
    check("gradual chain nesting", 15, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let levels = 1 + rng.below_usize(3);
        let chain = nested_masks_from(&mask, levels, rng);
        prop_assert_eq!(chain.len(), levels + 1, "chain length");
        prop_assert!(is_nested(&chain), "chain must be nested");
        for (i, w) in chain.windows(2).enumerate() {
            prop_assert!(
                mask_nnz(&w[0]) >= mask_nnz(&w[1]),
                "nnz must be monotone at level {i}"
            );
        }
        // With enough off-mask capacity, every intermediate is a *strict*
        // superset of its successor (see nested_masks_from's extra
        // enforcement; the bound covers rounding plus bump slack).
        let full_extra = cfg.cols() - cfg.row_nnz();
        if full_extra >= (levels + 1) * (levels + 1) {
            for (i, w) in chain.windows(2).enumerate() {
                prop_assert!(
                    mask_nnz(&w[0]) > mask_nnz(&w[1]),
                    "level {i} must strictly tighten ({} vs {})",
                    mask_nnz(&w[0]),
                    mask_nnz(&w[1])
                );
            }
        }
        prop_assert_eq!(
            chain.last().unwrap(),
            &mask.dense(),
            "chain must end at the exact RBGP4 mask"
        );
        Ok(())
    });
}

/// The re-key contract of the structure hash: for each milestone mask,
/// the exported-CSR structure hash is (a) stable within the milestone —
/// recomputation and weight-value changes don't move it — and (b) changed
/// across every milestone that actually tightened the mask.
#[test]
fn prop_milestone_structure_hashes_rekey_exactly() {
    check("structure hash per milestone", 15, |rng| {
        let cfg = random_config(rng);
        let mask = Rbgp4Mask::sample(cfg, rng).map_err(|e| e.to_string())?;
        let levels = 1 + rng.below_usize(3);
        let chain = nested_masks_from(&mask, levels, rng);
        let (rows, cols) = (cfg.rows(), cfg.cols());
        let hash_of = |values: &[f32], m: &[f32]| {
            SparseMatrix::Csr(CsrMatrix::from_dense_with_pattern(values, m, rows, cols))
                .structure_hash()
        };
        let hashes: Vec<u64> = chain.iter().map(|m| hash_of(m, m)).collect();
        // (a) stable within one milestone: recomputation agrees, and the
        // hash is a function of the mask alone, not the weight values.
        for (i, m) in chain.iter().enumerate() {
            prop_assert_eq!(hashes[i], hash_of(m, m), "hash must be stable (level {i})");
            let values = rng.normal_vec_f32(rows * cols, 1.0);
            prop_assert_eq!(
                hashes[i],
                hash_of(&values, m),
                "hash must ignore weight values (level {i})"
            );
        }
        // (b) changes across every milestone whose mask actually changed
        // (saturated shapes may repeat the densest level).
        for (i, w) in chain.windows(2).enumerate() {
            if w[0] != w[1] {
                prop_assert!(
                    hashes[i] != hashes[i + 1],
                    "hash must change at milestone {i}"
                );
            }
        }
        Ok(())
    });
}

/// PlanCache hit/miss/eviction accounting stays exact across a structure
/// re-key with 8 threads racing on the resolve path: one build per
/// (structure, thread-class), eviction removes exactly the dead
/// structure's plans, and the next structure rebuilds fresh.
#[test]
fn prop_plan_cache_rekey_accounting_is_exact_under_races() {
    let registry = KernelRegistry::builtin();
    check("PlanCache re-key accounting", 6, |rng| {
        let m = 4 * gen::range(rng, 2, 8);
        let k = 4 * gen::range(rng, 2, 8);
        let a = SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.5, rng));
        let b = SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.75, rng));
        let n = gen::range(rng, 1, 16);
        let cache = PlanCache::new();
        let n_threads = 8;
        let rounds = 4;
        // 8 threads race on one structure; odd/even threads use different
        // thread-class keys, so each phase caches exactly two plans.
        let hammer = |w: &SparseMatrix| {
            std::thread::scope(|scope| {
                for t in 0..n_threads {
                    let cache = &cache;
                    let registry = &registry;
                    scope.spawn(move || {
                        for _ in 0..rounds {
                            let req = PlanRequest::new(n, 1 + (t % 2));
                            cache.plan_for(registry, w, &req).unwrap();
                        }
                    });
                }
            });
        };

        hammer(&a);
        let calls = n_threads * rounds;
        let (hits, misses) = cache.stats();
        prop_assert_eq!(misses, 2, "one build per (structure, thread class)");
        prop_assert_eq!(hits, calls - 2, "every other racing resolve hits");
        prop_assert_eq!(
            cache.structure_plan_count(a.structure_hash()),
            2,
            "phase-1 plans live under a's namespace"
        );

        // Re-key: structure `a` dies.
        let evicted = cache.invalidate_structure(a.structure_hash());
        prop_assert_eq!(evicted, 2, "exactly the dead structure's plans evicted");
        prop_assert_eq!(cache.eviction_stats(), (1, 2), "eviction accounting exact");
        prop_assert!(cache.is_empty(), "nothing else was cached");

        hammer(&b);
        let (hits, misses) = cache.stats();
        prop_assert_eq!(misses, 4, "the new structure rebuilds fresh, no stale hits");
        prop_assert_eq!(hits, 2 * (calls - 2), "hit accounting continues exactly");
        prop_assert_eq!(
            cache.structures(),
            vec![b.structure_hash()],
            "only the live structure remains"
        );
        Ok(())
    });
}

#[test]
fn prop_plan_cache_concurrent_resolve_is_consistent() {
    let registry = KernelRegistry::builtin();
    check("PlanCache under concurrent resolve", 8, |rng| {
        // A few distinct structures at one shape (dense + two CSR patterns).
        let m = 4 * gen::range(rng, 2, 8);
        let k = 4 * gen::range(rng, 2, 8);
        let matrices = [
            SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k),
            SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.75, rng)),
            SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.5, rng)),
        ];
        let n = gen::range(rng, 1, 16);
        let req = PlanRequest::new(n, 2);
        let cache = PlanCache::new();
        let n_threads = 8;
        let rounds = 4;
        // N threads race to resolve every structure's plan `rounds` times
        // (the multi-worker server's warm-up pattern).
        let ptrs: Vec<Vec<usize>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_threads)
                .map(|_| {
                    let cache = &cache;
                    let registry = &registry;
                    let matrices = &matrices;
                    scope.spawn(move || {
                        let mut seen = Vec::new();
                        for _ in 0..rounds {
                            for w in matrices {
                                let plan = cache.plan_for(registry, w, &req).unwrap();
                                seen.push(std::sync::Arc::as_ptr(&plan) as usize);
                            }
                        }
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Hit/miss accounting must be exact even when builders race: a
        // miss is counted only for the plan that won insertion, so misses
        // equal the distinct structures and everything else is a hit.
        let (hits, misses) = cache.stats();
        let total = n_threads * rounds * matrices.len();
        prop_assert_eq!(misses, matrices.len(), "one build per structure");
        prop_assert_eq!(hits, total - matrices.len(), "every other resolve hits");
        prop_assert_eq!(cache.len(), matrices.len(), "no duplicate entries survive");
        // Every thread got the same canonical Arc per structure — racing
        // losers adopt the winner's plan instead of keeping their own.
        let mut distinct: Vec<usize> = ptrs.into_iter().flatten().collect();
        distinct.sort_unstable();
        distinct.dedup();
        prop_assert_eq!(distinct.len(), matrices.len(), "one shared plan per structure");
        Ok(())
    });
}
