//! Minimal JSON value + writer + parser.
//!
//! Used for artifact metadata (`artifacts/*.json`), experiment configs, and
//! benchmark reports. The offline vendor set has no `serde`/`serde_json`, so
//! this is a small, strict implementation: UTF-8, no comments, no trailing
//! commas, f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministically
/// ordered (stable artifacts, diffable reports).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `get(key).and_then(as_usize)` with a useful error.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/non-numeric key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/non-string key '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/non-array key '{key}'"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(out, indent + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document (strict).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
    } else {
        let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut v = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    v.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    m.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // No surrogate-pair support; metadata never needs it.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "rbgp4")
            .set("rows", 4096usize)
            .set("sparsity", 0.875)
            .set("ok", true)
            .set("tags", vec!["a", "b"]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.req_usize("rows").unwrap(), 4096);
        assert_eq!(back.req_str("name").unwrap(), "rbgp4");
    }

    #[test]
    fn roundtrip_pretty() {
        let mut j = Json::obj();
        j.set("a", vec![1usize, 2, 3]).set("b", Json::Null);
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"x": [1, {"y": "z\n"}, null, -2.5e3], "w": false}"#).unwrap();
        let x = j.req_arr("x").unwrap();
        assert_eq!(x[0].as_f64(), Some(1.0));
        assert_eq!(x[1].get("y").unwrap().as_str(), Some("z\n"));
        assert_eq!(x[2], Json::Null);
        assert_eq!(x[3].as_f64(), Some(-2500.0));
        assert_eq!(j.get("w").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("λ₂ ≤ √d".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::Num(4096.0).to_string(), "4096");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
