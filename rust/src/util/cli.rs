//! Tiny command-line argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments.
//! A repeated `--key` accumulates every value in order ([`Args::get_all`]);
//! the single-value accessors return the last occurrence. Subcommand
//! dispatch is done by the caller (`main.rs`) on the first positional token.

use std::collections::BTreeMap;

/// Parsed arguments: named options plus positionals, in order.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (typically `std::env::args().skip(1)`).
    ///
    /// A `--key` followed by a token that does not start with `--` consumes it
    /// as the value; a bare trailing `--key` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.opts
                        .entry(body.to_string())
                        .or_default()
                        .push(toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    /// True when `--name` appeared at all (bare, `--name=x`, or `--name x`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    /// Last value of `--name` (repeated options: the final one wins).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts
            .get(name)
            .and_then(|v| v.last())
            .map(String::as_str)
    }

    /// Every value of a repeated `--name`, in appearance order (empty when
    /// absent) — e.g. `serve --model a=a.json --model b=b.json`.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.opts
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Parse `--name a,b,c` into a list (empty if absent).
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default()
    }

    /// Parse a pair like `--size 128x32` (separator `x` or `,`).
    pub fn get_pair(&self, name: &str) -> anyhow::Result<Option<(usize, usize)>> {
        let Some(v) = self.get(name) else {
            return Ok(None);
        };
        let sep = if v.contains('x') { 'x' } else { ',' };
        let parts: Vec<&str> = v.split(sep).collect();
        if parts.len() != 2 {
            anyhow::bail!("--{name} expects AxB, got '{v}'");
        }
        Ok(Some((parts[0].trim().parse()?, parts[1].trim().parse()?)))
    }
}

/// Split a `NAME=VALUE` option body (e.g. `--alias prod=v1`,
/// `--model a=a.json`) into trimmed halves; errors mention `flag` so the
/// message reads as `--alias expects NAME=VALUE`.
pub fn split_assign<'a>(flag: &str, body: &'a str) -> anyhow::Result<(&'a str, &'a str)> {
    match body.split_once('=') {
        Some((k, v)) => {
            let (k, v) = (k.trim(), v.trim());
            anyhow::ensure!(
                !k.is_empty() && !v.is_empty(),
                "--{flag} expects NAME=VALUE, got '{body}'"
            );
            Ok((k, v))
        }
        None => anyhow::bail!("--{flag} expects NAME=VALUE, got '{body}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn split_assign_trims_and_rejects_malformed() {
        assert_eq!(split_assign("alias", "prod=v1").unwrap(), ("prod", "v1"));
        assert_eq!(split_assign("alias", " a = b ").unwrap(), ("a", "b"));
        // Only the first '=' splits: values may carry their own.
        assert_eq!(split_assign("canary", "prod=v2@10").unwrap(), ("prod", "v2@10"));
        for bad in ["noequals", "=v", "k=", " = "] {
            let err = split_assign("alias", bad).unwrap_err().to_string();
            assert!(err.contains("--alias expects NAME=VALUE"), "{err}");
        }
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--flag value` pair is ambiguous in this mini-parser
        // (value gets consumed); boolean flags go last or use `=`.
        let a = parse("train data.bin --steps 100 --lr=0.1 --verbose");
        assert_eq!(a.command(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["train", "data.bin"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("bench --n x");
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_str("who", "d"), "d");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = parse("serve --model a=a.json --model b=b.json --workers 2");
        assert_eq!(a.get_all("model"), vec!["a=a.json", "b=b.json"]);
        assert_eq!(a.get("model"), Some("b=b.json"), "single-value get: last wins");
        assert_eq!(a.get_all("nope"), Vec::<&str>::new());
        assert_eq!(a.get_usize("workers", 0).unwrap(), 2);
    }

    #[test]
    fn lists_and_pairs() {
        let a = parse("t --sp 0.5,0.75 --size 128x32");
        assert_eq!(a.get_list("sp"), vec!["0.5", "0.75"]);
        assert_eq!(a.get_pair("size").unwrap(), Some((128, 32)));
        assert_eq!(a.get_pair("nope").unwrap(), None);
    }
}
