//! Benchmark timing harness.
//!
//! The vendor set has no `criterion`, so benches (`harness = false`) use this
//! small harness: warmup iterations, then `n` timed samples, reporting
//! median / mean / MAD / min. Deterministic output format so bench logs diff
//! cleanly between perf iterations.

use std::time::{Duration, Instant};

/// Statistics over timed samples, in seconds.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub samples: Vec<f64>,
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> BenchStats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&samples, 50.0);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples[0];
        let max = *samples.last().unwrap();
        let mut dev: Vec<f64> = samples.iter().map(|x| (x - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&dev, 50.0);
        BenchStats {
            samples,
            median,
            mean,
            min,
            max,
            mad,
        }
    }

    /// Milliseconds, for report rows.
    pub fn median_ms(&self) -> f64 {
        self.median * 1e3
    }
}

fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Configuration for `bench_fn`.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Hard cap on total measurement time; sampling stops early past this.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            samples: 15,
            max_total: Duration::from_secs(10),
        }
    }
}

impl BenchConfig {
    /// A faster profile for smoke runs / CI (`RBGP_BENCH_FAST=1`).
    pub fn from_env() -> BenchConfig {
        if std::env::var("RBGP_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
            BenchConfig {
                warmup_iters: 1,
                samples: 5,
                max_total: Duration::from_secs(2),
            }
        } else {
            BenchConfig::default()
        }
    }
}

/// Time `f` under `cfg`. `f` must perform one complete operation per call;
/// use `std::hint::black_box` inside for anything the optimizer might drop.
pub fn bench_fn<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> BenchStats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    let start = Instant::now();
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if start.elapsed() > cfg.max_total && !samples.is_empty() {
            break;
        }
    }
    BenchStats::from_samples(samples)
}

/// One formatted bench row: `name  median  mad  min` (ms).
pub fn report_row(name: &str, stats: &BenchStats) -> String {
    format!(
        "{:<44} {:>10.3} ms  ±{:>7.3}  min {:>10.3}",
        name,
        stats.median * 1e3,
        stats.mad * 1e3,
        stats.min * 1e3
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_mean() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let s = BenchStats::from_samples(vec![1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median, 1.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    fn bench_fn_runs_and_counts() {
        let mut count = 0usize;
        let cfg = BenchConfig {
            warmup_iters: 2,
            samples: 5,
            max_total: Duration::from_secs(5),
        };
        let stats = bench_fn(&cfg, || {
            count += 1;
        });
        assert_eq!(count, 7);
        assert_eq!(stats.samples.len(), 5);
        assert!(stats.median >= 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
    }
}
