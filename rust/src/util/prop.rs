//! Minimal property-based testing harness (no `proptest` in the vendor set).
//!
//! `check(name, cases, |rng| ...)` runs a property closure over `cases`
//! independently-seeded RNGs; on failure it reports the failing seed so the
//! case can be replayed deterministically with `replay(seed, ...)`.
//! There is no shrinking — generators are written to produce small cases by
//! construction (sizes drawn from small ranges).

use crate::util::rng::Rng;

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Assert a condition inside a property, with context formatting.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert equality inside a property.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {{
        let (av, bv) = (&$a, &$b);
        if av != bv {
            return Err(format!(
                "{} — left={:?} right={:?}",
                format!($($fmt)*), av, bv
            ));
        }
    }};
}

/// Run `prop` over `cases` cases. Seeds are derived from `base_seed` so the
/// whole suite is deterministic; set env `RBGP_PROP_SEED` to reproduce a CI
/// run locally.
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut meta = Rng::new(base_seed);
    for case in 0..cases {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Run with the default or env-provided base seed.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = std::env::var("RBGP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00Du64);
    check_seeded(name, base, cases, prop)
}

/// Replay one failing case by exact seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay seed {seed:#x} failed:\n  {msg}");
    }
}

/// Generator helpers for common shapes used across the test suite.
pub mod gen {
    use crate::util::rng::Rng;

    /// A power of two in `[lo, hi]` (both must be powers of two).
    pub fn pow2(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_log = lo.trailing_zeros();
        let hi_log = hi.trailing_zeros();
        1usize << (lo_log + rng.below((hi_log - lo_log + 1) as u64) as u32)
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below_usize(hi - lo + 1)
    }

    /// A divisor of `n`, uniform over divisors.
    pub fn divisor(rng: &mut Rng, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        divs[rng.below_usize(divs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 25, |rng| {
            n += 1;
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check_seeded("det", 99, 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check_seeded("det", 99, 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn gen_helpers() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let p = gen::pow2(&mut rng, 2, 64);
            assert!(p.is_power_of_two() && (2..=64).contains(&p));
            let r = gen::range(&mut rng, 3, 9);
            assert!((3..=9).contains(&r));
            let d = gen::divisor(&mut rng, 24);
            assert_eq!(24 % d, 0);
        }
    }
}
