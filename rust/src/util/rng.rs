//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement a small,
//! well-understood generator ourselves: `xoshiro256**` seeded through
//! SplitMix64. All randomized pieces of the library (2-lifts, Ramanujan
//! rejection sampling, synthetic data, property tests) take an explicit
//! `Rng` so every experiment is reproducible from a single `u64` seed.

/// xoshiro256** PRNG (Blackman & Vigna). Passes BigCrush; more than
/// adequate for graph sampling and synthetic data.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Fork an independent stream (for per-thread / per-layer seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method
    /// to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; the twin is
    /// discarded for simplicity — sampling is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Vector of iid standard normals scaled by `scale`.
    pub fn normal_vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_mean_half() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(13);
        for _ in 0..20 {
            let s = rng.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
