//! A small scoped thread pool (no `rayon` in the offline vendor set).
//!
//! Two entry points:
//!  * [`ThreadPool`] — long-lived workers fed closures over a channel; used by
//!    the coordinator for request handling.
//!  * [`parallel_for`] — scoped fork-join over an index range with static
//!    chunking; used by the parallel SDMM kernels.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived worker pool. Jobs are `FnOnce() + Send`; results flow through
/// whatever channel the caller closes over.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("rbgp-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = super::lock_recover(&rx);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            sender: Some(tx),
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to default to: available parallelism, capped so
/// benches stay stable on oversubscribed machines.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Scoped fork-join parallel loop: calls `f(i)` for every `i in 0..n`, using
/// `threads` OS threads with dynamic (atomic counter) chunking of size
/// `chunk`. `f` only needs to live for the call (scoped threads).
pub fn parallel_for<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Split a mutable slice into `n` disjoint row-chunks and process them in
/// parallel: `f(chunk_index, rows_start, chunk_slice)`. Used by kernels that
/// write disjoint row ranges of the output.
pub fn parallel_rows<T: Send, F>(data: &mut [T], rows: usize, row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * row_len);
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 || rows == 0 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(threads);
    thread::scope(|scope| {
        let mut rest = data;
        let mut row0 = 0usize;
        while row0 < rows {
            let take = per.min(rows - row0);
            let (head, tail) = rest.split_at_mut(take * row_len);
            let start_row = row0;
            let fr = &f;
            scope.spawn(move || fr(start_row, head));
            rest = tail;
            row0 += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 8, 7, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_single_thread_and_empty() {
        parallel_for(0, 4, 8, |_| panic!("should not run"));
        let mut sum = AtomicUsize::new(0);
        parallel_for(10, 1, 3, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(*sum.get_mut(), 45);
    }

    #[test]
    fn parallel_rows_disjoint_writes() {
        let rows = 37;
        let cols = 5;
        let mut data = vec![0u32; rows * cols];
        parallel_rows(&mut data, rows, cols, 4, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + r) as u32;
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(data[r * cols + c], r as u32);
            }
        }
    }
}
