//! Foundation utilities, hand-rolled because the offline vendor set contains
//! only the `xla` crate closure (no rand / serde / clap / criterion /
//! proptest / rayon / tokio).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timing;

/// Human-friendly byte formatting (MB with 2 decimals, as the paper's
/// Table 1 reports memory in MB).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// `a/b` rounded up.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_mb_matches_paper_convention() {
        assert_eq!(fmt_mb(1024 * 1024), "1.00");
        assert_eq!(fmt_mb(81_146_470), "77.39"); // VGG19 dense params ≈ paper's 77.39 MB
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
    }
}
