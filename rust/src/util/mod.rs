//! Foundation utilities, hand-rolled because the offline vendor set contains
//! only the `xla` crate closure (no rand / serde / clap / criterion /
//! proptest / rayon / tokio).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timing;

/// Lock a mutex, recovering the guard when a previous holder panicked.
/// For advisory shared state (metrics rings, plan caches): a torn value
/// from a crashed thread is strictly better than propagating its panic
/// into every other thread that later takes the lock.
pub fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Human-friendly byte formatting (MB with 2 decimals, as the paper's
/// Table 1 reports memory in MB).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// `a/b` rounded up.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// FNV-1a over u64 words — cheap, deterministic, dependency-free. Used for
/// structure hashes (plan-cache keys) where stability across runs matters
/// and cryptographic strength does not.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    /// Mix all 8 bytes of `word`.
    #[inline]
    pub fn push(&mut self, word: u64) {
        let mut x = word;
        for _ in 0..8 {
            self.0 ^= x & 0xff;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
            x >>= 8;
        }
    }

    pub fn push_all(&mut self, words: impl Iterator<Item = u64>) {
        for w in words {
            self.push(w);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Fnv::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_mb_matches_paper_convention() {
        assert_eq!(fmt_mb(1024 * 1024), "1.00");
        assert_eq!(fmt_mb(81_146_470), "77.39"); // VGG19 dense params ≈ paper's 77.39 MB
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
    }

    #[test]
    fn fnv_is_deterministic_and_order_sensitive() {
        let mut a = Fnv::new();
        a.push_all([1u64, 2, 3].into_iter());
        let mut b = Fnv::new();
        b.push_all([1u64, 2, 3].into_iter());
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv::new();
        c.push_all([3u64, 2, 1].into_iter());
        assert_ne!(a.finish(), c.finish());
    }
}
