//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! PJRT client. After `make artifacts` the Rust binary is self-contained —
//! Python never runs on the request path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md).
//!
//! The artifact metadata layer ([`artifact`]) is always available; the
//! executor needs the `xla` PJRT binding and is gated behind the `xla`
//! feature so the default build is self-contained (the native plan-based
//! serving/training paths in [`crate::coordinator`] cover the featureless
//! build).

pub mod artifact;
#[cfg(feature = "xla")]
pub mod executor;

pub use artifact::{Artifact, ArtifactMeta, TensorSig};
#[cfg(feature = "xla")]
pub use executor::Executor;
