//! Artifact metadata: the positional input/output contract emitted by
//! `python/compile/aot.py` as `<name>.json` beside each `<name>.hlo.txt`.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSig> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(TensorSig {
            name: j.req_str("name")?.to_string(),
            shape,
            dtype: j.req_str("dtype")?.to_string(),
        })
    }
}

/// Parsed `<name>.json` metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub kind: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    /// Canonical parameter name order (train/forward artifacts).
    pub param_order: Vec<String>,
    /// Raw JSON for anything consumers want to dig out (masks, configs…).
    pub raw: Json,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> anyhow::Result<ArtifactMeta> {
        let raw = Json::parse(text)?;
        let sigs = |key: &str| -> anyhow::Result<Vec<TensorSig>> {
            raw.req_arr(key)?.iter().map(TensorSig::from_json).collect()
        };
        let param_order = raw
            .get("param_order")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        Ok(ArtifactMeta {
            kind: raw.req_str("kind")?.to_string(),
            inputs: sigs("inputs")?,
            outputs: sigs("outputs")?,
            param_order,
            raw,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        ArtifactMeta::parse(&text)
    }

    pub fn input_index(&self, name: &str) -> anyhow::Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("no input '{name}' in {} artifact", self.kind))
    }

    pub fn output_index(&self, name: &str) -> anyhow::Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("no output '{name}' in {} artifact", self.kind))
    }

    /// Batch size declared by the exporter (if present).
    pub fn batch(&self) -> Option<usize> {
        self.raw.get("batch").and_then(Json::as_usize)
    }
}

/// Paths for one artifact pair in a directory.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub hlo_path: PathBuf,
    pub meta: ArtifactMeta,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.json`.
    pub fn load(dir: &Path, name: &str) -> anyhow::Result<Artifact> {
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(
            hlo_path.exists(),
            "missing artifact {} — run `make artifacts`",
            hlo_path.display()
        );
        let meta = ArtifactMeta::load(&dir.join(format!("{name}.json")))?;
        Ok(Artifact {
            name: name.to_string(),
            hlo_path,
            meta,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
        "kind": "forward",
        "batch": 8,
        "param_order": ["bc", "w0", "wc"],
        "inputs": [
            {"name": "w0", "shape": [128, 32], "dtype": "float32"},
            {"name": "x", "shape": [8, 128], "dtype": "float32"}
        ],
        "outputs": [
            {"name": "logits", "shape": [8, 4], "dtype": "float32"}
        ]
    }"#;

    #[test]
    fn parse_meta() {
        let m = ArtifactMeta::parse(META).unwrap();
        assert_eq!(m.kind, "forward");
        assert_eq!(m.batch(), Some(8));
        assert_eq!(m.param_order, vec!["bc", "w0", "wc"]);
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0].elements(), 128 * 32);
        assert_eq!(m.input_index("x").unwrap(), 1);
        assert_eq!(m.output_index("logits").unwrap(), 0);
        assert!(m.input_index("nope").is_err());
    }

    #[test]
    fn scalar_sig_has_one_element() {
        let s = TensorSig {
            name: "lr".into(),
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(s.elements(), 1);
    }

    #[test]
    fn missing_artifact_errors_helpfully() {
        let err = Artifact::load(Path::new("/nonexistent"), "forward").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
