//! Executor: compile an HLO-text artifact on the PJRT CPU client and run it
//! with `Vec<f32>` host tensors, handling Literal packing/unpacking and the
//! 1-tuple convention (`return_tuple=True` on the Python side).

use crate::runtime::artifact::Artifact;
use std::path::Path;

/// A compiled artifact bound to a PJRT client.
pub struct Executor {
    pub artifact: Artifact,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

/// Host-side tensor: flat f32 data + shape. The only dtype our artifacts
/// use at the boundary (masks/adjacency are baked into the HLO).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl HostTensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>().max(1),
            "data/shape mismatch"
        );
        HostTensor {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor {
            data: vec![0.0; shape.iter().product::<usize>().max(1)],
            shape: shape.to_vec(),
        }
    }

    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

impl Executor {
    /// Compile `artifacts_dir/<name>.hlo.txt` on a fresh CPU client.
    pub fn compile(artifacts_dir: &Path, name: &str) -> anyhow::Result<Executor> {
        let artifact = Artifact::load(artifacts_dir, name)?;
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            artifact
                .hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Executor {
            artifact,
            client,
            exe,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with positional inputs; returns outputs in artifact order.
    ///
    /// Validates input count and shapes against the artifact signature so a
    /// stale artifact fails loudly instead of producing garbage.
    pub fn run(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let sig = &self.artifact.meta.inputs;
        anyhow::ensure!(
            inputs.len() == sig.len(),
            "{}: expected {} inputs, got {}",
            self.artifact.name,
            sig.len(),
            inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(sig).enumerate() {
            anyhow::ensure!(
                t.shape == s.shape,
                "{}: input {i} ({}) shape {:?} != declared {:?}",
                self.artifact.name,
                s.name,
                t.shape,
                s.shape
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<anyhow::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let outs = &self.artifact.meta.outputs;
        anyhow::ensure!(
            parts.len() == outs.len(),
            "{}: executable returned {} outputs, metadata declares {}",
            self.artifact.name,
            parts.len(),
            outs.len()
        );
        parts
            .into_iter()
            .zip(outs)
            .map(|(lit, sig)| {
                let data = lit.to_vec::<f32>()?;
                anyhow::ensure!(
                    data.len() == sig.elements(),
                    "output {} length {} != {}",
                    sig.name,
                    data.len(),
                    sig.elements()
                );
                Ok(HostTensor {
                    data,
                    shape: sig.shape.clone(),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        let s = HostTensor::scalar(7.0);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(HostTensor::zeros(&[3]).data, vec![0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "data/shape mismatch")]
    fn host_tensor_rejects_mismatch() {
        HostTensor::new(vec![1.0], &[2, 2]);
    }

    /// End-to-end through PJRT using the `smoke` artifact — requires
    /// `make artifacts` to have run (skipped otherwise).
    #[test]
    fn smoke_artifact_roundtrip() {
        let dir = artifacts_dir();
        if !dir.join("smoke.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exe = Executor::compile(&dir, "smoke").unwrap();
        let a = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = HostTensor::new(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let out = exe.run(&[a, b]).unwrap();
        assert_eq!(out.len(), 1);
        // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
        assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn run_rejects_wrong_arity_and_shape() {
        let dir = artifacts_dir();
        if !dir.join("smoke.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exe = Executor::compile(&dir, "smoke").unwrap();
        let a = HostTensor::new(vec![0.0; 4], &[2, 2]);
        assert!(exe.run(&[a.clone()]).is_err());
        let bad = HostTensor::new(vec![0.0; 2], &[2, 1]);
        assert!(exe.run(&[a, bad]).is_err());
    }
}
