//! SDMM kernels: the dense (cuBLAS stand-in), unstructured CSR and block BSR
//! baselines (cuSparse stand-ins), and the paper's RBGP4MM (Algorithm 1)
//! adapted to the CPU cache hierarchy. These are the *measured* halves of
//! Tables 1–3; the V100 estimates come from [`crate::gpusim`].
//!
//! All four families are unified behind the [`registry::SparseKernel`]
//! trait: [`plan`] holds the execution-plan layer (build once per
//! `(matrix, batch class, threads)`, execute allocation-free), [`autotune`]
//! turns `build_plan` into a roofline-scored schedule search, [`registry`]
//! holds the `Pattern`-keyed family registry shared with the cost model's
//! [`crate::gpusim::KernelKind`]. The historical free functions remain as
//! per-call wrappers.

pub mod autotune;
pub mod bsr_sdmm;
pub mod csr_sdmm;
pub mod dense;
pub mod plan;
pub mod rbgp4mm;
pub mod registry;

pub use autotune::{
    candidate_plans, machine_probe, search_reps, tolerance_rejections, MachineProbe, TuneCache,
    TuneKey, TuneMode, TunedConfig,
};
pub use bsr_sdmm::{bsr_sdmm, bsr_sdmm_parallel};
pub use csr_sdmm::{csr_sdmm, csr_sdmm_parallel};
pub use dense::{gemm_blocked, gemm_naive, gemm_parallel};
pub use plan::{batch_class, KernelPlan, PlanCache, PlanKey, PlanRequest, SparseMatrix};
pub use rbgp4mm::{rbgp4mm, rbgp4mm_naive, rbgp4mm_parallel, Rbgp4Plan, Rbgp4Tunable};
pub use registry::{KernelRegistry, SparseKernel};
