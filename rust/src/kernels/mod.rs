//! SDMM kernels: the dense (cuBLAS stand-in), unstructured CSR and block BSR
//! baselines (cuSparse stand-ins), and the paper's RBGP4MM (Algorithm 1)
//! adapted to the CPU cache hierarchy. These are the *measured* halves of
//! Tables 1–3; the V100 estimates come from [`crate::gpusim`].

pub mod bsr_sdmm;
pub mod csr_sdmm;
pub mod dense;
pub mod rbgp4mm;

pub use bsr_sdmm::{bsr_sdmm, bsr_sdmm_parallel};
pub use csr_sdmm::{csr_sdmm, csr_sdmm_parallel};
pub use dense::{gemm_blocked, gemm_naive, gemm_parallel};
pub use rbgp4mm::{rbgp4mm, rbgp4mm_naive, rbgp4mm_parallel};
