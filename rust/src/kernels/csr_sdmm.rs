//! Unstructured SDMM: `O = W_csr · I` (the cuSparse-CSR stand-in).
//!
//! This kernel has the access pattern the paper's §5 motivates against:
//! every non-zero triggers a *gathered* row of `I` — no reuse across rows,
//! no tile skipping, index storage read alongside every value.

use crate::sparsity::csr::CsrMatrix;
use crate::util::threadpool::parallel_rows;

/// Row-by-row CSR SDMM. `i` is (cols × n) row-major, `o` is (rows × n).
pub fn csr_sdmm(w: &CsrMatrix, i: &[f32], o: &mut [f32], n: usize) {
    assert_eq!(i.len(), w.cols * n);
    assert_eq!(o.len(), w.rows * n);
    o.fill(0.0);
    for r in 0..w.rows {
        let orow = &mut o[r * n..(r + 1) * n];
        for k in w.indptr[r]..w.indptr[r + 1] {
            let a = w.values[k];
            let irow = &i[w.indices[k] * n..w.indices[k] * n + n];
            for c in 0..n {
                orow[c] += a * irow[c];
            }
        }
    }
}

/// Rows `[row0, row0+rows)` of the product, written into `chunk`
/// (`rows × n`, already zeroed by the caller or zeroed here).
fn csr_rows_into(w: &CsrMatrix, i: &[f32], chunk: &mut [f32], n: usize, row0: usize) {
    chunk.fill(0.0);
    let rows = chunk.len() / n.max(1);
    for r in 0..rows {
        let orow = &mut chunk[r * n..(r + 1) * n];
        let wr = row0 + r;
        for k in w.indptr[wr]..w.indptr[wr + 1] {
            let a = w.values[k];
            let irow = &i[w.indices[k] * n..w.indices[k] * n + n];
            for c in 0..n {
                orow[c] += a * irow[c];
            }
        }
    }
}

/// Parallel CSR SDMM over disjoint output-row chunks (even row split).
pub fn csr_sdmm_parallel(w: &CsrMatrix, i: &[f32], o: &mut [f32], n: usize, threads: usize) {
    assert_eq!(o.len(), w.rows * n);
    parallel_rows(o, w.rows, n, threads, |row0, chunk| {
        csr_rows_into(w, i, chunk, n, row0);
    });
}

/// Parallel CSR SDMM over precomputed contiguous row `ranges` (one worker
/// per range) — the plan-based execute path, where ranges were balanced by
/// non-zero count at plan-build time instead of split evenly per call.
/// `ranges` must be ascending, contiguous, and cover `0..w.rows`.
pub fn csr_sdmm_ranges(
    w: &CsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
) {
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        csr_sdmm(w, i, o, n);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(r0, r1) in ranges {
            assert_eq!(r0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            scope.spawn(move || csr_rows_into(w, i, chunk, n, r0));
            rest = tail;
            row = r1;
        }
        assert_eq!(row, w.rows, "ranges must cover all rows");
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::gemm_naive;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(200);
        for &(m, k, n, sp) in &[(16usize, 32usize, 8usize, 0.5f64), (33, 65, 13, 0.75)] {
            let w = CsrMatrix::random_row_uniform(m, k, sp, &mut rng);
            let i = rng.normal_vec_f32(k * n, 1.0);
            let mut o = vec![0.0; m * n];
            csr_sdmm(&w, &i, &mut o, n);
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
            for (a, b) in o.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(201);
        let (m, k, n) = (40, 64, 16);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut o1, n);
        csr_sdmm_parallel(&w, &i, &mut o2, n, 3);
        assert_eq!(o1, o2);
    }

    #[test]
    fn ranges_match_serial() {
        let mut rng = Rng::new(202);
        let (m, k, n) = (37, 48, 11);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut o1, n);
        let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, 4);
        csr_sdmm_ranges(&w, &i, &mut o2, n, &ranges);
        assert_eq!(o1, o2);
    }

    #[test]
    fn empty_rows_produce_zeros() {
        let w = CsrMatrix::from_dense(&[0.0, 0.0, 1.0, 0.0], 2, 2);
        let i = vec![1.0, 2.0, 3.0, 4.0];
        let mut o = vec![9.0; 4];
        csr_sdmm(&w, &i, &mut o, 2);
        assert_eq!(o, vec![0.0, 0.0, 1.0, 2.0]);
    }
}
