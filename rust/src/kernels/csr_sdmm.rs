//! Unstructured SDMM: `O = W_csr · I` (the cuSparse-CSR stand-in).
//!
//! This kernel has the access pattern the paper's §5 motivates against:
//! every non-zero triggers a *gathered* row of `I` — no reuse across rows,
//! no tile skipping, index storage read alongside every value.

use crate::sparsity::csr::CsrMatrix;
use crate::util::threadpool::parallel_rows;

/// Row-by-row CSR SDMM. `i` is (cols × n) row-major, `o` is (rows × n).
pub fn csr_sdmm(w: &CsrMatrix, i: &[f32], o: &mut [f32], n: usize) {
    assert_eq!(i.len(), w.cols * n);
    assert_eq!(o.len(), w.rows * n);
    o.fill(0.0);
    for r in 0..w.rows {
        let orow = &mut o[r * n..(r + 1) * n];
        for k in w.indptr[r]..w.indptr[r + 1] {
            let a = w.values[k];
            let irow = &i[w.indices[k] * n..w.indices[k] * n + n];
            for c in 0..n {
                orow[c] += a * irow[c];
            }
        }
    }
}

/// Rows `[row0, row0+rows)` of the product, written into `chunk`
/// (`rows × n`, already zeroed by the caller or zeroed here).
fn csr_rows_into(w: &CsrMatrix, i: &[f32], chunk: &mut [f32], n: usize, row0: usize) {
    chunk.fill(0.0);
    let rows = chunk.len() / n.max(1);
    for r in 0..rows {
        let orow = &mut chunk[r * n..(r + 1) * n];
        let wr = row0 + r;
        for k in w.indptr[wr]..w.indptr[wr + 1] {
            let a = w.values[k];
            let irow = &i[w.indices[k] * n..w.indices[k] * n + n];
            for c in 0..n {
                orow[c] += a * irow[c];
            }
        }
    }
}

/// Parallel CSR SDMM over disjoint output-row chunks (even row split).
pub fn csr_sdmm_parallel(w: &CsrMatrix, i: &[f32], o: &mut [f32], n: usize, threads: usize) {
    assert_eq!(o.len(), w.rows * n);
    parallel_rows(o, w.rows, n, threads, |row0, chunk| {
        csr_rows_into(w, i, chunk, n, row0);
    });
}

/// Parallel CSR SDMM over precomputed contiguous row `ranges` (one worker
/// per range) — the plan-based execute path, where ranges were balanced by
/// non-zero count at plan-build time instead of split evenly per call.
/// `ranges` must be ascending, contiguous, and cover `0..w.rows`.
pub fn csr_sdmm_ranges(
    w: &CsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
) {
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        csr_sdmm(w, i, o, n);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(r0, r1) in ranges {
            assert_eq!(r0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            scope.spawn(move || csr_rows_into(w, i, chunk, n, r0));
            rest = tail;
            row = r1;
        }
        assert_eq!(row, w.rows, "ranges must cover all rows");
    });
}

/// Rows `[row0, row0+rows)` of the product with the output columns walked
/// in `col_block`-wide blocks (col blocks outer, rows inner) so each block
/// of gathered `I` columns stays cache-resident across the chunk's rows.
/// Bit-identical to [`csr_rows_into`]: for any output element the non-zeros
/// are accumulated in the same `k` order — blocking only reorders *which
/// elements* are visited, never the reduction within one.
fn csr_rows_into_blocked(
    w: &CsrMatrix,
    i: &[f32],
    chunk: &mut [f32],
    n: usize,
    row0: usize,
    col_block: usize,
) {
    let rows = chunk.len() / n.max(1);
    let mut c0 = 0;
    while c0 < n {
        let cb = col_block.min(n - c0);
        for r in 0..rows {
            let obase = r * n + c0;
            let orow = &mut chunk[obase..obase + cb];
            orow.fill(0.0);
            let wr = row0 + r;
            for k in w.indptr[wr]..w.indptr[wr + 1] {
                let a = w.values[k];
                let ibase = w.indices[k] * n + c0;
                let irow = &i[ibase..ibase + cb];
                for c in 0..cb {
                    orow[c] += a * irow[c];
                }
            }
        }
        c0 += cb;
    }
}

/// [`csr_sdmm_ranges`] with an output column block width — the autotuned
/// execute path. `col_block == 0` (or ≥ `n`) means unblocked and delegates
/// to the plain ranges kernel.
pub fn csr_sdmm_ranges_blocked(
    w: &CsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
    col_block: usize,
) {
    if col_block == 0 || col_block >= n {
        csr_sdmm_ranges(w, i, o, n, ranges);
        return;
    }
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        let row0 = ranges.first().map(|r| r.0).unwrap_or(0);
        csr_rows_into_blocked(w, i, o, n, row0, col_block);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(r0, r1) in ranges {
            assert_eq!(r0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            scope.spawn(move || csr_rows_into_blocked(w, i, chunk, n, r0, col_block));
            rest = tail;
            row = r1;
        }
        assert_eq!(row, w.rows, "ranges must cover all rows");
    });
}

/// Rows `[row0, row0+rows)` with the per-row reduction *fanned* into
/// `fan`-wide groups of interleaved partial products combined as a
/// balanced tree (`(a0·x0 + a1·x1) + (a2·x2 + a3·x3)` for fan 4). This
/// **re-associates the sum** — outputs are close to, but not bit-identical
/// with, [`csr_rows_into`] — which is why fanned schedules are only ever
/// admitted through the tolerance-gated search (`PlanRequest::reduce_tol`).
/// The payoff is ILP: `fan` independent FMA chains per output column
/// instead of one serial dependency chain.
fn csr_rows_into_fanned(w: &CsrMatrix, i: &[f32], chunk: &mut [f32], n: usize, row0: usize, fan: usize) {
    chunk.fill(0.0);
    let rows = chunk.len() / n.max(1);
    let irow = |k: usize| &i[w.indices[k] * n..w.indices[k] * n + n];
    for r in 0..rows {
        let orow = &mut chunk[r * n..(r + 1) * n];
        let wr = row0 + r;
        let (mut k, k1) = (w.indptr[wr], w.indptr[wr + 1]);
        if fan >= 4 {
            while k + 4 <= k1 {
                let (a0, a1, a2, a3) = (
                    w.values[k],
                    w.values[k + 1],
                    w.values[k + 2],
                    w.values[k + 3],
                );
                let (x0, x1, x2, x3) = (irow(k), irow(k + 1), irow(k + 2), irow(k + 3));
                for c in 0..n {
                    orow[c] += (a0 * x0[c] + a1 * x1[c]) + (a2 * x2[c] + a3 * x3[c]);
                }
                k += 4;
            }
        }
        while k + 2 <= k1 {
            let (a0, a1) = (w.values[k], w.values[k + 1]);
            let (x0, x1) = (irow(k), irow(k + 1));
            for c in 0..n {
                orow[c] += a0 * x0[c] + a1 * x1[c];
            }
            k += 2;
        }
        while k < k1 {
            let a = w.values[k];
            let x = irow(k);
            for c in 0..n {
                orow[c] += a * x[c];
            }
            k += 1;
        }
    }
}

/// The full plan-based execute path: [`csr_sdmm_ranges_blocked`] when
/// `fan <= 1` (the strict bit-identical schedules), otherwise the
/// accumulator-fanned kernel over the same balanced ranges. The candidate
/// generator never pairs `fan > 1` with column blocking, so the fanned
/// path runs unblocked.
pub fn csr_sdmm_ranges_fanned(
    w: &CsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
    col_block: usize,
    fan: usize,
) {
    if fan <= 1 {
        csr_sdmm_ranges_blocked(w, i, o, n, ranges, col_block);
        return;
    }
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        let row0 = ranges.first().map(|r| r.0).unwrap_or(0);
        csr_rows_into_fanned(w, i, o, n, row0, fan);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(r0, r1) in ranges {
            assert_eq!(r0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((r1 - r0) * n);
            scope.spawn(move || csr_rows_into_fanned(w, i, chunk, n, r0, fan));
            rest = tail;
            row = r1;
        }
        assert_eq!(row, w.rows, "ranges must cover all rows");
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::gemm_naive;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(200);
        for &(m, k, n, sp) in &[(16usize, 32usize, 8usize, 0.5f64), (33, 65, 13, 0.75)] {
            let w = CsrMatrix::random_row_uniform(m, k, sp, &mut rng);
            let i = rng.normal_vec_f32(k * n, 1.0);
            let mut o = vec![0.0; m * n];
            csr_sdmm(&w, &i, &mut o, n);
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
            for (a, b) in o.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(201);
        let (m, k, n) = (40, 64, 16);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut o1, n);
        csr_sdmm_parallel(&w, &i, &mut o2, n, 3);
        assert_eq!(o1, o2);
    }

    #[test]
    fn ranges_match_serial() {
        let mut rng = Rng::new(202);
        let (m, k, n) = (37, 48, 11);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut o1, n);
        let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, 4);
        csr_sdmm_ranges(&w, &i, &mut o2, n, &ranges);
        assert_eq!(o1, o2);
    }

    #[test]
    fn col_blocked_ranges_bit_identical_to_unblocked() {
        let mut rng = Rng::new(204);
        let (m, k, n) = (37, 48, 19);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut reference = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut reference, n);
        for threads in [1usize, 4] {
            let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, threads);
            // col_block that divides n, one that doesn't, and the 0/≥n
            // delegating cases.
            for cb in [0usize, 1, 7, 16, 19, 64] {
                let mut o = vec![9.0; m * n];
                csr_sdmm_ranges_blocked(&w, &i, &mut o, n, &ranges, cb);
                assert_eq!(o, reference, "threads={threads} cb={cb}");
            }
        }
    }

    #[test]
    fn empty_rows_produce_zeros() {
        let w = CsrMatrix::from_dense(&[0.0, 0.0, 1.0, 0.0], 2, 2);
        let i = vec![1.0, 2.0, 3.0, 4.0];
        let mut o = vec![9.0; 4];
        csr_sdmm(&w, &i, &mut o, 2);
        assert_eq!(o, vec![0.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn fan_one_delegates_bit_identical() {
        let mut rng = Rng::new(205);
        let (m, k, n) = (37, 48, 13);
        let w = CsrMatrix::random_row_uniform(m, k, 0.75, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut reference = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut reference, n);
        let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, 3);
        for fan in [0usize, 1] {
            let mut o = vec![9.0; m * n];
            csr_sdmm_ranges_fanned(&w, &i, &mut o, n, &ranges, 0, fan);
            assert_eq!(o, reference, "fan={fan}");
        }
    }

    #[test]
    fn fanned_matches_serial_within_tolerance_and_is_deterministic() {
        let mut rng = Rng::new(206);
        let (m, k, n) = (41, 64, 17);
        let w = CsrMatrix::random_row_uniform(m, k, 0.6, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut reference = vec![0.0; m * n];
        csr_sdmm(&w, &i, &mut reference, n);
        for threads in [1usize, 4] {
            let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, threads);
            for fan in [2usize, 4] {
                let mut o1 = vec![9.0; m * n];
                let mut o2 = vec![9.0; m * n];
                csr_sdmm_ranges_fanned(&w, &i, &mut o1, n, &ranges, 0, fan);
                csr_sdmm_ranges_fanned(&w, &i, &mut o2, n, &ranges, 0, fan);
                // Re-associated, so close-not-equal vs the strict order...
                for (a, b) in o1.iter().zip(&reference) {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "threads={threads} fan={fan}: {a} vs {b}"
                    );
                }
                // ...but the fanned schedule itself is deterministic.
                assert_eq!(o1, o2, "threads={threads} fan={fan}");
            }
        }
    }
}
