//! Block SDMM: `O = W_bsr · I` (the cuSparse-BSR stand-in, Table 1 "Block").
//!
//! Block structure buys back regularity: each non-zero block is a dense
//! (bh × bw)·(bw × n) mini-GEMM, so values stream and `I` rows are reused
//! `bh` times — but there is no clone pattern or row repetition to exploit
//! beyond the block, which is exactly the gap RBGP4 closes.

use crate::sparsity::bsr::BsrMatrix;
use crate::util::threadpool::parallel_rows;

/// Serial BSR SDMM. `i` is (cols × n) row-major, `o` is (rows × n).
pub fn bsr_sdmm(w: &BsrMatrix, i: &[f32], o: &mut [f32], n: usize) {
    assert_eq!(i.len(), w.cols * n);
    assert_eq!(o.len(), w.rows * n);
    o.fill(0.0);
    bsr_block_rows(w, i, o, n, 0, w.block_rows());
}

/// Process block rows [br0, br1) of `w`, writing into `o` offset so that
/// block row br0 lands at o[0..]. Shared by serial and parallel drivers.
fn bsr_block_rows(w: &BsrMatrix, i: &[f32], o: &mut [f32], n: usize, br0: usize, br1: usize) {
    let (bh, bw) = (w.bh, w.bw);
    for bi in br0..br1 {
        let obase = (bi - br0) * bh * n;
        for k in w.indptr[bi]..w.indptr[bi + 1] {
            let bj = w.indices[k];
            let blk = &w.values[k * bh * bw..(k + 1) * bh * bw];
            // Dense micro-GEMM: (bh x bw) block times (bw x n) slab of I.
            for br in 0..bh {
                let orow = obase + br * n;
                for bc in 0..bw {
                    let a = blk[br * bw + bc];
                    if a == 0.0 {
                        continue;
                    }
                    let irow = &i[(bj * bw + bc) * n..(bj * bw + bc) * n + n];
                    for c in 0..n {
                        o[orow + c] += a * irow[c];
                    }
                }
            }
        }
    }
}

/// Parallel BSR SDMM over disjoint block-row chunks.
pub fn bsr_sdmm_parallel(w: &BsrMatrix, i: &[f32], o: &mut [f32], n: usize, threads: usize) {
    assert_eq!(o.len(), w.rows * n);
    let row_len = w.bh * n; // one block row of output
    parallel_rows(o, w.block_rows(), row_len, threads, |br0, chunk| {
        chunk.fill(0.0);
        let brs = chunk.len() / row_len;
        bsr_block_rows(w, i, chunk, n, br0, br0 + brs);
    });
}

/// Parallel BSR SDMM over precomputed contiguous block-row `ranges` (one
/// worker per range) — the plan-based execute path with block-balanced
/// partitions. `ranges` must be ascending, contiguous, and cover
/// `0..w.block_rows()`.
pub fn bsr_sdmm_ranges(
    w: &BsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
) {
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        bsr_sdmm(w, i, o, n);
        return;
    }
    let row_len = w.bh * n;
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(br0, br1) in ranges {
            assert_eq!(br0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((br1 - br0) * row_len);
            scope.spawn(move || {
                chunk.fill(0.0);
                bsr_block_rows(w, i, chunk, n, br0, br1);
            });
            rest = tail;
            row = br1;
        }
        assert_eq!(row, w.block_rows(), "ranges must cover all block rows");
    });
}

/// Block rows [br0, br1) with the output columns walked in `col_block`-wide
/// blocks (col blocks outer). Zeroes each column block before accumulating,
/// so callers must NOT pre-zero. Bit-identical to [`bsr_block_rows`]: per
/// output element the `(k, bc)` accumulation order is unchanged.
fn bsr_block_rows_blocked(
    w: &BsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    br0: usize,
    br1: usize,
    col_block: usize,
) {
    let (bh, bw) = (w.bh, w.bw);
    let mut c0 = 0;
    while c0 < n {
        let cb = col_block.min(n - c0);
        for bi in br0..br1 {
            let obase = (bi - br0) * bh * n;
            for br in 0..bh {
                o[obase + br * n + c0..obase + br * n + c0 + cb].fill(0.0);
            }
            for k in w.indptr[bi]..w.indptr[bi + 1] {
                let bj = w.indices[k];
                let blk = &w.values[k * bh * bw..(k + 1) * bh * bw];
                for br in 0..bh {
                    let orow = obase + br * n + c0;
                    for bc in 0..bw {
                        let a = blk[br * bw + bc];
                        if a == 0.0 {
                            continue;
                        }
                        let ibase = (bj * bw + bc) * n + c0;
                        let irow = &i[ibase..ibase + cb];
                        for c in 0..cb {
                            o[orow + c] += a * irow[c];
                        }
                    }
                }
            }
        }
        c0 += cb;
    }
}

/// [`bsr_sdmm_ranges`] with an output column block width — the autotuned
/// execute path. `col_block == 0` (or ≥ `n`) delegates to the plain ranges
/// kernel.
pub fn bsr_sdmm_ranges_blocked(
    w: &BsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
    col_block: usize,
) {
    if col_block == 0 || col_block >= n {
        bsr_sdmm_ranges(w, i, o, n, ranges);
        return;
    }
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        let (br0, br1) = ranges.first().copied().unwrap_or((0, w.block_rows()));
        bsr_block_rows_blocked(w, i, o, n, br0, br1, col_block);
        return;
    }
    let row_len = w.bh * n;
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(br0, br1) in ranges {
            assert_eq!(br0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((br1 - br0) * row_len);
            scope.spawn(move || bsr_block_rows_blocked(w, i, chunk, n, br0, br1, col_block));
            rest = tail;
            row = br1;
        }
        assert_eq!(row, w.block_rows(), "ranges must cover all block rows");
    });
}

/// Block rows [br0, br1) with the per-block `bc` reduction fanned into
/// `fan`-wide groups of interleaved partial products combined as a balanced
/// tree. This **re-associates the inner sum** (and drops the explicit-zero
/// skip, since `a == 0.0` lanes now ride inside a fused group), so it is
/// only reachable through the tolerance-gated search
/// (`PlanRequest::reduce_tol`). Caller must pre-zero `o`.
fn bsr_block_rows_fanned(
    w: &BsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    br0: usize,
    br1: usize,
    fan: usize,
) {
    let (bh, bw) = (w.bh, w.bw);
    let irow = |bj: usize, bc: usize| &i[(bj * bw + bc) * n..(bj * bw + bc) * n + n];
    for bi in br0..br1 {
        let obase = (bi - br0) * bh * n;
        for k in w.indptr[bi]..w.indptr[bi + 1] {
            let bj = w.indices[k];
            let blk = &w.values[k * bh * bw..(k + 1) * bh * bw];
            for br in 0..bh {
                let orow = &mut o[obase + br * n..obase + br * n + n];
                let mut bc = 0;
                if fan >= 4 {
                    while bc + 4 <= bw {
                        let (a0, a1, a2, a3) = (
                            blk[br * bw + bc],
                            blk[br * bw + bc + 1],
                            blk[br * bw + bc + 2],
                            blk[br * bw + bc + 3],
                        );
                        let (x0, x1, x2, x3) = (
                            irow(bj, bc),
                            irow(bj, bc + 1),
                            irow(bj, bc + 2),
                            irow(bj, bc + 3),
                        );
                        for c in 0..n {
                            orow[c] += (a0 * x0[c] + a1 * x1[c]) + (a2 * x2[c] + a3 * x3[c]);
                        }
                        bc += 4;
                    }
                }
                while bc + 2 <= bw {
                    let (a0, a1) = (blk[br * bw + bc], blk[br * bw + bc + 1]);
                    let (x0, x1) = (irow(bj, bc), irow(bj, bc + 1));
                    for c in 0..n {
                        orow[c] += a0 * x0[c] + a1 * x1[c];
                    }
                    bc += 2;
                }
                while bc < bw {
                    let a = blk[br * bw + bc];
                    if a != 0.0 {
                        let x = irow(bj, bc);
                        for c in 0..n {
                            orow[c] += a * x[c];
                        }
                    }
                    bc += 1;
                }
            }
        }
    }
}

/// The full plan-based execute path: [`bsr_sdmm_ranges_blocked`] when
/// `fan <= 1` (the strict bit-identical schedules), otherwise the
/// accumulator-fanned kernel over the same block-balanced ranges. The
/// candidate generator never pairs `fan > 1` with column blocking, so the
/// fanned path runs unblocked.
pub fn bsr_sdmm_ranges_fanned(
    w: &BsrMatrix,
    i: &[f32],
    o: &mut [f32],
    n: usize,
    ranges: &[(usize, usize)],
    col_block: usize,
    fan: usize,
) {
    if fan <= 1 {
        bsr_sdmm_ranges_blocked(w, i, o, n, ranges, col_block);
        return;
    }
    assert_eq!(o.len(), w.rows * n);
    if ranges.len() <= 1 {
        let (br0, br1) = ranges.first().copied().unwrap_or((0, w.block_rows()));
        o.fill(0.0);
        bsr_block_rows_fanned(w, i, o, n, br0, br1, fan);
        return;
    }
    let row_len = w.bh * n;
    std::thread::scope(|scope| {
        let mut rest = o;
        let mut row = 0usize;
        for &(br0, br1) in ranges {
            assert_eq!(br0, row, "ranges must be contiguous");
            let (chunk, tail) = rest.split_at_mut((br1 - br0) * row_len);
            scope.spawn(move || {
                chunk.fill(0.0);
                bsr_block_rows_fanned(w, i, chunk, n, br0, br1, fan);
            });
            rest = tail;
            row = br1;
        }
        assert_eq!(row, w.block_rows(), "ranges must cover all block rows");
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::gemm_naive;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(300);
        for &(m, k, n, sp) in &[(16usize, 16usize, 8usize, 0.5f64), (32, 64, 12, 0.75)] {
            let w = BsrMatrix::random_block_uniform(m, k, 4, 4, sp, &mut rng);
            let i = rng.normal_vec_f32(k * n, 1.0);
            let mut o = vec![0.0; m * n];
            bsr_sdmm(&w, &i, &mut o, n);
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
            for (a, b) in o.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(301);
        let (m, k, n) = (48, 32, 16);
        let w = BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        bsr_sdmm(&w, &i, &mut o1, n);
        bsr_sdmm_parallel(&w, &i, &mut o2, n, 5);
        assert_eq!(o1, o2);
    }

    #[test]
    fn ranges_match_serial() {
        let mut rng = Rng::new(303);
        let (m, k, n) = (48, 32, 9);
        let w = BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        bsr_sdmm(&w, &i, &mut o1, n);
        let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, 3);
        bsr_sdmm_ranges(&w, &i, &mut o2, n, &ranges);
        assert_eq!(o1, o2);
    }

    #[test]
    fn col_blocked_ranges_bit_identical_to_unblocked() {
        let mut rng = Rng::new(304);
        let (m, k, n) = (48, 32, 19);
        let w = BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut reference = vec![0.0; m * n];
        bsr_sdmm(&w, &i, &mut reference, n);
        for threads in [1usize, 3] {
            let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, threads);
            for cb in [0usize, 1, 7, 16, 19, 64] {
                let mut o = vec![9.0; m * n];
                bsr_sdmm_ranges_blocked(&w, &i, &mut o, n, &ranges, cb);
                assert_eq!(o, reference, "threads={threads} cb={cb}");
            }
        }
    }

    #[test]
    fn fan_one_delegates_bit_identical() {
        let mut rng = Rng::new(305);
        let (m, k, n) = (48, 32, 13);
        let w = BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, &mut rng);
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut reference = vec![0.0; m * n];
        bsr_sdmm(&w, &i, &mut reference, n);
        let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, 3);
        for fan in [0usize, 1] {
            let mut o = vec![9.0; m * n];
            bsr_sdmm_ranges_fanned(&w, &i, &mut o, n, &ranges, 0, fan);
            assert_eq!(o, reference, "fan={fan}");
        }
    }

    #[test]
    fn fanned_matches_serial_within_tolerance_and_is_deterministic() {
        let mut rng = Rng::new(306);
        let (m, k, n) = (48, 64, 17);
        // bw = 4 exercises the full fan-4 group; bw = 3 exercises the
        // pair + remainder tail.
        for &(bh, bw) in &[(4usize, 4usize), (2, 3)] {
            let w = BsrMatrix::random_block_uniform(m, k, bh, bw, 0.5, &mut rng);
            let i = rng.normal_vec_f32(k * n, 1.0);
            let mut reference = vec![0.0; m * n];
            bsr_sdmm(&w, &i, &mut reference, n);
            for threads in [1usize, 3] {
                let ranges = crate::kernels::plan::balanced_row_ranges(&w.indptr, threads);
                for fan in [2usize, 4] {
                    let mut o1 = vec![9.0; m * n];
                    let mut o2 = vec![9.0; m * n];
                    bsr_sdmm_ranges_fanned(&w, &i, &mut o1, n, &ranges, 0, fan);
                    bsr_sdmm_ranges_fanned(&w, &i, &mut o2, n, &ranges, 0, fan);
                    for (a, b) in o1.iter().zip(&reference) {
                        assert!(
                            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                            "bw={bw} threads={threads} fan={fan}: {a} vs {b}"
                        );
                    }
                    assert_eq!(o1, o2, "bw={bw} threads={threads} fan={fan}");
                }
            }
        }
    }

    #[test]
    fn non_square_blocks() {
        let mut rng = Rng::new(302);
        let w = BsrMatrix::random_block_uniform(12, 18, 2, 3, 0.5, &mut rng);
        let i = rng.normal_vec_f32(18 * 7, 1.0);
        let mut o = vec![0.0; 12 * 7];
        bsr_sdmm(&w, &i, &mut o, 7);
        let mut oracle = vec![0.0; 12 * 7];
        gemm_naive(&w.to_dense(), &i, &mut oracle, 12, 18, 7);
        for (a, b) in o.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
