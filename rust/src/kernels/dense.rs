//! Dense GEMM baselines (the cuBLAS stand-in for Table 2's 0 %-sparsity row).
//!
//! `gemm_naive` is the correctness oracle; `gemm_blocked` is the
//! cache-blocked implementation used for timing. All matrices are row-major
//! f32: `O (M×N) = W (M×K) · I (K×N)`.

use crate::util::threadpool::parallel_rows;

/// Triple-loop reference GEMM (i-k-j order so the inner loop streams the
/// output row — still the slow oracle, only for tests/small shapes).
pub fn gemm_naive(w: &[f32], i: &[f32], o: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(w.len(), m * k);
    assert_eq!(i.len(), k * n);
    assert_eq!(o.len(), m * n);
    o.fill(0.0);
    for r in 0..m {
        for kk in 0..k {
            let a = w[r * k + kk];
            if a == 0.0 {
                continue;
            }
            let irow = &i[kk * n..(kk + 1) * n];
            let orow = &mut o[r * n..(r + 1) * n];
            for c in 0..n {
                orow[c] += a * irow[c];
            }
        }
    }
}

/// Cache-blocked GEMM: MC×KC panels of W against KC-row slabs of I, with a
/// 4-row micro-kernel that keeps four output rows hot while streaming I.
pub fn gemm_blocked(w: &[f32], inp: &[f32], o: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(w.len(), m * k);
    assert_eq!(inp.len(), k * n);
    assert_eq!(o.len(), m * n);
    o.fill(0.0);
    const MC: usize = 32;
    const KC: usize = 256;
    let mut r0 = 0;
    while r0 < m {
        let mb = MC.min(m - r0);
        let mut k0 = 0;
        while k0 < k {
            let kb = KC.min(k - k0);
            gemm_panel(w, inp, o, r0, mb, k0, kb, k, n);
            k0 += kb;
        }
        r0 += mb;
    }
}

/// One (mb × kb) panel of W times the corresponding slab of I, accumulated
/// into O. Processes rows in groups of 4 for register reuse of I rows.
#[inline]
fn gemm_panel(
    w: &[f32],
    inp: &[f32],
    o: &mut [f32],
    r0: usize,
    mb: usize,
    k0: usize,
    kb: usize,
    k: usize,
    n: usize,
) {
    let mut r = 0;
    while r + 4 <= mb {
        let base = (r0 + r) * k + k0;
        let (w0, w1, w2, w3) = (
            &w[base..base + kb],
            &w[base + k..base + k + kb],
            &w[base + 2 * k..base + 2 * k + kb],
            &w[base + 3 * k..base + 3 * k + kb],
        );
        for kk in 0..kb {
            let (a0, a1, a2, a3) = (w0[kk], w1[kk], w2[kk], w3[kk]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let irow = &inp[(k0 + kk) * n..(k0 + kk + 1) * n];
            let ob = (r0 + r) * n;
            for c in 0..n {
                let x = irow[c];
                o[ob + c] += a0 * x;
                o[ob + n + c] += a1 * x;
                o[ob + 2 * n + c] += a2 * x;
                o[ob + 3 * n + c] += a3 * x;
            }
        }
        r += 4;
    }
    while r < mb {
        let wrow = &w[(r0 + r) * k + k0..(r0 + r) * k + k0 + kb];
        for kk in 0..kb {
            let a = wrow[kk];
            if a == 0.0 {
                continue;
            }
            let irow = &inp[(k0 + kk) * n..(k0 + kk + 1) * n];
            let ob = (r0 + r) * n;
            for c in 0..n {
                o[ob + c] += a * irow[c];
            }
        }
        r += 1;
    }
}

/// `out (M×N) = a (M×K) · bᵀ` where `b` is (N×K) — the gradient-side GEMM
/// (`dW = dY · Xᵀ`) shared by every native training consumer.
pub fn gemm_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for r in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            let ar = &a[r * k..(r + 1) * k];
            let br = &b[j * k..(j + 1) * k];
            for kk in 0..k {
                s += ar[kk] * br[kk];
            }
            out[r * n + j] = s;
        }
    }
}

/// `out (K×N) = aᵀ · b` where `a` is (M×K), `b` is (M×N) — the backprop
/// input-gradient GEMM (`dX = Wᵀ · dY`), zero-skipping on `a` so masked
/// weights cost nothing.
pub fn gemm_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(out.len(), k * n);
    out.fill(0.0);
    for row in 0..m {
        for kk in 0..k {
            let av = a[row * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[row * n..(row + 1) * n];
            let orow = &mut out[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// (rows × cols) row-major → (cols × rows).
pub fn transpose(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * cols);
    let mut t = vec![0.0f32; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = x[r * cols + c];
        }
    }
    t
}

/// Multi-threaded blocked GEMM: row-partitioned (disjoint output chunks).
pub fn gemm_parallel(
    w: &[f32],
    inp: &[f32],
    o: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(o.len(), m * n);
    parallel_rows(o, m, n, threads, |row0, chunk| {
        let rows = chunk.len() / n;
        gemm_blocked(&w[row0 * k..(row0 + rows) * k], inp, chunk, rows, k, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        rng.normal_vec_f32(len, 1.0)
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(100);
        for &(m, k, n) in &[(5usize, 7usize, 9usize), (64, 64, 32), (100, 300, 17), (130, 257, 65)] {
            let w = rand_mat(&mut rng, m * k);
            let i = rand_mat(&mut rng, k * n);
            let mut o1 = vec![0.0; m * n];
            let mut o2 = vec![0.0; m * n];
            gemm_naive(&w, &i, &mut o1, m, k, n);
            gemm_blocked(&w, &i, &mut o2, m, k, n);
            assert_close(&o1, &o2, 1e-4);
        }
    }

    #[test]
    fn parallel_matches_naive() {
        let mut rng = Rng::new(101);
        let (m, k, n) = (97, 128, 33);
        let w = rand_mat(&mut rng, m * k);
        let i = rand_mat(&mut rng, k * n);
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        gemm_naive(&w, &i, &mut o1, m, k, n);
        gemm_parallel(&w, &i, &mut o2, m, k, n, 4);
        assert_close(&o1, &o2, 1e-4);
    }

    #[test]
    fn gemm_helpers_match_naive() {
        let mut rng = Rng::new(30);
        let (m, k, n) = (5, 7, 4);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, n * k);
        let mut out = vec![0.0; m * n];
        gemm_nt(&a, &b, &mut out, m, k, n);
        for r in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a[r * k + kk] * b[j * k + kk]).sum();
                assert!((out[r * n + j] - want).abs() < 1e-4);
            }
        }
        let b2 = rand_mat(&mut rng, m * n);
        let mut out2 = vec![0.0; k * n];
        gemm_tn(&a, &b2, &mut out2, m, k, n);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|r| a[r * k + kk] * b2[r * n + j]).sum();
                assert!((out2[kk * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let t = transpose(&x, 3, 4);
        assert_eq!(transpose(&t, 4, 3), x);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // (0,1) of transposed = (1,0) of original
    }

    #[test]
    fn identity_weight_copies_input() {
        let n = 8;
        let mut w = vec![0.0f32; n * n];
        for d in 0..n {
            w[d * n + d] = 1.0;
        }
        let mut rng = Rng::new(102);
        let i = rand_mat(&mut rng, n * 4);
        let mut o = vec![0.0; n * 4];
        gemm_blocked(&w, &i, &mut o, n, n, 4);
        assert_close(&o, &i, 1e-6);
    }
}
