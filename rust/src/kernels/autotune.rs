//! Roofline-driven plan autotuning: turn `build_plan` from a fixed
//! heuristic into a short empirical search.
//!
//! Three pieces (see ARCHITECTURE.md §Plan autotuning):
//!
//! * **Machine probe** — a one-time, process-cached measurement of the two
//!   numbers a roofline needs: sustainable memory bandwidth (STREAM-style
//!   triad over arrays larger than the last-level cache) and dense FMA
//!   throughput (multi-accumulator L1-resident loop). Together they fix
//!   `attainable(AI) = min(peak_flops, AI · peak_bw)` — the Sparsity
//!   Roofline (arXiv 2310.00496) against which every plan is scored.
//! * **Candidate generation** — per kernel family, the small schedule
//!   space worth searching: packed-panel column stride, worker count and
//!   packed-vs-gather panel layout for rbgp4mm; row-range granularity and
//!   output column blocking for csr/bsr; dense has a single candidate.
//!   Candidate 0 is always the fixed heuristic (exactly what
//!   [`TuneMode::Off`] builds), so the search can only match or beat it.
//! * **Measured search** — `build_plan` (see `registry::tuned_build`) runs
//!   warmup + timed reps of each candidate on the caller's real batch
//!   class and keeps the fastest, recording a [`TunedConfig`] in the plan.
//!   The [`PlanCache`](crate::kernels::plan::PlanCache) key is unchanged,
//!   so the search runs once per `(structure, shape, batch class,
//!   threads)` and every later resolve reuses the winner for free.
//!
//! **The bit-identity contract**: every candidate a generator emits must
//! produce *bit-identical* output to the heuristic plan at the same thread
//! count — tuning may change the schedule, never the numbers. Safe
//! dimensions: panel stride and column blocking split the batch (n)
//! dimension, not the reduction; row-range granularity moves whole output
//! rows between workers; the gather layout feeds the identical micro-kernels
//! from un-copied input rows; rbgp4 worker counts vary only *within* the
//! parallel regime (each output tile row is computed by exactly one worker
//! in a fixed ko-major order). What is **not** safe — and never generated —
//! is crossing the rbgp4 serial/parallel boundary: the serial kernel
//! reduces vo-major, the threaded one ko-major, and those summation orders
//! differ. `prop_kernels.rs` property-tests the contract.

use crate::kernels::plan::{
    balanced_row_ranges, batch_class, KernelPlan, PlanRequest, PlanState, SparseMatrix,
};
use crate::kernels::rbgp4mm::{Rbgp4Plan, Rbgp4Tunable};
use std::sync::OnceLock;
use std::time::Instant;

/// How much plan-construction time a caller is willing to trade for a
/// better schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TuneMode {
    /// No search, no probe: build exactly the fixed heuristic plan.
    Off,
    /// Small candidate set, 1 warmup + 2 timed reps each (the default —
    /// cheap enough to run inside every warm).
    #[default]
    Quick,
    /// Wider candidate set, 2 warmups + 5 timed reps each.
    Full,
}

impl TuneMode {
    pub fn parse(text: &str) -> anyhow::Result<TuneMode> {
        match text {
            "off" => Ok(TuneMode::Off),
            "quick" => Ok(TuneMode::Quick),
            "full" => Ok(TuneMode::Full),
            other => anyhow::bail!("unknown tune mode '{other}' (expected off|quick|full)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::Quick => "quick",
            TuneMode::Full => "full",
        }
    }
}

/// What the search learned about the winning schedule, recorded inside the
/// [`KernelPlan`] (and therefore in the plan cache, per key).
#[derive(Clone, Debug)]
pub struct TunedConfig {
    /// Human-readable winning parameters (e.g. `stride=256 workers=4
    /// layout=gather`).
    pub params: String,
    /// Measured throughput of the winner on the tuning shape.
    pub gflops: f64,
    /// `gflops / attainable(AI)` against the machine probe's roofline —
    /// 1.0 means the kernel is at the memory/compute bound for its
    /// arithmetic intensity.
    pub roofline_fraction: f64,
}

/// The two numbers that fix the roofline on this machine.
#[derive(Clone, Copy, Debug)]
pub struct MachineProbe {
    /// Sustainable bandwidth (GB/s) from a STREAM-style triad.
    pub peak_gbps: f64,
    /// Dense FMA throughput (GFLOP/s) from an L1-resident
    /// multiply-accumulate loop.
    pub peak_gflops: f64,
}

impl MachineProbe {
    /// Attainable GFLOP/s at arithmetic intensity `ai` (flops/byte):
    /// `min(peak_flops, ai · peak_bw)`, floored away from zero so fractions
    /// stay finite.
    pub fn attainable_gflops(&self, ai: f64) -> f64 {
        (ai * self.peak_gbps).min(self.peak_gflops).max(1e-9)
    }
}

static PROBE: OnceLock<MachineProbe> = OnceLock::new();

/// The process-wide machine probe, measured on first use (~tens of ms) and
/// cached for the life of the process. Every tuned plan in every cache
/// shares one probe, so roofline fractions are comparable across plans.
pub fn machine_probe() -> &'static MachineProbe {
    PROBE.get_or_init(|| MachineProbe {
        peak_gbps: stream_triad_gbps(),
        peak_gflops: fma_peak_gflops(),
    })
}

/// Best-of-passes timing of `pass`, returning `work / best_seconds`.
fn rate_of(work: f64, mut pass: impl FnMut()) -> f64 {
    pass(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..4 {
        let t0 = Instant::now();
        pass();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    work / best.max(1e-12)
}

/// STREAM triad `a = b + s·c` over arrays sized past the last-level cache;
/// counts 3 streams (two reads, one write — write-allocate traffic is
/// deliberately not charged, matching how the kernels' `bytes_touched`
/// counts output traffic).
fn stream_triad_gbps() -> f64 {
    const LEN: usize = 1 << 21; // 8 MiB per array, 24 MiB working set
    let mut a = vec![0.0f32; LEN];
    let b: Vec<f32> = (0..LEN).map(|i| 1.0 + (i % 13) as f32).collect();
    let c: Vec<f32> = (0..LEN).map(|i| 0.5 + (i % 7) as f32).collect();
    let s = 1.0 + f32::EPSILON;
    let gbps = rate_of(3.0 * 4.0 * LEN as f64, || {
        for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
            *ai = *bi + s * *ci;
        }
        std::hint::black_box(&a);
    });
    gbps / 1e9
}

/// Dense multiply-accumulate peak over an L1-resident buffer with eight
/// independent accumulator lanes (enough ILP for the FMA pipes to fill);
/// 2 flops per element per pass.
fn fma_peak_gflops() -> f64 {
    const LEN: usize = 2048; // 8 KiB, L1-resident
    const INNER: usize = 512;
    let x: Vec<f32> = (0..LEN).map(|i| 1.0 + (i % 9) as f32 * 1e-3).collect();
    let mut acc = [0.0f32; 8];
    let gflops = rate_of(2.0 * (LEN * INNER) as f64, || {
        let mut lanes = [0.0f32; 8];
        for _ in 0..INNER {
            for ch in x.chunks_exact(8) {
                for l in 0..8 {
                    lanes[l] = lanes[l] * 0.999_9 + ch[l];
                }
            }
        }
        for l in 0..8 {
            acc[l] += lanes[l];
        }
        std::hint::black_box(&acc);
    });
    gflops / 1e9
}

/// Warmup/rep counts of the measured search for one tune mode (`None` for
/// [`TuneMode::Off`] — no search at all).
#[derive(Clone, Copy, Debug)]
pub struct SearchBudget {
    pub warmup: usize,
    pub reps: usize,
}

impl SearchBudget {
    pub fn for_mode(mode: TuneMode) -> Option<SearchBudget> {
        match mode {
            TuneMode::Off => None,
            TuneMode::Quick => Some(SearchBudget { warmup: 1, reps: 2 }),
            TuneMode::Full => Some(SearchBudget { warmup: 2, reps: 5 }),
        }
    }
}

/// Best-of-`reps` seconds of `f` under `budget`.
pub fn measure_seconds(
    budget: &SearchBudget,
    mut f: impl FnMut() -> anyhow::Result<()>,
) -> anyhow::Result<f64> {
    for _ in 0..budget.warmup {
        f()?;
    }
    let mut best = f64::INFINITY;
    for _ in 0..budget.reps {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

/// Deterministic non-zero tuning input (BSR skips exact zeros, so the
/// synthetic batch must not contain any).
pub fn synth_input(len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| 0.5 + ((i * 37 + 11) % 23) as f32 / 23.0)
        .collect()
}

/// All labeled candidate plans for `(w, req)`. Candidate 0 is always the
/// fixed heuristic — the exact plan [`TuneMode::Off`] builds — and every
/// candidate is bit-identical to it in output (the contract the module
/// docs spell out and `prop_kernels.rs` enforces). `req.tune` selects the
/// breadth of the space; `Off` returns the heuristic alone.
pub fn candidate_plans(w: &SparseMatrix, req: &PlanRequest) -> Vec<(String, KernelPlan)> {
    let n_class = batch_class(req.n);
    let threads = req.threads.max(1);
    let states = match w {
        SparseMatrix::Dense { .. } => vec![("heuristic".to_string(), PlanState::Dense)],
        SparseMatrix::Csr(m) => ranges_states(&m.indptr, threads, n_class, req.tune),
        SparseMatrix::Bsr(m) => ranges_states(&m.indptr, threads, n_class, req.tune),
        SparseMatrix::Rbgp4(m) => rbgp4_states(&m.mask, n_class, threads, req.tune),
    };
    states
        .into_iter()
        .map(|(label, state)| {
            (
                label,
                KernelPlan {
                    pattern: w.pattern(),
                    rows: w.rows(),
                    cols: w.cols(),
                    batch_class: n_class,
                    threads,
                    build_seconds: 0.0,
                    tuned: None,
                    state,
                },
            )
        })
        .collect()
}

/// CSR/BSR candidate space: row-range granularity (worker counts ≤
/// `threads` — any partition is bit-identical, the per-row reduction order
/// never changes) × output column blocking (0 = unblocked full width).
fn ranges_states(
    indptr: &[usize],
    threads: usize,
    n_class: usize,
    mode: TuneMode,
) -> Vec<(String, PlanState)> {
    let mut worker_counts = vec![threads];
    let mut col_blocks = vec![0usize];
    match mode {
        TuneMode::Off => {}
        TuneMode::Quick => {
            if threads > 1 {
                worker_counts.push((threads / 2).max(1));
            }
            if 256 < n_class {
                col_blocks.push(256);
            }
        }
        TuneMode::Full => {
            if threads > 1 {
                worker_counts.push((threads / 2).max(1));
                worker_counts.push(1);
            }
            for cb in [512usize, 256, 128, 64] {
                if cb < n_class {
                    col_blocks.push(cb);
                }
            }
        }
    }
    let mut out: Vec<(String, PlanState)> = Vec::new();
    for &wk in &worker_counts {
        let ranges = balanced_row_ranges(indptr, wk);
        for &cb in &col_blocks {
            let dup = out.iter().any(|(_, s)| match s {
                PlanState::Ranges {
                    ranges: r,
                    col_block,
                } => *r == ranges && *col_block == cb,
                _ => false,
            });
            if !dup {
                out.push((
                    format!("ranges={} colblock={cb}", ranges.len().max(1)),
                    PlanState::Ranges {
                        ranges: ranges.clone(),
                        col_block: cb,
                    },
                ));
            }
        }
    }
    out
}

/// RBGP4 candidate space: packed-panel column stride (n-dimension blocking
/// only — reduction order untouched), worker count, and packed-vs-gather
/// panel layout (identical micro-kernels over un-copied input rows).
/// Worker candidates never cross the serial/parallel boundary: when the
/// heuristic runs parallel (≥ 2 workers) every candidate stays ≥ 2, and a
/// serial heuristic admits no worker variation — the two regimes reduce in
/// different orders (vo-major vs ko-major) and are not bit-compatible.
fn rbgp4_states(
    mask: &crate::sparsity::rbgp4::Rbgp4Mask,
    n_class: usize,
    threads: usize,
    mode: TuneMode,
) -> Vec<(String, PlanState)> {
    let base = Rbgp4Tunable::heuristic(mask, n_class, threads);
    let mut tunables = vec![base];
    let push = |v: &mut Vec<Rbgp4Tunable>, t: Rbgp4Tunable| {
        if !v.contains(&t) {
            v.push(t);
        }
    };
    let mut strides = vec![base.stride];
    let mut workers = vec![base.workers];
    let mut gathers = vec![false];
    match mode {
        TuneMode::Off => {}
        TuneMode::Quick => {
            if base.stride >= 2 {
                strides.push(base.stride / 2);
            }
            gathers.push(true);
        }
        TuneMode::Full => {
            if base.stride >= 2 {
                strides.push(base.stride / 2);
            }
            if base.stride >= 4 {
                strides.push(base.stride / 4);
            }
            if base.stride * 2 <= n_class {
                strides.push(base.stride * 2);
            }
            if base.workers >= 4 {
                workers.push((base.workers / 2).max(2));
            }
            gathers.push(true);
        }
    }
    for &stride in &strides {
        for &wk in &workers {
            for &gather in &gathers {
                push(
                    &mut tunables,
                    Rbgp4Tunable {
                        stride,
                        workers: wk,
                        gather,
                    },
                );
            }
        }
    }
    tunables
        .into_iter()
        .map(|t| {
            (
                format!(
                    "stride={} workers={} layout={}",
                    t.stride,
                    t.workers,
                    if t.gather { "gather" } else { "packed" }
                ),
                PlanState::Rbgp4(Box::new(Rbgp4Plan::build_tuned(mask, n_class, &t))),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::csr::CsrMatrix;
    use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
    use crate::util::rng::Rng;

    fn rbgp4_matrix(seed: u64) -> SparseMatrix {
        let cfg = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(cfg, &mut rng).unwrap();
        SparseMatrix::Rbgp4(Rbgp4Matrix::random(mask, &mut rng))
    }

    #[test]
    fn tune_mode_parses_and_defaults_to_quick() {
        assert_eq!(TuneMode::parse("off").unwrap(), TuneMode::Off);
        assert_eq!(TuneMode::parse("quick").unwrap(), TuneMode::Quick);
        assert_eq!(TuneMode::parse("full").unwrap(), TuneMode::Full);
        assert!(TuneMode::parse("fast").is_err());
        assert_eq!(TuneMode::default(), TuneMode::Quick);
        assert_eq!(TuneMode::Full.name(), "full");
    }

    #[test]
    fn probe_is_finite_positive_and_cached() {
        let p1 = machine_probe();
        assert!(p1.peak_gbps.is_finite() && p1.peak_gbps > 0.0);
        assert!(p1.peak_gflops.is_finite() && p1.peak_gflops > 0.0);
        let p2 = machine_probe();
        assert!(std::ptr::eq(p1, p2), "probe measured once per process");
        // The roofline is the min of the two bounds.
        let low_ai = p1.attainable_gflops(1e-6);
        assert!(low_ai <= p1.peak_gflops);
        assert!(p1.attainable_gflops(1e9) <= p1.peak_gflops + 1e-9);
    }

    #[test]
    fn off_mode_yields_exactly_the_heuristic() {
        let mut rng = Rng::new(7);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng));
        for threads in [1usize, 4] {
            let req = PlanRequest::new(8, threads).with_tune(TuneMode::Off);
            let cands = candidate_plans(&w, &req);
            assert_eq!(cands.len(), 1, "Off searches nothing");
        }
        let cands = candidate_plans(&rbgp4_matrix(8), &PlanRequest::new(8, 4).with_tune(TuneMode::Off));
        assert_eq!(cands.len(), 1);
    }

    #[test]
    fn quick_and_full_widen_the_space_first_is_heuristic() {
        let w = rbgp4_matrix(9);
        let quick = candidate_plans(&w, &PlanRequest::new(64, 4));
        let full = candidate_plans(&w, &PlanRequest::new(64, 4).with_tune(TuneMode::Full));
        assert!(quick.len() > 1, "quick explores: {}", quick.len());
        assert!(full.len() >= quick.len(), "full at least as wide");
        let off = candidate_plans(&w, &PlanRequest::new(64, 4).with_tune(TuneMode::Off));
        assert_eq!(quick[0].0, off[0].0, "candidate 0 is the heuristic");
    }

    #[test]
    fn rbgp4_candidates_never_cross_the_serial_parallel_boundary() {
        let w = rbgp4_matrix(10);
        // Parallel heuristic (threads > 1): every candidate keeps ≥ 2 workers.
        for (label, plan) in candidate_plans(&w, &PlanRequest::new(32, 4).with_tune(TuneMode::Full)) {
            if let crate::kernels::plan::PlanState::Rbgp4(p) = &plan.state {
                assert!(p.threads() >= 2, "{label} fell back to serial");
            } else {
                panic!("rbgp4 candidate with non-rbgp4 state");
            }
        }
        // Serial heuristic (threads == 1): every candidate stays serial.
        for (label, plan) in candidate_plans(&w, &PlanRequest::new(32, 1).with_tune(TuneMode::Full)) {
            if let crate::kernels::plan::PlanState::Rbgp4(p) = &plan.state {
                assert_eq!(p.threads(), 1, "{label} escaped the serial regime");
            }
        }
    }

    #[test]
    fn ranges_candidates_respect_thread_cap_and_dedup() {
        let mut rng = Rng::new(11);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(32, 32, 0.75, &mut rng));
        let cands = candidate_plans(&w, &PlanRequest::new(512, 4).with_tune(TuneMode::Full));
        let mut seen = std::collections::HashSet::new();
        for (label, plan) in &cands {
            if let crate::kernels::plan::PlanState::Ranges { ranges, col_block } = &plan.state {
                assert!(ranges.len() <= 4, "{label}: more workers than threads");
                assert!(
                    seen.insert((ranges.clone(), *col_block)),
                    "{label}: duplicate candidate"
                );
            }
        }
        assert!(cands.len() > 1);
    }

    #[test]
    fn synth_input_is_nonzero() {
        assert!(synth_input(1000).iter().all(|&x| x != 0.0));
    }
}
