//! Roofline-driven plan autotuning: turn `build_plan` from a fixed
//! heuristic into a short empirical search.
//!
//! Three pieces (see ARCHITECTURE.md §Plan autotuning):
//!
//! * **Machine probe** — a one-time, process-cached measurement of the two
//!   numbers a roofline needs: sustainable memory bandwidth (STREAM-style
//!   triad over arrays larger than the last-level cache) and dense FMA
//!   throughput (multi-accumulator L1-resident loop). Together they fix
//!   `attainable(AI) = min(peak_flops, AI · peak_bw)` — the Sparsity
//!   Roofline (arXiv 2310.00496) against which every plan is scored.
//! * **Candidate generation** — per kernel family, the small schedule
//!   space worth searching: packed-panel column stride, worker count and
//!   packed-vs-gather panel layout for rbgp4mm; row-range granularity and
//!   output column blocking for csr/bsr; dense has a single candidate.
//!   Candidate 0 is always the fixed heuristic (exactly what
//!   [`TuneMode::Off`] builds), so the search can only match or beat it.
//! * **Measured search** — `build_plan` (see `registry::tuned_build`) runs
//!   warmup + timed reps of each candidate on the caller's real batch
//!   class and keeps the fastest, recording a [`TunedConfig`] in the plan.
//!   The [`PlanCache`](crate::kernels::plan::PlanCache) key is unchanged,
//!   so the search runs once per `(structure, shape, batch class,
//!   threads)` and every later resolve reuses the winner for free.
//!
//! **The bit-identity contract**: every candidate a generator emits must
//! produce *bit-identical* output to the heuristic plan at the same thread
//! count — tuning may change the schedule, never the numbers. Safe
//! dimensions: panel stride and column blocking split the batch (n)
//! dimension, not the reduction; row-range granularity moves whole output
//! rows between workers; the gather layout feeds the identical micro-kernels
//! from un-copied input rows; rbgp4 worker counts vary only *within* the
//! parallel regime (each output tile row is computed by exactly one worker
//! in a fixed ko-major order). What is **not** safe — and never generated —
//! is crossing the rbgp4 serial/parallel boundary: the serial kernel
//! reduces vo-major, the threaded one ko-major, and those summation orders
//! differ. `prop_kernels.rs` property-tests the contract.
//!
//! **Tolerance-gated reduction schedules** relax that contract *only on
//! request*: [`PlanRequest::with_reduce_tol`](crate::kernels::plan::PlanRequest)
//! admits candidates that re-associate the inner sum — k-split partial-sum
//! trees for rbgp4 panels, accumulator fanning for csr/bsr rows — and
//! `tuned_build` validates each one against the heuristic plan's output at
//! search time, rejecting (and counting, see [`tolerance_rejections`]) any
//! candidate whose absolute+relative error exceeds the caller's tolerance.
//! With the knob off (the default) no reduction-reordering candidate is
//! ever generated and PR 6's bit-identity contract is untouched.
//!
//! **Persistence** ([`TuneCache`]): tuned winners serialize to a versioned
//! JSON file keyed by `(family, structure hash, shape, batch class,
//! threads, probe fingerprint)`. [`MachineProbe::fingerprint`] buckets the
//! probe's GB/s and GFLOP/s into quarter-octave steps so run-to-run jitter
//! doesn't fork keys, while a genuinely different machine (or a badly
//! contended one) misses and re-measures. Writes are atomic
//! (tmp + rename) and reads fail soft — a truncated, garbage or
//! version-skewed file behaves like an empty cache, never a panic.

use crate::kernels::plan::{
    balanced_row_ranges, batch_class, KernelPlan, PlanRequest, PlanState, SparseMatrix,
};
use crate::kernels::rbgp4mm::{Rbgp4Plan, Rbgp4Tunable};
use crate::util::json::Json;
use crate::util::lock_recover;
use std::cell::Cell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// How much plan-construction time a caller is willing to trade for a
/// better schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TuneMode {
    /// No search, no probe: build exactly the fixed heuristic plan.
    Off,
    /// Small candidate set, 1 warmup + 2 timed reps each (the default —
    /// cheap enough to run inside every warm).
    #[default]
    Quick,
    /// Wider candidate set, 2 warmups + 5 timed reps each.
    Full,
}

impl TuneMode {
    pub fn parse(text: &str) -> anyhow::Result<TuneMode> {
        match text {
            "off" => Ok(TuneMode::Off),
            "quick" => Ok(TuneMode::Quick),
            "full" => Ok(TuneMode::Full),
            other => anyhow::bail!("unknown tune mode '{other}' (expected off|quick|full)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::Quick => "quick",
            TuneMode::Full => "full",
        }
    }
}

/// What the search learned about the winning schedule, recorded inside the
/// [`KernelPlan`] (and therefore in the plan cache, per key).
#[derive(Clone, Debug)]
pub struct TunedConfig {
    /// Human-readable winning parameters (e.g. `stride=256 workers=4
    /// layout=gather`).
    pub params: String,
    /// Measured throughput of the winner on the tuning shape.
    pub gflops: f64,
    /// `gflops / attainable(AI)` against the machine probe's roofline —
    /// 1.0 means the kernel is at the memory/compute bound for its
    /// arithmetic intensity.
    pub roofline_fraction: f64,
}

/// The two numbers that fix the roofline on this machine.
#[derive(Clone, Copy, Debug)]
pub struct MachineProbe {
    /// Sustainable bandwidth (GB/s) from a STREAM-style triad.
    pub peak_gbps: f64,
    /// Dense FMA throughput (GFLOP/s) from an L1-resident
    /// multiply-accumulate loop.
    pub peak_gflops: f64,
}

impl MachineProbe {
    /// Attainable GFLOP/s at arithmetic intensity `ai` (flops/byte):
    /// `min(peak_flops, ai · peak_bw)`, floored away from zero so fractions
    /// stay finite.
    pub fn attainable_gflops(&self, ai: f64) -> f64 {
        (ai * self.peak_gbps).min(self.peak_gflops).max(1e-9)
    }

    /// Stable identity of this machine for [`TuneCache`] keying: both probe
    /// numbers bucketed to quarter-octave (log₂/4 ≈ ±9%) steps, so normal
    /// run-to-run jitter maps to the same fingerprint while a different
    /// machine — or one probed under heavy contention — forks the key and
    /// forces a fresh measurement instead of trusting stale winners.
    pub fn fingerprint(&self) -> String {
        let bucket = |x: f64| (x.max(1e-9).log2() * 4.0).round() as i64;
        format!("bw{}f{}", bucket(self.peak_gbps), bucket(self.peak_gflops))
    }
}

static PROBE: OnceLock<MachineProbe> = OnceLock::new();

/// The process-wide machine probe, measured on first use (~tens of ms) and
/// cached for the life of the process. Every tuned plan in every cache
/// shares one probe, so roofline fractions are comparable across plans.
pub fn machine_probe() -> &'static MachineProbe {
    PROBE.get_or_init(|| MachineProbe {
        peak_gbps: stream_triad_gbps(),
        peak_gflops: fma_peak_gflops(),
    })
}

/// Best-of-passes timing of `pass`, returning `work / best_seconds`.
fn rate_of(work: f64, mut pass: impl FnMut()) -> f64 {
    pass(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..4 {
        let t0 = Instant::now();
        pass();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    work / best.max(1e-12)
}

/// STREAM triad `a = b + s·c` over arrays sized past the last-level cache;
/// counts 3 streams (two reads, one write — write-allocate traffic is
/// deliberately not charged, matching how the kernels' `bytes_touched`
/// counts output traffic).
fn stream_triad_gbps() -> f64 {
    const LEN: usize = 1 << 21; // 8 MiB per array, 24 MiB working set
    let mut a = vec![0.0f32; LEN];
    let b: Vec<f32> = (0..LEN).map(|i| 1.0 + (i % 13) as f32).collect();
    let c: Vec<f32> = (0..LEN).map(|i| 0.5 + (i % 7) as f32).collect();
    let s = 1.0 + f32::EPSILON;
    let gbps = rate_of(3.0 * 4.0 * LEN as f64, || {
        for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
            *ai = *bi + s * *ci;
        }
        std::hint::black_box(&a);
    });
    gbps / 1e9
}

/// Dense multiply-accumulate peak over an L1-resident buffer with eight
/// independent accumulator lanes (enough ILP for the FMA pipes to fill);
/// 2 flops per element per pass.
fn fma_peak_gflops() -> f64 {
    const LEN: usize = 2048; // 8 KiB, L1-resident
    const INNER: usize = 512;
    let x: Vec<f32> = (0..LEN).map(|i| 1.0 + (i % 9) as f32 * 1e-3).collect();
    let mut acc = [0.0f32; 8];
    let gflops = rate_of(2.0 * (LEN * INNER) as f64, || {
        let mut lanes = [0.0f32; 8];
        for _ in 0..INNER {
            for ch in x.chunks_exact(8) {
                for l in 0..8 {
                    lanes[l] = lanes[l] * 0.999_9 + ch[l];
                }
            }
        }
        for l in 0..8 {
            acc[l] += lanes[l];
        }
        std::hint::black_box(&acc);
    });
    gflops / 1e9
}

/// Warmup/rep counts of the measured search for one tune mode (`None` for
/// [`TuneMode::Off`] — no search at all).
#[derive(Clone, Copy, Debug)]
pub struct SearchBudget {
    pub warmup: usize,
    pub reps: usize,
}

impl SearchBudget {
    pub fn for_mode(mode: TuneMode) -> Option<SearchBudget> {
        match mode {
            TuneMode::Off => None,
            TuneMode::Quick => Some(SearchBudget { warmup: 1, reps: 2 }),
            TuneMode::Full => Some(SearchBudget { warmup: 2, reps: 5 }),
        }
    }
}

thread_local! {
    /// Measurement executions (warmup + timed) this thread has performed
    /// inside `measure_seconds_with` — the observable the warm-cache
    /// property tests assert on: a populated [`TuneCache`] must build every
    /// plan without a single rep. Thread-local because searches run on the
    /// calling thread and a process-global counter would race under
    /// cargo's parallel test harness.
    static SEARCH_REPS: Cell<usize> = const { Cell::new(0) };
    /// Tolerance-gated candidates rejected on this thread because their
    /// search-time validation error exceeded the caller's `reduce_tol`.
    static TOL_REJECTIONS: Cell<usize> = const { Cell::new(0) };
}

/// Total measurement executions (warmup + timed reps) performed on the
/// calling thread since it started. Snapshot before/after a `build_plan`
/// to count what one search cost — zero across a warm-cache build.
pub fn search_reps() -> usize {
    SEARCH_REPS.with(|c| c.get())
}

/// Tolerance-gated candidates rejected on the calling thread because they
/// exceeded the configured reduction tolerance (see
/// `PlanRequest::with_reduce_tol`).
pub fn tolerance_rejections() -> usize {
    TOL_REJECTIONS.with(|c| c.get())
}

pub(crate) fn count_tolerance_rejection() {
    TOL_REJECTIONS.with(|c| c.set(c.get() + 1));
}

/// Best-of-`reps` seconds of `f` under `budget`, timed by the real clock.
pub fn measure_seconds(
    budget: &SearchBudget,
    f: impl FnMut() -> anyhow::Result<()>,
) -> anyhow::Result<f64> {
    let mut last = Instant::now();
    measure_seconds_with(budget, f, || {
        let now = Instant::now();
        let dt = now.duration_since(last).as_secs_f64();
        last = now;
        dt
    })
}

/// Best-of-`reps` scoring core with an injectable timer: `clock()` is
/// called after each timed rep and must return the seconds elapsed since
/// the previous call (the rep's duration). **Min**, not mean, of reps is
/// the score — standard for cycle-accurate timing, because preemption and
/// cache pollution only ever add time, so the minimum is the least-noisy
/// estimate and one descheduled rep cannot crown a slow candidate.
pub fn measure_seconds_with(
    budget: &SearchBudget,
    mut f: impl FnMut() -> anyhow::Result<()>,
    mut clock: impl FnMut() -> f64,
) -> anyhow::Result<f64> {
    for _ in 0..budget.warmup {
        SEARCH_REPS.with(|c| c.set(c.get() + 1));
        f()?;
    }
    let mut best = f64::INFINITY;
    clock(); // reset the elapsed-seconds baseline after warmup
    for _ in 0..budget.reps {
        SEARCH_REPS.with(|c| c.set(c.get() + 1));
        f()?;
        best = best.min(clock());
    }
    Ok(best)
}

/// Deterministic non-zero tuning input (BSR skips exact zeros, so the
/// synthetic batch must not contain any).
pub fn synth_input(len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| 0.5 + ((i * 37 + 11) % 23) as f32 / 23.0)
        .collect()
}

/// Identity of one tuning problem — what a persisted winner is keyed by.
/// Mirrors `PlanKey` (structure + shape + batch class + threads); the probe
/// fingerprint joins at serialization time so one file can carry entries
/// from several machines without cross-contamination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneKey {
    pub family: u8,
    pub structure: u64,
    pub rows: usize,
    pub cols: usize,
    pub batch_class: usize,
    pub threads: usize,
}

impl TuneKey {
    pub fn of(w: &SparseMatrix, req: &PlanRequest) -> TuneKey {
        use crate::sparsity::memory::Pattern;
        let family = match w.pattern() {
            Pattern::Dense => 0,
            Pattern::Unstructured => 1,
            Pattern::Block(_, _) => 2,
            Pattern::Rbgp4 => 3,
        };
        TuneKey {
            family,
            structure: w.structure_hash(),
            rows: w.rows(),
            cols: w.cols(),
            batch_class: batch_class(req.n),
            threads: req.threads.max(1),
        }
    }

    /// The flat string key one entry lives under in the cache file.
    fn entry_key(&self, fingerprint: &str) -> String {
        format!(
            "f{}:{:016x}:{}x{}:b{}:t{}:{}",
            self.family, self.structure, self.rows, self.cols, self.batch_class, self.threads,
            fingerprint
        )
    }
}

/// Cache-file schema version; a file with any other version is ignored
/// wholesale (fail-soft) rather than partially trusted.
const TUNE_CACHE_VERSION: i64 = 1;

/// Persistent store of tuned winners: a versioned JSON file mapping
/// [`TuneKey`] + probe fingerprint to the winning [`TunedConfig`].
/// `tuned_build` consults it before measuring (a hit skips every
/// measurement rep — the warm-cache property) and appends new winners
/// after a search.
///
/// Durability model: [`TuneCache::record`] re-reads the file, merges it
/// under the in-memory entries (memory wins for keys both have, so a
/// concurrent writer's *other* keys survive), writes the merged map to a
/// pid-suffixed temp file and renames it into place — rename is atomic on
/// POSIX, so readers never observe a torn file; racing writers last-wins
/// per batch but never corrupt. Every IO or parse failure degrades to "no
/// cached entry", never an error on the build path.
pub struct TuneCache {
    path: PathBuf,
    fingerprint: String,
    entries: Mutex<BTreeMap<String, TunedConfig>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Winners recorded (and persisted) through this handle.
    stored: AtomicUsize,
    /// Entries in the loaded file that were skipped as malformed.
    rejected_entries: AtomicUsize,
}

impl TuneCache {
    /// Open (or create lazily on first record) the cache at `path`, keyed
    /// by this process's probe fingerprint. Missing, truncated or garbage
    /// files load as empty.
    pub fn open(path: impl Into<PathBuf>) -> Arc<TuneCache> {
        TuneCache::open_with_fingerprint(path, machine_probe().fingerprint())
    }

    /// [`TuneCache::open`] with an explicit fingerprint — lets tests (and
    /// diagnostics) prove that a probe mismatch forces a full re-measure.
    pub fn open_with_fingerprint(
        path: impl Into<PathBuf>,
        fingerprint: impl Into<String>,
    ) -> Arc<TuneCache> {
        let path = path.into();
        let mut rejected = 0usize;
        let entries = load_entries(&path, &mut rejected);
        Arc::new(TuneCache {
            path,
            fingerprint: fingerprint.into(),
            entries: Mutex::new(entries),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            stored: AtomicUsize::new(0),
            rejected_entries: AtomicUsize::new(rejected),
        })
    }

    /// The persisted winner for `key` on this machine, if any.
    pub fn lookup(&self, key: &TuneKey) -> Option<TunedConfig> {
        let found = lock_recover(&self.entries)
            .get(&key.entry_key(&self.fingerprint))
            .cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Record a freshly-measured winner and persist the whole map
    /// atomically. Failures are swallowed (the in-memory entry still
    /// serves this process); corrupting the file is impossible by
    /// construction — the rename either happens or it doesn't.
    pub fn record(&self, key: &TuneKey, cfg: &TunedConfig) {
        let mut entries = lock_recover(&self.entries);
        entries.insert(key.entry_key(&self.fingerprint), cfg.clone());
        self.stored.fetch_add(1, Ordering::Relaxed);
        // Merge under the lock: keys another process persisted since our
        // load survive; our in-memory values win conflicts.
        let mut rejected = 0usize;
        let mut merged = load_entries(&self.path, &mut rejected);
        for (k, v) in entries.iter() {
            merged.insert(k.clone(), v.clone());
        }
        *entries = merged;
        let mut doc = Json::obj();
        let mut map = Json::obj();
        for (k, v) in entries.iter() {
            let mut e = Json::obj();
            e.set("params", v.params.as_str())
                .set("gflops", v.gflops)
                .set("roofline_fraction", v.roofline_fraction);
            map.set(k, e);
        }
        doc.set("version", TUNE_CACHE_VERSION).set("entries", map);
        // Unique per write (pid + sequence), so concurrent writers — other
        // processes or other handles in this one — never share a temp file.
        static WRITE_SEQ: AtomicUsize = AtomicUsize::new(0);
        let tmp = self.path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let ok = std::fs::write(&tmp, doc.to_string_pretty()).is_ok()
            && std::fs::rename(&tmp, &self.path).is_ok();
        if !ok {
            let _ = std::fs::remove_file(&tmp);
        }
    }

    /// Drop the in-memory entry for `key` (this machine's fingerprint), so
    /// the next `tuned_build` re-measures instead of warm-starting. The file
    /// is left alone: the stale winner only dies on disk when the fresh
    /// search `record`s its replacement (memory wins the merge). Returns
    /// whether an entry was present. This is the drift re-tune hook —
    /// without it a re-tune would re-adopt the stale winner with zero reps.
    pub fn invalidate(&self, key: &TuneKey) -> bool {
        lock_recover(&self.entries)
            .remove(&key.entry_key(&self.fingerprint))
            .is_some()
    }

    /// `(lookup hits, lookup misses, winners recorded)` through this handle.
    pub fn stats(&self) -> (usize, usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.stored.load(Ordering::Relaxed),
        )
    }

    /// Entries skipped as malformed when the file was loaded.
    pub fn rejected_entries(&self) -> usize {
        self.rejected_entries.load(Ordering::Relaxed)
    }

    /// Entries currently held (all fingerprints, not just this machine's).
    pub fn len(&self) -> usize {
        lock_recover(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }
}

/// Parse the cache file at `path` fail-soft: any IO error, parse error,
/// version skew or malformed entry yields an empty (or partial) map and
/// never an error.
fn load_entries(path: &Path, rejected: &mut usize) -> BTreeMap<String, TunedConfig> {
    let mut out = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return out;
    };
    let Ok(doc) = Json::parse(&text) else {
        return out;
    };
    if doc.get("version").and_then(|v| v.as_f64()) != Some(TUNE_CACHE_VERSION as f64) {
        return out;
    }
    let Some(Json::Obj(map)) = doc.get("entries") else {
        return out;
    };
    for (k, v) in map {
        let parsed = (|| {
            Some(TunedConfig {
                params: v.get("params")?.as_str()?.to_string(),
                gflops: v.get("gflops")?.as_f64()?,
                roofline_fraction: v.get("roofline_fraction")?.as_f64()?,
            })
        })();
        match parsed {
            Some(cfg) if cfg.gflops.is_finite() && cfg.roofline_fraction.is_finite() => {
                out.insert(k.clone(), cfg);
            }
            _ => *rejected += 1,
        }
    }
    out
}

/// All labeled candidate plans for `(w, req)`. Candidate 0 is always the
/// fixed heuristic — the exact plan [`TuneMode::Off`] builds — and every
/// candidate is bit-identical to it in output (the contract the module
/// docs spell out and `prop_kernels.rs` enforces). `req.tune` selects the
/// breadth of the space; `Off` returns the heuristic alone.
pub fn candidate_plans(w: &SparseMatrix, req: &PlanRequest) -> Vec<(String, KernelPlan)> {
    let n_class = batch_class(req.n);
    let threads = req.threads.max(1);
    // Reduction-reordering candidates only exist when the caller opted in
    // *and* a search will run to validate them (Off builds heuristic-only).
    let reduce = req.reduce_tol.is_some() && req.tune != TuneMode::Off;
    let states = match w {
        SparseMatrix::Dense { .. } => vec![("heuristic".to_string(), PlanState::Dense)],
        SparseMatrix::Csr(m) => ranges_states(&m.indptr, threads, n_class, req.tune, reduce),
        SparseMatrix::Bsr(m) => ranges_states(&m.indptr, threads, n_class, req.tune, reduce),
        SparseMatrix::Rbgp4(m) => rbgp4_states(&m.mask, n_class, threads, req.tune, reduce),
    };
    states
        .into_iter()
        .map(|(label, state)| {
            (
                label,
                KernelPlan {
                    pattern: w.pattern(),
                    rows: w.rows(),
                    cols: w.cols(),
                    batch_class: n_class,
                    threads,
                    build_seconds: 0.0,
                    tuned: None,
                    state,
                },
            )
        })
        .collect()
}

/// CSR/BSR candidate space: row-range granularity (worker counts ≤
/// `threads` — any partition is bit-identical, the per-row reduction order
/// never changes) × output column blocking (0 = unblocked full width).
/// With `reduce` (tolerance-gated), accumulator-fanned variants of the
/// heuristic partition join the space — those *do* re-associate the
/// per-row sum and are only admitted after search-time validation.
fn ranges_states(
    indptr: &[usize],
    threads: usize,
    n_class: usize,
    mode: TuneMode,
    reduce: bool,
) -> Vec<(String, PlanState)> {
    let mut worker_counts = vec![threads];
    let mut col_blocks = vec![0usize];
    match mode {
        TuneMode::Off => {}
        TuneMode::Quick => {
            if threads > 1 {
                worker_counts.push((threads / 2).max(1));
            }
            if 256 < n_class {
                col_blocks.push(256);
            }
        }
        TuneMode::Full => {
            if threads > 1 {
                worker_counts.push((threads / 2).max(1));
                worker_counts.push(1);
            }
            for cb in [512usize, 256, 128, 64] {
                if cb < n_class {
                    col_blocks.push(cb);
                }
            }
        }
    }
    let mut fans = vec![1usize];
    if reduce {
        match mode {
            TuneMode::Off => {}
            TuneMode::Quick => fans.push(4),
            TuneMode::Full => fans.extend([2, 4]),
        }
    }
    let mut out: Vec<(String, PlanState)> = Vec::new();
    for &wk in &worker_counts {
        let ranges = balanced_row_ranges(indptr, wk);
        for &cb in &col_blocks {
            for &fan in &fans {
                // Fanned variants only ride the heuristic partition at
                // full width: the fan is the dimension under test, not a
                // cross product with every schedule.
                if fan > 1 && (wk != threads || cb != col_blocks[0]) {
                    continue;
                }
                let dup = out.iter().any(|(_, s)| match s {
                    PlanState::Ranges {
                        ranges: r,
                        col_block,
                        fan: f,
                    } => *r == ranges && *col_block == cb && *f == fan,
                    _ => false,
                });
                if !dup {
                    out.push((
                        format!("ranges={} colblock={cb} fan={fan}", ranges.len().max(1)),
                        PlanState::Ranges {
                            ranges: ranges.clone(),
                            col_block: cb,
                            fan,
                        },
                    ));
                }
            }
        }
    }
    out
}

/// RBGP4 candidate space: packed-panel column stride (n-dimension blocking
/// only — reduction order untouched), worker count, and packed-vs-gather
/// panel layout (identical micro-kernels over un-copied input rows).
/// Worker candidates never cross the serial/parallel boundary: when the
/// heuristic runs parallel (≥ 2 workers) every candidate stays ≥ 2, and a
/// serial heuristic admits no worker variation — the two regimes reduce in
/// different orders (vo-major vs ko-major) and are not bit-compatible.
fn rbgp4_states(
    mask: &crate::sparsity::rbgp4::Rbgp4Mask,
    n_class: usize,
    threads: usize,
    mode: TuneMode,
    reduce: bool,
) -> Vec<(String, PlanState)> {
    let base = Rbgp4Tunable::heuristic(mask, n_class, threads);
    let mut tunables = vec![base];
    let push = |v: &mut Vec<Rbgp4Tunable>, t: Rbgp4Tunable| {
        if !v.contains(&t) {
            v.push(t);
        }
    };
    let mut strides = vec![base.stride];
    let mut workers = vec![base.workers];
    let mut gathers = vec![false];
    match mode {
        TuneMode::Off => {}
        TuneMode::Quick => {
            if base.stride >= 2 {
                strides.push(base.stride / 2);
            }
            gathers.push(true);
        }
        TuneMode::Full => {
            if base.stride >= 2 {
                strides.push(base.stride / 2);
            }
            if base.stride >= 4 {
                strides.push(base.stride / 4);
            }
            if base.stride * 2 <= n_class {
                strides.push(base.stride * 2);
            }
            if base.workers >= 4 {
                workers.push((base.workers / 2).max(2));
            }
            gathers.push(true);
        }
    }
    for &stride in &strides {
        for &wk in &workers {
            for &gather in &gathers {
                push(
                    &mut tunables,
                    Rbgp4Tunable {
                        stride,
                        workers: wk,
                        gather,
                        ksplit: 1,
                    },
                );
            }
        }
    }
    // Tolerance-gated k-split: halve the panel reduction into two partial
    // sums combined at the end — a genuine re-association, admitted only
    // after search-time validation. Rides the heuristic schedule (and, in
    // Full mode, the gather layout) rather than the whole cross product.
    if reduce && mode != TuneMode::Off {
        push(&mut tunables, Rbgp4Tunable { ksplit: 2, ..base });
        if mode == TuneMode::Full {
            push(
                &mut tunables,
                Rbgp4Tunable {
                    gather: true,
                    ksplit: 2,
                    ..base
                },
            );
        }
    }
    tunables
        .into_iter()
        .map(|t| {
            let mut label = format!(
                "stride={} workers={} layout={}",
                t.stride,
                t.workers,
                if t.gather { "gather" } else { "packed" }
            );
            if t.ksplit > 1 {
                label.push_str(&format!(" ksplit={}", t.ksplit));
            }
            (
                label,
                PlanState::Rbgp4(Box::new(Rbgp4Plan::build_tuned(mask, n_class, &t))),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::csr::CsrMatrix;
    use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
    use crate::util::rng::Rng;

    fn rbgp4_matrix(seed: u64) -> SparseMatrix {
        let cfg = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(cfg, &mut rng).unwrap();
        SparseMatrix::Rbgp4(Rbgp4Matrix::random(mask, &mut rng))
    }

    #[test]
    fn tune_mode_parses_and_defaults_to_quick() {
        assert_eq!(TuneMode::parse("off").unwrap(), TuneMode::Off);
        assert_eq!(TuneMode::parse("quick").unwrap(), TuneMode::Quick);
        assert_eq!(TuneMode::parse("full").unwrap(), TuneMode::Full);
        assert!(TuneMode::parse("fast").is_err());
        assert_eq!(TuneMode::default(), TuneMode::Quick);
        assert_eq!(TuneMode::Full.name(), "full");
    }

    #[test]
    fn probe_is_finite_positive_and_cached() {
        let p1 = machine_probe();
        assert!(p1.peak_gbps.is_finite() && p1.peak_gbps > 0.0);
        assert!(p1.peak_gflops.is_finite() && p1.peak_gflops > 0.0);
        let p2 = machine_probe();
        assert!(std::ptr::eq(p1, p2), "probe measured once per process");
        // The roofline is the min of the two bounds.
        let low_ai = p1.attainable_gflops(1e-6);
        assert!(low_ai <= p1.peak_gflops);
        assert!(p1.attainable_gflops(1e9) <= p1.peak_gflops + 1e-9);
    }

    #[test]
    fn off_mode_yields_exactly_the_heuristic() {
        let mut rng = Rng::new(7);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng));
        for threads in [1usize, 4] {
            let req = PlanRequest::new(8, threads).with_tune(TuneMode::Off);
            let cands = candidate_plans(&w, &req);
            assert_eq!(cands.len(), 1, "Off searches nothing");
        }
        let cands = candidate_plans(&rbgp4_matrix(8), &PlanRequest::new(8, 4).with_tune(TuneMode::Off));
        assert_eq!(cands.len(), 1);
    }

    #[test]
    fn quick_and_full_widen_the_space_first_is_heuristic() {
        let w = rbgp4_matrix(9);
        let quick = candidate_plans(&w, &PlanRequest::new(64, 4));
        let full = candidate_plans(&w, &PlanRequest::new(64, 4).with_tune(TuneMode::Full));
        assert!(quick.len() > 1, "quick explores: {}", quick.len());
        assert!(full.len() >= quick.len(), "full at least as wide");
        let off = candidate_plans(&w, &PlanRequest::new(64, 4).with_tune(TuneMode::Off));
        assert_eq!(quick[0].0, off[0].0, "candidate 0 is the heuristic");
    }

    #[test]
    fn rbgp4_candidates_never_cross_the_serial_parallel_boundary() {
        let w = rbgp4_matrix(10);
        // Parallel heuristic (threads > 1): every candidate keeps ≥ 2 workers.
        for (label, plan) in candidate_plans(&w, &PlanRequest::new(32, 4).with_tune(TuneMode::Full)) {
            if let crate::kernels::plan::PlanState::Rbgp4(p) = &plan.state {
                assert!(p.threads() >= 2, "{label} fell back to serial");
            } else {
                panic!("rbgp4 candidate with non-rbgp4 state");
            }
        }
        // Serial heuristic (threads == 1): every candidate stays serial.
        for (label, plan) in candidate_plans(&w, &PlanRequest::new(32, 1).with_tune(TuneMode::Full)) {
            if let crate::kernels::plan::PlanState::Rbgp4(p) = &plan.state {
                assert_eq!(p.threads(), 1, "{label} escaped the serial regime");
            }
        }
    }

    #[test]
    fn ranges_candidates_respect_thread_cap_and_dedup() {
        let mut rng = Rng::new(11);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(32, 32, 0.75, &mut rng));
        let cands = candidate_plans(&w, &PlanRequest::new(512, 4).with_tune(TuneMode::Full));
        let mut seen = std::collections::HashSet::new();
        for (label, plan) in &cands {
            if let crate::kernels::plan::PlanState::Ranges {
                ranges,
                col_block,
                fan,
            } = &plan.state
            {
                assert!(ranges.len() <= 4, "{label}: more workers than threads");
                assert_eq!(*fan, 1, "{label}: fan without reduce_tol");
                assert!(
                    seen.insert((ranges.clone(), *col_block)),
                    "{label}: duplicate candidate"
                );
            }
        }
        assert!(cands.len() > 1);
    }

    #[test]
    fn reduce_tol_widens_and_off_mode_suppresses() {
        let mut rng = Rng::new(12);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(32, 32, 0.75, &mut rng));
        let plain = candidate_plans(&w, &PlanRequest::new(64, 4).with_tune(TuneMode::Full));
        let with_tol = candidate_plans(
            &w,
            &PlanRequest::new(64, 4)
                .with_tune(TuneMode::Full)
                .with_reduce_tol(1e-5),
        );
        assert!(with_tol.len() > plain.len(), "fan candidates join the space");
        assert!(with_tol.iter().any(|(l, _)| l.contains("fan=4")));
        assert!(plain.iter().all(|(l, _)| l.ends_with("fan=1")));
        // Off mode never generates them, tolerance or not.
        let off = candidate_plans(
            &w,
            &PlanRequest::new(64, 4)
                .with_tune(TuneMode::Off)
                .with_reduce_tol(1e-5),
        );
        assert_eq!(off.len(), 1);

        let r = rbgp4_matrix(13);
        let plain = candidate_plans(&r, &PlanRequest::new(64, 4).with_tune(TuneMode::Full));
        let with_tol = candidate_plans(
            &r,
            &PlanRequest::new(64, 4)
                .with_tune(TuneMode::Full)
                .with_reduce_tol(1e-5),
        );
        assert!(with_tol.len() > plain.len());
        assert!(with_tol.iter().any(|(l, _)| l.contains("ksplit=2")));
        assert!(plain.iter().all(|(l, _)| !l.contains("ksplit")));
    }

    #[test]
    fn measure_with_injected_clock_scores_min_of_reps() {
        // Rep 1 "preempted" (100 ms), rep 2 clean (1 ms): min-of-reps must
        // report 1 ms — a mean would report 50.5 ms and could crown a slow
        // candidate that merely got lucky scheduling.
        let budget = SearchBudget { warmup: 1, reps: 2 };
        let mut times = vec![0.0, 0.100, 0.001].into_iter();
        let mut calls = 0usize;
        let secs = measure_seconds_with(
            &budget,
            || {
                calls += 1;
                Ok(())
            },
            || times.next().expect("clock called once per rep + reset"),
        )
        .unwrap();
        assert_eq!(calls, 3, "1 warmup + 2 reps");
        assert_eq!(secs, 0.001, "min, not mean, of reps");
    }

    #[test]
    fn search_rep_counter_tracks_executions() {
        let before = search_reps();
        let budget = SearchBudget { warmup: 2, reps: 3 };
        measure_seconds(&budget, || Ok(())).unwrap();
        assert_eq!(search_reps() - before, 5);
    }

    #[test]
    fn fingerprint_buckets_absorb_jitter_but_not_machines() {
        let p = MachineProbe {
            peak_gbps: 20.0,
            peak_gflops: 100.0,
        };
        // ±3% jitter lands in the same quarter-octave bucket.
        let jitter = MachineProbe {
            peak_gbps: 20.5,
            peak_gflops: 98.0,
        };
        assert_eq!(p.fingerprint(), jitter.fingerprint());
        // A 2× different machine forks the key.
        let other = MachineProbe {
            peak_gbps: 40.0,
            peak_gflops: 100.0,
        };
        assert_ne!(p.fingerprint(), other.fingerprint());
    }

    fn tmp_cache_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "rbgp_tune_cache_{tag}_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn demo_key(batch_class: usize) -> TuneKey {
        TuneKey {
            family: 3,
            structure: 0xdead_beef_cafe_f00d,
            rows: 256,
            cols: 256,
            batch_class,
            threads: 4,
        }
    }

    fn demo_cfg(gflops: f64) -> TunedConfig {
        TunedConfig {
            params: "stride=128 workers=4 layout=packed".to_string(),
            gflops,
            roofline_fraction: 0.123_456_789,
        }
    }

    #[test]
    fn tune_cache_roundtrips_bit_exact() {
        let path = tmp_cache_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let a = TuneCache::open_with_fingerprint(&path, "bwXfY");
        // f64 Display is shortest-roundtrip, so gflops survives exactly.
        let cfg = demo_cfg(12.345_678_901_234_567);
        a.record(&demo_key(64), &cfg);
        let b = TuneCache::open_with_fingerprint(&path, "bwXfY");
        let got = b.lookup(&demo_key(64)).expect("persisted entry");
        assert_eq!(got.params, cfg.params);
        assert_eq!(got.gflops.to_bits(), cfg.gflops.to_bits());
        assert_eq!(
            got.roofline_fraction.to_bits(),
            cfg.roofline_fraction.to_bits()
        );
        assert_eq!(b.stats(), (1, 0, 0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tune_cache_fingerprint_mismatch_misses() {
        let path = tmp_cache_path("fpmiss");
        let _ = std::fs::remove_file(&path);
        let a = TuneCache::open_with_fingerprint(&path, "bw80f28");
        a.record(&demo_key(64), &demo_cfg(10.0));
        // Same file, different machine: entry invisible, lookup misses.
        let b = TuneCache::open_with_fingerprint(&path, "bw99f31");
        assert_eq!(b.len(), 1, "foreign entries survive in the file");
        assert!(b.lookup(&demo_key(64)).is_none());
        assert_eq!(b.stats(), (0, 1, 0));
        // Recording under the new fingerprint keeps the old machine's
        // entry alongside.
        b.record(&demo_key(64), &demo_cfg(20.0));
        let c = TuneCache::open_with_fingerprint(&path, "bw80f28");
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup(&demo_key(64)).unwrap().gflops, 10.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tune_cache_fails_soft_on_garbage_and_version_skew() {
        for (tag, text) in [
            ("garbage", "not json at all {{{"),
            ("truncated", "{\"version\": 1, \"entri"),
            ("skew", "{\"version\": 99, \"entries\": {\"k\": {}}}"),
            ("wrongshape", "{\"version\": 1, \"entries\": [1, 2]}"),
        ] {
            let path = tmp_cache_path(tag);
            std::fs::write(&path, text).unwrap();
            let c = TuneCache::open_with_fingerprint(&path, "bwXfY");
            assert!(c.is_empty(), "{tag}: loads as empty, no panic");
            assert!(c.lookup(&demo_key(8)).is_none());
            // Recording over the bad file replaces it with a valid one.
            c.record(&demo_key(8), &demo_cfg(5.0));
            let reopened = TuneCache::open_with_fingerprint(&path, "bwXfY");
            assert_eq!(reopened.len(), 1, "{tag}: recovered by rewrite");
            let _ = std::fs::remove_file(&path);
        }
        // A missing file is simply empty.
        let path = tmp_cache_path("missing");
        let _ = std::fs::remove_file(&path);
        assert!(TuneCache::open_with_fingerprint(&path, "x").is_empty());
        // Malformed individual entries are skipped and counted, valid
        // siblings load.
        let path = tmp_cache_path("partial");
        std::fs::write(
            &path,
            "{\"version\": 1, \"entries\": {\
             \"bad\": {\"params\": \"p\"},\
             \"good\": {\"params\": \"p\", \"gflops\": 2.0, \"roofline_fraction\": 0.5}}}",
        )
        .unwrap();
        let c = TuneCache::open_with_fingerprint(&path, "x");
        assert_eq!(c.len(), 1);
        assert_eq!(c.rejected_entries(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tune_cache_concurrent_writers_never_corrupt() {
        let path = tmp_cache_path("concurrent");
        let _ = std::fs::remove_file(&path);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let path = path.clone();
                scope.spawn(move || {
                    // Each writer its own handle — the cross-process shape.
                    let c = TuneCache::open_with_fingerprint(&path, "bwXfY");
                    for i in 0..8 {
                        let mut key = demo_key(1 << i);
                        key.structure = t as u64;
                        c.record(&key, &demo_cfg(1.0 + i as f64));
                    }
                });
            }
        });
        // Whatever interleaving happened, the surviving file parses and
        // every entry in it is well-formed (rename is all-or-nothing).
        let c = TuneCache::open_with_fingerprint(&path, "bwXfY");
        assert!(!c.is_empty());
        assert_eq!(c.rejected_entries(), 0, "no torn entries");
        let mut key = demo_key(1);
        key.structure = 0;
        // The last writer to persist holds its own full entry set.
        assert!(c.len() >= 8, "at least one writer's batch survived whole");
        let _ = c.lookup(&key);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn synth_input_is_nonzero() {
        assert!(synth_input(1000).iter().all(|&x| x != 0.0));
    }
}
