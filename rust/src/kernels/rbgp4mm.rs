//! RBGP4MM: `O = W_s · I` with `W_s` in RBGP4 compact storage —
//! Algorithm 1 (Appendix 8.2) adapted from CUDA to a cache-hierarchy CPU.
//!
//! The GPU schedule maps onto the CPU as:
//!
//! * thread block / output tile `OT`  → loop over `(u_o, u_i)` row groups
//! * `G_o` tile skipping              → only `d_o` packed steps per tile row
//! * shared-memory staging of `IT`    → `pack` buffer: the `tile_row_nnz`
//!   rows of `I` a tile touches are gathered once into contiguous memory
//! * register-level row repetition    → the packed panel is then hit with a
//!   dense micro-GEMM over all `|G_r.U|·|G_b.U|` repeated rows, so every
//!   packed element is reused `row_repetition` times from L1
//!
//! All derived structure — flattened intra-tile column offsets, the
//! `v_o → (u_o, k_o)` reverse adjacency, the pack-panel layout, and one
//! scratch arena per worker thread — lives in an [`Rbgp4Plan`] built once
//! per `(mask, batch class, threads)` (see [`crate::kernels::plan`]).
//! `rbgp4mm_with_plan` / `rbgp4mm_parallel_with_plan` run allocation-free
//! from a plan; the historical free functions build a transient plan per
//! call and remain the "per-call" baseline the benches compare against.
//!
//! Pack reuse is maximized by iterating `(v_o, u_i)` on the outside: one
//! packed panel serves every tile row `u_o` adjacent to `v_o`
//! (`d_r(G_o)` tile rows × `row_repetition` rows each), and the repetition
//! group is processed two output rows at a time so each packed element is
//! read once per *pair* of rows.

use crate::sparsity::rbgp4::{Rbgp4Mask, Rbgp4Matrix};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Precomputed intra-tile column offsets: for each `u_i`, the tile-local
/// columns of its `tile_row_nnz` non-zeros (ascending). This is `m_i ×
/// tile_row_nnz` integers — part of the succinct index, derived from
/// `adj_i` once per matrix, never per call.
pub fn local_cols(mask: &Rbgp4Mask) -> Vec<Vec<usize>> {
    let c = &mask.config;
    (0..c.gi.nu)
        .map(|ui| {
            let mut cols = Vec::with_capacity(c.tile_row_nnz());
            for vr in 0..c.gr.1 {
                for &vi in &mask.gi.adj[ui] {
                    for vb in 0..c.gb.1 {
                        cols.push((vr * c.gi.nv + vi) * c.gb.1 + vb);
                    }
                }
            }
            cols
        })
        .collect()
}

/// Reference row-at-a-time kernel (correctness oracle; no packing, no
/// grouping). `i` is (cols × n) row-major, `o` is (rows × n).
pub fn rbgp4mm_naive(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize) {
    let mask = &w.mask;
    let c = &mask.config;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    o.fill(0.0);
    let lc = local_cols(mask);
    let (tk, rn) = (c.tile_k(), c.row_nnz());
    for u in 0..mask.rows() {
        let (uo, _ur, ui, _ub) = mask.row_coords(u);
        let orow = &mut o[u * n..(u + 1) * n];
        let wrow = &w.data[u * rn..(u + 1) * rn];
        let mut k = 0;
        for &vo in &mask.go.adj[uo] {
            let tile_base = vo * tk;
            for &off in &lc[ui] {
                let a = wrow[k];
                k += 1;
                let irow = &i[(tile_base + off) * n..(tile_base + off) * n + n];
                for cix in 0..n {
                    orow[cix] += a * irow[cix];
                }
            }
        }
    }
}

/// Maximum column-block size for the packed panel: chosen so (tile_row_nnz
/// + group) rows of NC f32 stay L1/L2-resident for the paper's configs.
/// Perf §L3 iter 2 swept {128, 256, 512, 1024}: 512 is 17 % faster than 256
/// on the Table-2 config (2 KiB per panel row amortizes the pack copy
/// without spilling L2). Plans tighten the panel stride to the batch class
/// when it is smaller, which keeps the pack footprint minimal at small n.
const NC: usize = 512;

/// The schedule knobs `build_plan`'s autotuner searches over (see
/// `kernels::autotune`). Every combination with `ksplit == 1` is
/// *bit-identical* in output to the heuristic at the same serial/parallel
/// regime: `stride` blocks the batch dimension only, `workers` moves whole
/// output tile rows between threads, and `gather` feeds the identical
/// micro-kernels from un-copied input rows instead of the packed arena.
/// `ksplit > 1` is the one exception: it splits the panel reduction into
/// independent partial-sum trees (re-associating the inner sum), so the
/// autotuner only proposes it through the tolerance gate
/// (`PlanRequest::reduce_tol`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rbgp4Tunable {
    /// Packed-panel column stride (clamped to `[1, batch class]`).
    pub stride: usize,
    /// Worker threads (clamped to the `m_o` tile rows).
    pub workers: usize,
    /// Skip the pack copy and read panel rows straight from `I` (wins when
    /// the pack copy can't amortize, e.g. low row repetition or tiny `n`).
    pub gather: bool,
    /// Split the `tile_row_nnz` panel reduction into this many independent
    /// partial-sum chains combined at the end (1 = off, the strict order).
    /// Clamped back to 1 when the panel is too short (`trn < 2·ksplit`) or
    /// the stride exceeds the stack accumulator ([`KSPLIT_NB_MAX`]).
    pub ksplit: usize,
}

impl Rbgp4Tunable {
    /// The fixed heuristic — exactly what [`Rbgp4Plan::build`] has always
    /// chosen, and candidate 0 of every tuning search.
    pub fn heuristic(mask: &Rbgp4Mask, n: usize, threads: usize) -> Rbgp4Tunable {
        Rbgp4Tunable {
            stride: NC.min(n.max(1).next_power_of_two()),
            workers: threads.max(1).min(mask.config.go.nu),
            gather: false,
            ksplit: 1,
        }
    }
}

/// Execution plan for one RBGP4 mask at one batch class / thread count:
/// everything `rbgp4mm` derives from the succinct index, computed once.
/// `Clone` lets an executor detach a private working copy (the arenas are
/// mutable scratch, so concurrent executors each need their own).
#[derive(Clone)]
pub struct Rbgp4Plan {
    /// Flattened `(m_i × tile_row_nnz)` intra-tile column offsets.
    pub(crate) local_cols: Vec<u32>,
    pub(crate) trn: usize,
    /// For each `v_o`: the `(u_o, k_o)` pairs whose tile row consumes this
    /// tile column — `G_o`'s right adjacency with the compact k-offset
    /// precomputed (replaces a per-call binary search).
    pub(crate) vo_targets: Vec<Vec<(u32, u32)>>,
    /// Column stride of the packed panel (tightened to the batch class so
    /// small batches keep a small L1 footprint; tunable).
    pub(crate) stride: usize,
    /// Gather layout: micro-kernels read rows of `I` directly and the
    /// arenas stay empty (one zero-length arena per worker, so
    /// [`Rbgp4Plan::threads`] still reports the worker count).
    pub(crate) gather: bool,
    /// Partial-sum chains per panel reduction (1 = strict order).
    pub(crate) ksplit: usize,
    /// One pack arena per worker thread, each `trn × stride` floats
    /// (zero-length under the gather layout).
    pub(crate) arenas: Vec<Vec<f32>>,
}

impl Rbgp4Plan {
    /// Derive the plan for `mask`, an expected batch size `n` (the plan is
    /// correct for any `n`; the panel stride is merely tuned for this one),
    /// and up to `threads` workers (clamped to the `m_o` tile rows) — the
    /// fixed-heuristic schedule.
    pub fn build(mask: &Rbgp4Mask, n: usize, threads: usize) -> Rbgp4Plan {
        Rbgp4Plan::build_tuned(mask, n, &Rbgp4Tunable::heuristic(mask, n, threads))
    }

    /// Derive the plan with an explicit schedule (the autotuner's entry
    /// point). Out-of-range knobs are clamped, never rejected.
    pub fn build_tuned(mask: &Rbgp4Mask, n: usize, tun: &Rbgp4Tunable) -> Rbgp4Plan {
        let c = &mask.config;
        let trn = c.tile_row_nnz();
        let mut lc = Vec::with_capacity(c.gi.nu * trn);
        for ui in 0..c.gi.nu {
            for vr in 0..c.gr.1 {
                for &vi in &mask.gi.adj[ui] {
                    for vb in 0..c.gb.1 {
                        lc.push(((vr * c.gi.nv + vi) * c.gb.1 + vb) as u32);
                    }
                }
            }
        }
        debug_assert_eq!(lc.len(), c.gi.nu * trn);
        let mut vo_targets = vec![Vec::new(); c.go.nv];
        for uo in 0..c.go.nu {
            for (ko, &vo) in mask.go.adj[uo].iter().enumerate() {
                vo_targets[vo].push((uo as u32, ko as u32));
            }
        }
        let stride = tun.stride.clamp(1, n.max(1).next_power_of_two());
        let workers = tun.workers.max(1).min(c.go.nu);
        let arena_len = if tun.gather { 0 } else { trn * stride };
        let arenas = (0..workers).map(|_| vec![0.0f32; arena_len]).collect();
        // k-split needs a stack accumulator per column block and enough
        // panel rows to split; degenerate requests fall back to the strict
        // order rather than erroring.
        let ksplit = if tun.ksplit > 1 && trn >= 2 * tun.ksplit && stride <= KSPLIT_NB_MAX {
            tun.ksplit
        } else {
            1
        };
        Rbgp4Plan {
            local_cols: lc,
            trn,
            vo_targets,
            stride,
            gather: tun.gather,
            ksplit,
            arenas,
        }
    }

    /// Worker threads this plan provisions arenas for.
    pub fn threads(&self) -> usize {
        self.arenas.len()
    }

    /// Packed-panel column stride.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Whether this plan reads panel rows directly from `I` (gather
    /// layout) instead of packing them.
    pub fn is_gather(&self) -> bool {
        self.gather
    }

    /// Partial-sum chains per panel reduction (1 = the strict, bit-stable
    /// accumulation order).
    pub fn ksplit(&self) -> usize {
        self.ksplit
    }
}

/// Optimized serial kernel executing from a prebuilt plan: gather-pack +
/// pair-wise grouped micro-GEMM, no allocation, no index derivation.
pub fn rbgp4mm_with_plan(w: &Rbgp4Matrix, plan: &mut Rbgp4Plan, i: &[f32], o: &mut [f32], n: usize) {
    let mask = &w.mask;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    let c = &mask.config;
    assert_eq!(plan.vo_targets.len(), c.go.nv, "plan built for another mask");
    o.fill(0.0);
    let Rbgp4Plan {
        ref local_cols,
        trn,
        ref vo_targets,
        stride,
        gather,
        ksplit,
        ref mut arenas,
    } = *plan;
    let (mr, mi, mb) = (c.gr.0, c.gi.nu, c.gb.0);
    let rn = c.row_nnz();
    let rep = c.row_repetition();
    let tk = c.tile_k();
    let pack = &mut arenas[0];
    let mut n0 = 0;
    while n0 < n {
        let nb = stride.min(n - n0);
        for (vo, targets) in vo_targets.iter().enumerate() {
            for ui in 0..mi {
                let lci = &local_cols[ui * trn..(ui + 1) * trn];
                let panel = if gather {
                    PanelRef::Gather {
                        i,
                        n,
                        n0,
                        tile_base: vo * tk,
                        lci,
                    }
                } else {
                    pack_panel(mask, i, n, n0, nb, vo, lci, pack, stride);
                    PanelRef::Packed {
                        pack: pack.as_slice(),
                        stride,
                    }
                };
                for &(uo, ko) in targets {
                    let uo = uo as usize;
                    let row_of = |g: usize| ((uo * mr + g / mb) * mi + ui) * mb + g % mb;
                    rep_group_gemm(
                        &w.data,
                        rn,
                        ko as usize * trn,
                        trn,
                        o,
                        n,
                        n0,
                        nb,
                        rep,
                        ksplit,
                        &row_of,
                        &row_of,
                        &panel,
                    );
                }
            }
        }
        n0 += nb;
    }
}

/// Serial kernel, per-call form: builds a transient plan and executes. This
/// re-derives `local_cols` and allocates the pack buffer every call — kept
/// as the baseline the plan cache is benchmarked against (and for one-shot
/// callers).
pub fn rbgp4mm(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize) {
    let mut plan = Rbgp4Plan::build(&w.mask, n, 1);
    rbgp4mm_with_plan(w, &mut plan, i, o, n);
}

/// Where the micro-kernels read panel rows from. Both variants hand out
/// the *same values in the same order* — the packed arena is a contiguous
/// copy of exactly the rows the gather variant addresses in place — so the
/// floating-point expressions (and therefore the bits) of the result are
/// independent of the layout. The branch is resolved once per panel row,
/// outside the inner column loops.
enum PanelRef<'a> {
    /// Rows staged contiguously in the plan's pack arena.
    Packed { pack: &'a [f32], stride: usize },
    /// Rows read in place from `I` through the intra-tile offsets.
    Gather {
        i: &'a [f32],
        n: usize,
        n0: usize,
        tile_base: usize,
        lci: &'a [u32],
    },
}

impl<'a> PanelRef<'a> {
    /// Panel row `p`, `nb` columns wide.
    #[inline(always)]
    fn row(&self, p: usize, nb: usize) -> &'a [f32] {
        match *self {
            PanelRef::Packed { pack, stride } => &pack[p * stride..p * stride + nb],
            PanelRef::Gather {
                i,
                n,
                n0,
                tile_base,
                lci,
            } => {
                let src = (tile_base + lci[p] as usize) * n + n0;
                &i[src..src + nb]
            }
        }
    }
}

/// Gather the `tile_row_nnz` rows of `I` that tile column `v_o` and intra-
/// tile pattern `u_i` touch, restricted to columns [n0, n0+nb), into `pack`
/// (panel row stride `stride`).
#[allow(clippy::too_many_arguments)]
#[inline]
fn pack_panel(
    mask: &Rbgp4Mask,
    i: &[f32],
    n: usize,
    n0: usize,
    nb: usize,
    vo: usize,
    lci: &[u32],
    pack: &mut [f32],
    stride: usize,
) {
    let tk = mask.config.tile_k();
    let tile_base = vo * tk;
    for (p, &off) in lci.iter().enumerate() {
        let src = (tile_base + off as usize) * n + n0;
        pack[p * stride..p * stride + nb].copy_from_slice(&i[src..src + nb]);
    }
}

/// Largest panel stride the k-split micro-kernels support: the partial
/// accumulators live on the stack, `KSPLIT_NB_MAX` floats each.
/// [`Rbgp4Plan::build_tuned`] clamps `ksplit` back to 1 for wider strides.
const KSPLIT_NB_MAX: usize = NC;

/// Accumulate the contribution of one packed step into every row of a
/// repetition group, two output rows at a time so each packed element is
/// loaded once per row *pair*. `wrow_of`/`orow_of` map the group index
/// `g ∈ [0, rep)` to the weight row (global) and the output row (global or
/// chunk-local); both must be strictly increasing in `g`. `ksplit > 1`
/// routes to the partial-sum-tree micro-kernels (tolerance-gated).
#[allow(clippy::too_many_arguments)]
fn rep_group_gemm(
    wdata: &[f32],
    rn: usize,
    kbase: usize,
    trn: usize,
    o: &mut [f32],
    ostride: usize,
    n0: usize,
    nb: usize,
    rep: usize,
    ksplit: usize,
    wrow_of: &dyn Fn(usize) -> usize,
    orow_of: &dyn Fn(usize) -> usize,
    panel: &PanelRef<'_>,
) {
    let mut g = 0;
    while g + 2 <= rep {
        let (uw0, uw1) = (wrow_of(g), wrow_of(g + 1));
        let (ou0, ou1) = (orow_of(g), orow_of(g + 1));
        debug_assert!(ou0 < ou1, "orow_of must be increasing");
        let w0 = &wdata[uw0 * rn + kbase..uw0 * rn + kbase + trn];
        let w1 = &wdata[uw1 * rn + kbase..uw1 * rn + kbase + trn];
        let (lo, hi) = o.split_at_mut(ou1 * ostride);
        let orow0 = &mut lo[ou0 * ostride + n0..ou0 * ostride + n0 + nb];
        let orow1 = &mut hi[n0..n0 + nb];
        if ksplit > 1 {
            micro_2row_ksplit(w0, w1, orow0, orow1, trn, nb, panel, ksplit);
        } else {
            micro_2row(w0, w1, orow0, orow1, 0, trn, nb, panel);
        }
        g += 2;
    }
    if g < rep {
        let uw = wrow_of(g);
        let ou = orow_of(g);
        let wrow = &wdata[uw * rn + kbase..uw * rn + kbase + trn];
        let orow = &mut o[ou * ostride + n0..ou * ostride + n0 + nb];
        if ksplit > 1 {
            micro_1row_ksplit(wrow, orow, trn, nb, panel, ksplit);
        } else {
            micro_1row(wrow, orow, 0, trn, nb, panel);
        }
    }
}

/// Two output rows against panel rows `[p0, p1)`, 2-wide panel unroll.
/// With `(0, trn)` this is the historical whole-panel kernel, bit for bit.
#[inline]
fn micro_2row(
    w0: &[f32],
    w1: &[f32],
    o0: &mut [f32],
    o1: &mut [f32],
    p0: usize,
    p1: usize,
    nb: usize,
    panel: &PanelRef<'_>,
) {
    let mut p = p0;
    while p + 2 <= p1 {
        let (a0, a1) = (w0[p], w0[p + 1]);
        let (b0, b1) = (w1[p], w1[p + 1]);
        let r0 = panel.row(p, nb);
        let r1 = panel.row(p + 1, nb);
        for cix in 0..nb {
            let (x0, x1) = (r0[cix], r1[cix]);
            o0[cix] += a0 * x0 + a1 * x1;
            o1[cix] += b0 * x0 + b1 * x1;
        }
        p += 2;
    }
    if p < p1 {
        let (a, b) = (w0[p], w1[p]);
        let r = panel.row(p, nb);
        for cix in 0..nb {
            o0[cix] += a * r[cix];
            o1[cix] += b * r[cix];
        }
    }
}

/// One output row against panel rows `[p0, p1)`, 4-wide panel unroll
/// (perf §L3 iter 1: fewer orow passes at large tile_row_nnz). With
/// `(0, trn)` this is the historical whole-panel kernel, bit for bit.
#[inline]
fn micro_1row(
    wrow: &[f32],
    orow: &mut [f32],
    p0: usize,
    p1: usize,
    nb: usize,
    panel: &PanelRef<'_>,
) {
    let mut p = p0;
    while p + 4 <= p1 {
        let (a0, a1, a2, a3) = (wrow[p], wrow[p + 1], wrow[p + 2], wrow[p + 3]);
        let r0 = panel.row(p, nb);
        let r1 = panel.row(p + 1, nb);
        let r2 = panel.row(p + 2, nb);
        let r3 = panel.row(p + 3, nb);
        for cix in 0..nb {
            orow[cix] += a0 * r0[cix] + a1 * r1[cix] + a2 * r2[cix] + a3 * r3[cix];
        }
        p += 4;
    }
    while p < p1 {
        let a = wrow[p];
        let r = panel.row(p, nb);
        for cix in 0..nb {
            orow[cix] += a * r[cix];
        }
        p += 1;
    }
}

/// One output row with the `[0, trn)` panel reduction split into `ksplit`
/// independent partial-sum chains: split 0 accumulates into the output row
/// directly, each later split into a zeroed stack buffer folded in at the
/// end. **Re-associates the sum** vs [`micro_1row`] — only reachable via
/// the tolerance-gated search.
#[inline]
fn micro_1row_ksplit(
    wrow: &[f32],
    orow: &mut [f32],
    trn: usize,
    nb: usize,
    panel: &PanelRef<'_>,
    ksplit: usize,
) {
    debug_assert!(nb <= KSPLIT_NB_MAX);
    let mut acc = [0.0f32; KSPLIT_NB_MAX];
    for s in 0..ksplit {
        let (p0, p1) = (s * trn / ksplit, (s + 1) * trn / ksplit);
        if s == 0 {
            micro_1row(wrow, orow, p0, p1, nb, panel);
        } else {
            let a = &mut acc[..nb];
            a.fill(0.0);
            micro_1row(wrow, a, p0, p1, nb, panel);
            for cix in 0..nb {
                orow[cix] += a[cix];
            }
        }
    }
}

/// Two output rows with the panel reduction split into `ksplit` chains —
/// the pair-wise counterpart of [`micro_1row_ksplit`].
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_2row_ksplit(
    w0: &[f32],
    w1: &[f32],
    o0: &mut [f32],
    o1: &mut [f32],
    trn: usize,
    nb: usize,
    panel: &PanelRef<'_>,
    ksplit: usize,
) {
    debug_assert!(nb <= KSPLIT_NB_MAX);
    let mut acc0 = [0.0f32; KSPLIT_NB_MAX];
    let mut acc1 = [0.0f32; KSPLIT_NB_MAX];
    for s in 0..ksplit {
        let (p0, p1) = (s * trn / ksplit, (s + 1) * trn / ksplit);
        if s == 0 {
            micro_2row(w0, w1, o0, o1, p0, p1, nb, panel);
        } else {
            let (a0, a1) = (&mut acc0[..nb], &mut acc1[..nb]);
            a0.fill(0.0);
            a1.fill(0.0);
            micro_2row(w0, w1, a0, a1, p0, p1, nb, panel);
            for cix in 0..nb {
                o0[cix] += a0[cix];
                o1[cix] += a1[cix];
            }
        }
    }
}

/// Parallel kernel executing from a prebuilt plan: output tile rows `u_o`
/// are distributed across the plan's workers (disjoint output), each with
/// its private pack arena. Pack reuse inside a thread is per-(u_o):
/// `d_o · m_i` packs serving `row_repetition` rows each.
pub fn rbgp4mm_parallel_with_plan(
    w: &Rbgp4Matrix,
    plan: &mut Rbgp4Plan,
    i: &[f32],
    o: &mut [f32],
    n: usize,
) {
    if plan.arenas.len() <= 1 {
        rbgp4mm_with_plan(w, plan, i, o, n);
        return;
    }
    let mask = &w.mask;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    let c = &mask.config;
    assert_eq!(plan.vo_targets.len(), c.go.nv, "plan built for another mask");
    let m_o = c.go.nu;
    let tile_rows = c.tile_m() * n; // output elems per tile row
    let Rbgp4Plan {
        ref local_cols,
        trn,
        vo_targets: _,
        stride,
        gather,
        ksplit,
        ref mut arenas,
    } = *plan;
    let next = AtomicUsize::new(0);
    // Hand out tile rows dynamically; each chunk writes a disjoint region.
    let o_ptr = SendPtr(o.as_mut_ptr());
    std::thread::scope(|scope| {
        for pack in arenas.iter_mut() {
            let next = &next;
            let o_ptr = &o_ptr;
            scope.spawn(move || loop {
                let uo = next.fetch_add(1, Ordering::Relaxed);
                if uo >= m_o {
                    break;
                }
                // SAFETY: `o` has exactly `m_o * tile_rows` elements (the
                // caller sized it to the padded output), and `uo < m_o`
                // here, so `[uo*tile_rows, (uo+1)*tile_rows)` is in bounds.
                // The `fetch_add` hands each `uo` to exactly one worker,
                // so no two live slices alias: every packed-panel write
                // lands in this worker's disjoint output rows, and the
                // `&mut [f32]` borrow of `o` outlives the thread scope.
                let ochunk = unsafe {
                    std::slice::from_raw_parts_mut(o_ptr.0.add(uo * tile_rows), tile_rows)
                };
                ochunk.fill(0.0);
                tile_row_worker(
                    w, i, ochunk, n, uo, local_cols, trn, stride, gather, ksplit, pack,
                );
            });
        }
    });
}

/// Parallel kernel, per-call form: builds a transient plan and executes.
pub fn rbgp4mm_parallel(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize, threads: usize) {
    let mut plan = Rbgp4Plan::build(&w.mask, n, threads);
    rbgp4mm_parallel_with_plan(w, &mut plan, i, o, n);
}

struct SendPtr(*mut f32);
// SAFETY: SendPtr is only shared across the scoped workers above, which
// never dereference the same offset twice: the dynamic `uo` counter
// partitions the pointee into disjoint tile-row slices, so concurrent
// `&SendPtr` access never produces aliasing writes.
unsafe impl Sync for SendPtr {}

/// Compute one output tile row (all rows with this `u_o`) into `ochunk`
/// (length tile_m × n, starting at global row `uo·tile_m`).
#[allow(clippy::too_many_arguments)]
fn tile_row_worker(
    w: &Rbgp4Matrix,
    i: &[f32],
    ochunk: &mut [f32],
    n: usize,
    uo: usize,
    local_cols: &[u32],
    trn: usize,
    stride: usize,
    gather: bool,
    ksplit: usize,
    pack: &mut [f32],
) {
    let mask = &w.mask;
    let c = &mask.config;
    let (mi, mb) = (c.gi.nu, c.gb.0);
    let rn = c.row_nnz();
    let rep = c.row_repetition();
    let tm = c.tile_m();
    let tk = c.tile_k();
    let mut n0 = 0;
    while n0 < n {
        let nb = stride.min(n - n0);
        for (ko, &vo) in mask.go.adj[uo].iter().enumerate() {
            for ui in 0..mi {
                let lci = &local_cols[ui * trn..(ui + 1) * trn];
                let panel = if gather {
                    PanelRef::Gather {
                        i,
                        n,
                        n0,
                        tile_base: vo * tk,
                        lci,
                    }
                } else {
                    pack_panel(mask, i, n, n0, nb, vo, lci, pack, stride);
                    PanelRef::Packed {
                        pack: &*pack,
                        stride,
                    }
                };
                let local_row = |g: usize| ((g / mb) * mi + ui) * mb + g % mb;
                let global_row = |g: usize| uo * tm + local_row(g);
                rep_group_gemm(
                    &w.data,
                    rn,
                    ko * trn,
                    trn,
                    ochunk,
                    n,
                    n0,
                    nb,
                    rep,
                    ksplit,
                    &global_row,
                    &local_row,
                    &panel,
                );
            }
        }
        n0 += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::gemm_naive;
    use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config};
    use crate::util::rng::Rng;

    fn mk(config: Rbgp4Config, seed: u64) -> (Rbgp4Matrix, Rng) {
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(config, &mut rng).unwrap();
        let w = Rbgp4Matrix::random(mask, &mut rng);
        (w, rng)
    }

    fn check_all_kernels(config: Rbgp4Config, n: usize, seed: u64) {
        let (w, mut rng) = mk(config, seed);
        let (m, k) = (w.mask.rows(), w.mask.cols());
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut oracle = vec![0.0; m * n];
        gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);

        for (name, o) in [
            ("naive", {
                let mut o = vec![0.0; m * n];
                rbgp4mm_naive(&w, &i, &mut o, n);
                o
            }),
            ("packed", {
                let mut o = vec![0.0; m * n];
                rbgp4mm(&w, &i, &mut o, n);
                o
            }),
            ("parallel", {
                let mut o = vec![0.0; m * n];
                rbgp4mm_parallel(&w, &i, &mut o, n, 4);
                o
            }),
            ("cached-plan", {
                let mut plan = Rbgp4Plan::build(&w.mask, n, 1);
                let mut o = vec![0.0; m * n];
                // Execute twice from the same plan: the second run must not
                // be perturbed by scratch left over from the first.
                rbgp4mm_with_plan(&w, &mut plan, &i, &mut o, n);
                rbgp4mm_with_plan(&w, &mut plan, &i, &mut o, n);
                o
            }),
        ] {
            for (idx, (a, b)) in o.iter().zip(&oracle).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{name} idx {idx}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn small_config_matches_dense() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        check_all_kernels(c, 9, 1000);
    }

    #[test]
    fn figure1_like_config() {
        // Fig 1: G_o and G_i 50% sparse, G_r=(2,1), G_b=(2,2).
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 2, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(2, 2, 0.5),
            gb: (2, 2),
        };
        check_all_kernels(c, 8, 1001);
    }

    #[test]
    fn no_row_repetition_config() {
        let c = Rbgp4Config {
            go: GraphSpec::new(8, 8, 0.75),
            gr: (1, 1),
            gi: GraphSpec::new(8, 8, 0.5),
            gb: (1, 1),
        };
        check_all_kernels(c, 17, 1002);
    }

    #[test]
    fn dense_tiles_config() {
        // G_i complete (sp=0): only tile-level sparsity.
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.75),
            gr: (2, 2),
            gi: GraphSpec::new(4, 4, 0.0),
            gb: (2, 1),
        };
        check_all_kernels(c, 32, 1003);
    }

    #[test]
    fn n_larger_than_block() {
        // n > NC exercises the column-blocking path.
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 1),
        };
        check_all_kernels(c, NC + 37, 1004);
    }

    #[test]
    fn batch_of_one() {
        // n = 1: the panel stride degenerates to a single column.
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 2),
        };
        check_all_kernels(c, 1, 1007);
    }

    #[test]
    fn parallel_thread_counts_agree() {
        let c = Rbgp4Config {
            go: GraphSpec::new(8, 8, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 2),
        };
        let (w, mut rng) = mk(c, 1005);
        let n = 19;
        let i = rng.normal_vec_f32(w.mask.cols() * n, 1.0);
        let mut o1 = vec![0.0; w.mask.rows() * n];
        let mut o2 = vec![0.0; w.mask.rows() * n];
        rbgp4mm_parallel(&w, &i, &mut o1, n, 1);
        rbgp4mm_parallel(&w, &i, &mut o2, n, 7);
        // 1-thread path delegates to the vo-major serial kernel; threaded
        // path is ko-major — summation order differs, so compare with ulp
        // tolerance rather than bitwise.
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn plan_reuses_across_inputs_and_threads() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 8, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 1),
        };
        let (w, mut rng) = mk(c, 1008);
        let (m, k, n) = (w.mask.rows(), w.mask.cols(), 13);
        let mut plan = Rbgp4Plan::build(&w.mask, n, 4);
        assert_eq!(plan.threads(), 4);
        for trial in 0..3 {
            let i = rng.normal_vec_f32(k * n, 1.0);
            let mut o = vec![0.0; m * n];
            rbgp4mm_parallel_with_plan(&w, &mut plan, &i, &mut o, n);
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);
            for (a, b) in o.iter().zip(&oracle) {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "trial {trial}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn tuned_schedules_are_bit_identical_within_a_regime() {
        let c = Rbgp4Config {
            go: GraphSpec::new(8, 8, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 2),
        };
        let (w, mut rng) = mk(c, 1010);
        let n = 19;
        let (m, k) = (w.mask.rows(), w.mask.cols());
        let i = rng.normal_vec_f32(k * n, 1.0);
        for threads in [1usize, 4] {
            let heur = Rbgp4Tunable::heuristic(&w.mask, n, threads);
            let mut reference = vec![0.0; m * n];
            let mut base = Rbgp4Plan::build_tuned(&w.mask, n, &heur);
            rbgp4mm_parallel_with_plan(&w, &mut base, &i, &mut reference, n);
            let variants = [
                Rbgp4Tunable {
                    gather: true,
                    ..heur
                },
                Rbgp4Tunable {
                    stride: (heur.stride / 2).max(1),
                    ..heur
                },
                Rbgp4Tunable {
                    stride: heur.stride * 2,
                    gather: true,
                    ..heur
                },
            ];
            for (vix, tun) in variants.iter().enumerate() {
                let mut plan = Rbgp4Plan::build_tuned(&w.mask, n, tun);
                assert_eq!(plan.threads(), base.threads(), "regime preserved");
                assert_eq!(plan.is_gather(), tun.gather);
                let mut o = vec![0.0; m * n];
                rbgp4mm_parallel_with_plan(&w, &mut plan, &i, &mut o, n);
                assert_eq!(o, reference, "variant {vix} at threads={threads}");
            }
        }
        // Worker-count variation within the parallel regime (≥ 2 workers)
        // is bitwise too: each tile row is computed whole by one worker.
        let heur = Rbgp4Tunable::heuristic(&w.mask, n, 4);
        assert!(heur.workers >= 2);
        let mut p4 = Rbgp4Plan::build_tuned(&w.mask, n, &heur);
        let mut p2 = Rbgp4Plan::build_tuned(
            &w.mask,
            n,
            &Rbgp4Tunable {
                workers: 2,
                ..heur
            },
        );
        let (mut o4, mut o2) = (vec![0.0; m * n], vec![0.0; m * n]);
        rbgp4mm_parallel_with_plan(&w, &mut p4, &i, &mut o4, n);
        rbgp4mm_parallel_with_plan(&w, &mut p2, &i, &mut o2, n);
        assert_eq!(o4, o2);
    }

    #[test]
    fn ksplit_matches_strict_order_within_tolerance() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.75),
            gr: (2, 2),
            gi: GraphSpec::new(4, 4, 0.0),
            gb: (2, 1),
        };
        let (w, mut rng) = mk(c, 1011);
        let n = 21;
        let (m, k) = (w.mask.rows(), w.mask.cols());
        let i = rng.normal_vec_f32(k * n, 1.0);
        for threads in [1usize, 4] {
            let heur = Rbgp4Tunable::heuristic(&w.mask, n, threads);
            let mut reference = vec![0.0; m * n];
            let mut base = Rbgp4Plan::build_tuned(&w.mask, n, &heur);
            rbgp4mm_parallel_with_plan(&w, &mut base, &i, &mut reference, n);
            let mut plan = Rbgp4Plan::build_tuned(&w.mask, n, &Rbgp4Tunable { ksplit: 2, ..heur });
            assert_eq!(plan.ksplit(), 2);
            let (mut o1, mut o2) = (vec![0.0; m * n], vec![0.0; m * n]);
            rbgp4mm_parallel_with_plan(&w, &mut plan, &i, &mut o1, n);
            rbgp4mm_parallel_with_plan(&w, &mut plan, &i, &mut o2, n);
            for (a, b) in o1.iter().zip(&reference) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "threads={threads}: {a} vs {b}"
                );
            }
            // Re-associated, but still deterministic run to run.
            assert_eq!(o1, o2, "threads={threads}");
        }
    }

    #[test]
    fn ksplit_clamps_on_short_panels_and_wide_strides() {
        // trn = 2 < 2·ksplit: fall back to the strict order.
        let short = Rbgp4Config {
            go: GraphSpec::new(2, 2, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(2, 2, 0.5),
            gb: (1, 2),
        };
        let mut rng = Rng::new(1012);
        let mask = Rbgp4Mask::sample(short, &mut rng).unwrap();
        let tun = Rbgp4Tunable {
            ksplit: 2,
            ..Rbgp4Tunable::heuristic(&mask, 8, 1)
        };
        assert_eq!(Rbgp4Plan::build_tuned(&mask, 8, &tun).ksplit(), 1);

        // Stride wider than the stack accumulator: clamp too.
        let wide = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.75),
            gr: (2, 2),
            gi: GraphSpec::new(4, 4, 0.0),
            gb: (2, 1),
        };
        let mask = Rbgp4Mask::sample(wide, &mut rng).unwrap();
        let n = 2 * KSPLIT_NB_MAX;
        let tun = Rbgp4Tunable {
            stride: 2 * KSPLIT_NB_MAX,
            ksplit: 2,
            ..Rbgp4Tunable::heuristic(&mask, n, 1)
        };
        let plan = Rbgp4Plan::build_tuned(&mask, n, &tun);
        assert!(plan.stride() > KSPLIT_NB_MAX);
        assert_eq!(plan.ksplit(), 1);
    }

    #[test]
    fn plan_stride_tracks_batch_class() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let mut rng = Rng::new(1009);
        let mask = Rbgp4Mask::sample(c, &mut rng).unwrap();
        assert_eq!(Rbgp4Plan::build(&mask, 1, 1).stride(), 1);
        assert_eq!(Rbgp4Plan::build(&mask, 9, 1).stride(), 16);
        assert_eq!(Rbgp4Plan::build(&mask, 256, 1).stride(), 256);
        assert_eq!(Rbgp4Plan::build(&mask, 4096, 1).stride(), NC);
    }

    #[test]
    fn local_cols_sorted_and_sized() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (1, 2),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let (w, _) = mk(c, 1006);
        let lc = local_cols(&w.mask);
        assert_eq!(lc.len(), 4);
        for cols in &lc {
            assert_eq!(cols.len(), c.tile_row_nnz());
            assert!(cols.windows(2).all(|x| x[0] < x[1]));
            assert!(cols.iter().all(|&x| x < c.tile_k()));
        }
        // The plan's flattened offsets agree with the reference derivation.
        let plan = Rbgp4Plan::build(&w.mask, 8, 1);
        for (ui, cols) in lc.iter().enumerate() {
            let flat = &plan.local_cols[ui * plan.trn..(ui + 1) * plan.trn];
            assert!(flat.iter().map(|&x| x as usize).eq(cols.iter().copied()));
        }
    }
}
