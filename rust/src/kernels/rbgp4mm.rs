//! RBGP4MM: `O = W_s · I` with `W_s` in RBGP4 compact storage —
//! Algorithm 1 (Appendix 8.2) adapted from CUDA to a cache-hierarchy CPU.
//!
//! The GPU schedule maps onto the CPU as:
//!
//! * thread block / output tile `OT`  → loop over `(u_o, u_i)` row groups
//! * `G_o` tile skipping              → only `d_o` packed steps per tile row
//! * shared-memory staging of `IT`    → `pack` buffer: the `tile_row_nnz`
//!   rows of `I` a tile touches are gathered once into contiguous memory
//! * register-level row repetition    → the packed panel is then hit with a
//!   dense micro-GEMM over all `|G_r.U|·|G_b.U|` repeated rows, so every
//!   packed element is reused `row_repetition` times from L1
//!
//! Pack reuse is maximized by iterating `(v_o, u_i)` on the outside and
//! walking `G_o`'s *right* adjacency: one packed panel serves every tile row
//! `u_o` adjacent to `v_o` (d_r(G_o) tile rows × row_repetition rows each).

use crate::sparsity::rbgp4::{Rbgp4Mask, Rbgp4Matrix};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Precomputed intra-tile column offsets: for each `u_i`, the tile-local
/// columns of its `tile_row_nnz` non-zeros (ascending). This is `m_i ×
/// tile_row_nnz` integers — part of the succinct index, derived from
/// `adj_i` once per matrix, never per call.
pub fn local_cols(mask: &Rbgp4Mask) -> Vec<Vec<usize>> {
    let c = &mask.config;
    (0..c.gi.nu)
        .map(|ui| {
            let mut cols = Vec::with_capacity(c.tile_row_nnz());
            for vr in 0..c.gr.1 {
                for &vi in &mask.gi.adj[ui] {
                    for vb in 0..c.gb.1 {
                        cols.push((vr * c.gi.nv + vi) * c.gb.1 + vb);
                    }
                }
            }
            cols
        })
        .collect()
}

/// Reference row-at-a-time kernel (correctness oracle; no packing, no
/// grouping). `i` is (cols × n) row-major, `o` is (rows × n).
pub fn rbgp4mm_naive(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize) {
    let mask = &w.mask;
    let c = &mask.config;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    o.fill(0.0);
    let lc = local_cols(mask);
    let (tk, rn) = (c.tile_k(), c.row_nnz());
    for u in 0..mask.rows() {
        let (uo, _ur, ui, _ub) = mask.row_coords(u);
        let orow = &mut o[u * n..(u + 1) * n];
        let wrow = &w.data[u * rn..(u + 1) * rn];
        let mut k = 0;
        for &vo in &mask.go.adj[uo] {
            let tile_base = vo * tk;
            for &off in &lc[ui] {
                let a = wrow[k];
                k += 1;
                let irow = &i[(tile_base + off) * n..(tile_base + off) * n + n];
                for cix in 0..n {
                    orow[cix] += a * irow[cix];
                }
            }
        }
    }
}

/// Column-block size for the packed panel: chosen so (tile_row_nnz + group)
/// rows of NC f32 stay L1/L2-resident for the paper's configs. Perf §L3
/// iter 2 swept {128, 256, 512, 1024}: 512 is 17 % faster than 256 on the
/// Table-2 config (2 KiB per panel row amortizes the pack copy without
/// spilling L2).
const NC: usize = 512;

/// Optimized serial kernel: gather-pack + grouped micro-GEMM (see module
/// docs). Iterates `(v_o, u_i)`, packs once, reuses the panel across all
/// adjacent tile rows and all repeated rows.
pub fn rbgp4mm(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize) {
    let mask = &w.mask;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    o.fill(0.0);
    let radj_o = mask.go.right_adj();
    let lc = local_cols(mask);
    let mut pack = vec![0.0f32; mask.config.tile_row_nnz() * NC];
    let mut n0 = 0;
    while n0 < n {
        let nb = NC.min(n - n0);
        for vo in 0..mask.config.go.nv {
            for (ui, lci) in lc.iter().enumerate() {
                pack_panel(mask, i, n, n0, nb, vo, lci, &mut pack);
                for &uo in &radj_o[vo] {
                    // ko = position of vo within adj_o[uo] (compact k offset).
                    let ko = mask.go.adj[uo].binary_search(&vo).expect("vo adjacent");
                    group_micro_gemm(w, o, n, n0, nb, uo, ui, ko, &pack);
                }
            }
        }
        n0 += nb;
    }
}

/// Gather the `tile_row_nnz` rows of `I` that tile column `v_o` and intra-
/// tile pattern `u_i` touch, restricted to columns [n0, n0+nb), into `pack`.
#[inline]
fn pack_panel(
    mask: &Rbgp4Mask,
    i: &[f32],
    n: usize,
    n0: usize,
    nb: usize,
    vo: usize,
    lci: &[usize],
    pack: &mut [f32],
) {
    let tk = mask.config.tile_k();
    let tile_base = vo * tk;
    for (p, &off) in lci.iter().enumerate() {
        let src = (tile_base + off) * n + n0;
        pack[p * NC..p * NC + nb].copy_from_slice(&i[src..src + nb]);
    }
}

/// Accumulate the contribution of step `ko` into every row of the
/// `(u_o, u_i)` repetition group: a dense (group × tile_row_nnz)·(tile_row_nnz
/// × nb) micro-GEMM against the packed panel.
#[inline]
fn group_micro_gemm(
    w: &Rbgp4Matrix,
    o: &mut [f32],
    n: usize,
    n0: usize,
    nb: usize,
    uo: usize,
    ui: usize,
    ko: usize,
    pack: &[f32],
) {
    let c = &w.mask.config;
    let (mr, mi, mb) = (c.gr.0, c.gi.nu, c.gb.0);
    let trn = c.tile_row_nnz();
    let rn = c.row_nnz();
    let kbase = ko * trn;
    for ur in 0..mr {
        for ub in 0..mb {
            let u = ((uo * mr + ur) * mi + ui) * mb + ub;
            let wrow = &w.data[u * rn + kbase..u * rn + kbase + trn];
            let orow = &mut o[u * n + n0..u * n + n0 + nb];
            // One output row vs the whole packed panel; 4-wide panel
            // unroll (perf §L3 iter 1: within noise of 2-wide — kept for
            // fewer orow passes at large tile_row_nnz).
            let mut p = 0;
            while p + 4 <= trn {
                let (a0, a1, a2, a3) = (wrow[p], wrow[p + 1], wrow[p + 2], wrow[p + 3]);
                let r0 = &pack[p * NC..p * NC + nb];
                let r1 = &pack[(p + 1) * NC..(p + 1) * NC + nb];
                let r2 = &pack[(p + 2) * NC..(p + 2) * NC + nb];
                let r3 = &pack[(p + 3) * NC..(p + 3) * NC + nb];
                for cix in 0..nb {
                    orow[cix] += a0 * r0[cix] + a1 * r1[cix] + a2 * r2[cix] + a3 * r3[cix];
                }
                p += 4;
            }
            while p < trn {
                let a = wrow[p];
                let r = &pack[p * NC..p * NC + nb];
                for cix in 0..nb {
                    orow[cix] += a * r[cix];
                }
                p += 1;
            }
        }
    }
}

/// Parallel kernel: output tile rows `u_o` are distributed across threads
/// (disjoint output), each with a private pack buffer. Pack reuse inside a
/// thread is per-(u_o): `d_o · m_i` packs serving `row_repetition` rows each.
pub fn rbgp4mm_parallel(w: &Rbgp4Matrix, i: &[f32], o: &mut [f32], n: usize, threads: usize) {
    let mask = &w.mask;
    assert_eq!(i.len(), mask.cols() * n);
    assert_eq!(o.len(), mask.rows() * n);
    let c = &mask.config;
    let m_o = c.go.nu;
    let threads = threads.max(1).min(m_o);
    if threads == 1 {
        rbgp4mm(w, i, o, n);
        return;
    }
    let lc = local_cols(mask);
    let tile_rows = c.tile_m() * n; // output elems per tile row
    let next = AtomicUsize::new(0);
    // Hand out tile rows dynamically; each chunk writes a disjoint region.
    let o_ptr = SendPtr(o.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let lc = &lc;
            let next = &next;
            let o_ptr = &o_ptr;
            scope.spawn(move || {
                let mut pack = vec![0.0f32; c.tile_row_nnz() * NC];
                loop {
                    let uo = next.fetch_add(1, Ordering::Relaxed);
                    if uo >= m_o {
                        break;
                    }
                    // Safety: each uo owns rows [uo*TM, (uo+1)*TM) — disjoint.
                    let ochunk = unsafe {
                        std::slice::from_raw_parts_mut(o_ptr.0.add(uo * tile_rows), tile_rows)
                    };
                    ochunk.fill(0.0);
                    tile_row_worker(w, i, ochunk, n, uo, lc, &mut pack);
                }
            });
        }
    });
}

struct SendPtr(*mut f32);
unsafe impl Sync for SendPtr {}

/// Compute one output tile row (all rows with this `u_o`) into `ochunk`
/// (length tile_m × n, starting at global row `uo·tile_m`).
fn tile_row_worker(
    w: &Rbgp4Matrix,
    i: &[f32],
    ochunk: &mut [f32],
    n: usize,
    uo: usize,
    lc: &[Vec<usize>],
    pack: &mut [f32],
) {
    let mask = &w.mask;
    let c = &mask.config;
    let (mr, mi, mb) = (c.gr.0, c.gi.nu, c.gb.0);
    let trn = c.tile_row_nnz();
    let rn = c.row_nnz();
    let mut n0 = 0;
    while n0 < n {
        let nb = NC.min(n - n0);
        for (ko, &vo) in mask.go.adj[uo].iter().enumerate() {
            for (ui, lci) in lc.iter().enumerate() {
                pack_panel(mask, i, n, n0, nb, vo, lci, pack);
                let kbase = ko * trn;
                for ur in 0..mr {
                    for ub in 0..mb {
                        let local_u = (ur * mi + ui) * mb + ub;
                        let global_u = uo * c.tile_m() + local_u;
                        let wrow = &w.data[global_u * rn + kbase..global_u * rn + kbase + trn];
                        let orow = &mut ochunk[local_u * n + n0..local_u * n + n0 + nb];
                        for (p, &a) in wrow.iter().enumerate() {
                            let r = &pack[p * NC..p * NC + nb];
                            for cix in 0..nb {
                                orow[cix] += a * r[cix];
                            }
                        }
                    }
                }
            }
        }
        n0 += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::gemm_naive;
    use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config};
    use crate::util::rng::Rng;

    fn mk(config: Rbgp4Config, seed: u64) -> (Rbgp4Matrix, Rng) {
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(config, &mut rng).unwrap();
        let w = Rbgp4Matrix::random(mask, &mut rng);
        (w, rng)
    }

    fn check_all_kernels(config: Rbgp4Config, n: usize, seed: u64) {
        let (w, mut rng) = mk(config, seed);
        let (m, k) = (w.mask.rows(), w.mask.cols());
        let i = rng.normal_vec_f32(k * n, 1.0);
        let mut oracle = vec![0.0; m * n];
        gemm_naive(&w.to_dense(), &i, &mut oracle, m, k, n);

        for (name, o) in [
            ("naive", {
                let mut o = vec![0.0; m * n];
                rbgp4mm_naive(&w, &i, &mut o, n);
                o
            }),
            ("packed", {
                let mut o = vec![0.0; m * n];
                rbgp4mm(&w, &i, &mut o, n);
                o
            }),
            ("parallel", {
                let mut o = vec![0.0; m * n];
                rbgp4mm_parallel(&w, &i, &mut o, n, 4);
                o
            }),
        ] {
            for (idx, (a, b)) in o.iter().zip(&oracle).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{name} idx {idx}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn small_config_matches_dense() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        check_all_kernels(c, 9, 1000);
    }

    #[test]
    fn figure1_like_config() {
        // Fig 1: G_o and G_i 50% sparse, G_r=(2,1), G_b=(2,2).
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 2, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(2, 2, 0.5),
            gb: (2, 2),
        };
        check_all_kernels(c, 8, 1001);
    }

    #[test]
    fn no_row_repetition_config() {
        let c = Rbgp4Config {
            go: GraphSpec::new(8, 8, 0.75),
            gr: (1, 1),
            gi: GraphSpec::new(8, 8, 0.5),
            gb: (1, 1),
        };
        check_all_kernels(c, 17, 1002);
    }

    #[test]
    fn dense_tiles_config() {
        // G_i complete (sp=0): only tile-level sparsity.
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.75),
            gr: (2, 2),
            gi: GraphSpec::new(4, 4, 0.0),
            gb: (2, 1),
        };
        check_all_kernels(c, 32, 1003);
    }

    #[test]
    fn n_larger_than_block() {
        // n > NC exercises the column-blocking path.
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 1),
        };
        check_all_kernels(c, NC + 37, 1004);
    }

    #[test]
    fn parallel_thread_counts_agree() {
        let c = Rbgp4Config {
            go: GraphSpec::new(8, 8, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (1, 2),
        };
        let (w, mut rng) = mk(c, 1005);
        let n = 19;
        let i = rng.normal_vec_f32(w.mask.cols() * n, 1.0);
        let mut o1 = vec![0.0; w.mask.rows() * n];
        let mut o2 = vec![0.0; w.mask.rows() * n];
        rbgp4mm_parallel(&w, &i, &mut o1, n, 1);
        rbgp4mm_parallel(&w, &i, &mut o2, n, 7);
        // 1-thread path delegates to the vo-major serial kernel; threaded
        // path is ko-major — summation order differs, so compare with ulp
        // tolerance rather than bitwise.
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn local_cols_sorted_and_sized() {
        let c = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (1, 2),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let (w, _) = mk(c, 1006);
        let lc = local_cols(&w.mask);
        assert_eq!(lc.len(), 4);
        for cols in &lc {
            assert_eq!(cols.len(), c.tile_row_nnz());
            assert!(cols.windows(2).all(|x| x[0] < x[1]));
            assert!(cols.iter().all(|&x| x < c.tile_k()));
        }
    }
}
