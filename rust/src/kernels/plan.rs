//! Execution plans: the "build once, execute many" layer (§4–§5 of the
//! paper, and the architectural point of the Sparsity Roofline literature).
//!
//! The paper's RBGP4 speed claim rests on the succinct index being *derived
//! structure*: tile adjacency, intra-tile column offsets, pack layouts and
//! scratch memory depend only on the mask and the batch-size class — never
//! on the input values — so they can be computed once per
//! `(matrix, batch class, threads)` and reused on every call. A
//! [`KernelPlan`] captures exactly that derived structure; executing from a
//! plan is allocation-free on the hot path.
//!
//! Layer map:
//! * [`SparseMatrix`] — one weight operand in any of the four storage
//!   formats the evaluation compares (dense / CSR / BSR / RBGP4 compact).
//! * [`crate::kernels::registry::SparseKernel`] — the per-family trait that
//!   builds plans and executes from them.
//! * [`PlanCache`] — concurrent map from [`PlanKey`] (structure hash +
//!   shape + batch class + threads) to built plans, shared by the server
//!   batcher, the native trainer and the bench harness.

use crate::kernels::autotune::{TuneCache, TuneMode, TunedConfig};
use crate::sparsity::bsr::BsrMatrix;
use crate::sparsity::csr::CsrMatrix;
use crate::sparsity::memory::Pattern;
use crate::sparsity::rbgp4::Rbgp4Matrix;
use crate::util::{lock_recover, Fnv};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One SDMM weight operand `W (rows × cols)` in a concrete storage format.
/// This is the value every consumer (kernels, cost model, server, trainer,
/// benches) dispatches on, keyed by [`Pattern`].
#[derive(Clone, Debug)]
pub enum SparseMatrix {
    /// Row-major dense storage (the cuBLAS stand-in).
    Dense {
        data: Vec<f32>,
        rows: usize,
        cols: usize,
    },
    /// Unstructured CSR (the cuSparse-CSR stand-in).
    Csr(CsrMatrix),
    /// Block BSR (the cuSparse-BSR stand-in).
    Bsr(BsrMatrix),
    /// RBGP4 compact storage (the paper's format).
    Rbgp4(Rbgp4Matrix),
}

impl SparseMatrix {
    pub fn dense(data: Vec<f32>, rows: usize, cols: usize) -> SparseMatrix {
        assert_eq!(data.len(), rows * cols, "dense data/shape mismatch");
        SparseMatrix::Dense { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        match self {
            SparseMatrix::Dense { rows, .. } => *rows,
            SparseMatrix::Csr(w) => w.rows,
            SparseMatrix::Bsr(w) => w.rows,
            SparseMatrix::Rbgp4(w) => w.mask.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SparseMatrix::Dense { cols, .. } => *cols,
            SparseMatrix::Csr(w) => w.cols,
            SparseMatrix::Bsr(w) => w.cols,
            SparseMatrix::Rbgp4(w) => w.mask.cols(),
        }
    }

    /// The [`Pattern`] key this matrix dispatches under — shared with
    /// [`crate::gpusim::KernelKind::pattern`] so the cost model and the
    /// measured kernels select by the same key.
    pub fn pattern(&self) -> Pattern {
        match self {
            SparseMatrix::Dense { .. } => Pattern::Dense,
            SparseMatrix::Csr(_) => Pattern::Unstructured,
            SparseMatrix::Bsr(w) => Pattern::Block(w.bh, w.bw),
            SparseMatrix::Rbgp4(_) => Pattern::Rbgp4,
        }
    }

    /// Stored non-zeros (dense counts every element, as cuBLAS computes all).
    pub fn nnz(&self) -> usize {
        match self {
            SparseMatrix::Dense { rows, cols, .. } => rows * cols,
            SparseMatrix::Csr(w) => w.nnz(),
            SparseMatrix::Bsr(w) => w.nnz_stored(),
            SparseMatrix::Rbgp4(w) => w.mask.rows() * w.mask.config.row_nnz(),
        }
    }

    /// Fractional sparsity of the stored pattern (dense = 0).
    pub fn sparsity(&self) -> f64 {
        match self {
            SparseMatrix::Dense { .. } => 0.0,
            SparseMatrix::Csr(w) => w.sparsity(),
            SparseMatrix::Bsr(w) => w.sparsity(),
            SparseMatrix::Rbgp4(w) => w.mask.config.sparsity(),
        }
    }

    /// FLOPs of one SDMM against an `n`-column input (2·nnz·n).
    pub fn flops(&self, n: usize) -> f64 {
        2.0 * self.nnz() as f64 * n as f64
    }

    /// Minimum bytes one SDMM against an `n`-column input must move:
    /// weight values + the format's index structure + one read of the
    /// input + one write of the output (all f32/u32 words, 4 bytes). This
    /// is the compulsory-traffic denominator of arithmetic intensity —
    /// the Sparsity Roofline's x-axis — and deliberately counts each
    /// operand once (no cache-miss modelling), matching how the machine
    /// probe's triad counts its streams.
    pub fn bytes_touched(&self, n: usize) -> f64 {
        const B: f64 = 4.0;
        let io = B * (self.cols() * n + self.rows() * n) as f64;
        let weights_and_index = match self {
            // Dense: the values array is the whole story.
            SparseMatrix::Dense { rows, cols, .. } => B * (rows * cols) as f64,
            // CSR: values + one column index per nnz + row pointers.
            SparseMatrix::Csr(w) => B * (2 * w.nnz() + w.rows + 1) as f64,
            // BSR: stored block values + one column index per block +
            // block-row pointers.
            SparseMatrix::Bsr(w) => {
                B * (w.nnz_stored() + w.indices.len() + w.block_rows() + 1) as f64
            }
            // RBGP4: stored values + the succinct index (§4 memory
            // accounting — graph edges, not per-nnz coordinates).
            SparseMatrix::Rbgp4(w) => {
                B * (w.mask.rows() * w.mask.config.row_nnz() + w.mask.succinct_index_elems())
                    as f64
            }
        };
        weights_and_index + io
    }

    /// Arithmetic intensity (flops per compulsory byte) of one SDMM at
    /// batch `n` — rises with `n` as weight traffic amortizes.
    pub fn arithmetic_intensity(&self, n: usize) -> f64 {
        self.flops(n) / self.bytes_touched(n).max(1.0)
    }

    /// Scatter to a dense row-major matrix (oracle side of property tests).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            SparseMatrix::Dense { data, .. } => data.clone(),
            SparseMatrix::Csr(w) => w.to_dense(),
            SparseMatrix::Bsr(w) => w.to_dense(),
            SparseMatrix::Rbgp4(w) => w.to_dense(),
        }
    }

    /// Hash of the *structure* (shape + connectivity, not values): two
    /// matrices with equal structure hashes can share an execution plan.
    /// Dense plans depend only on the shape, so dense hashes ignore values —
    /// which is what lets a trainer update weights in place without
    /// invalidating its cached plans.
    pub fn structure_hash(&self) -> u64 {
        let mut h = Fnv::new();
        match self {
            SparseMatrix::Dense { rows, cols, .. } => {
                h.push(1);
                h.push(*rows as u64);
                h.push(*cols as u64);
            }
            SparseMatrix::Csr(w) => {
                h.push(2);
                h.push(w.rows as u64);
                h.push(w.cols as u64);
                h.push_all(w.indptr.iter().map(|&x| x as u64));
                h.push_all(w.indices.iter().map(|&x| x as u64));
            }
            SparseMatrix::Bsr(w) => {
                h.push(3);
                h.push(w.rows as u64);
                h.push(w.cols as u64);
                h.push(w.bh as u64);
                h.push(w.bw as u64);
                h.push_all(w.indptr.iter().map(|&x| x as u64));
                h.push_all(w.indices.iter().map(|&x| x as u64));
            }
            SparseMatrix::Rbgp4(w) => {
                h.push(4);
                h.push(w.mask.structure_hash());
            }
        }
        h.finish()
    }
}

/// Batch-size class a plan is built for: the next power of two, so nearby
/// batch sizes (the dynamic batcher's partial flushes) share one plan.
pub fn batch_class(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// What a caller asks of `build_plan`.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    /// Expected input columns (batch size); the plan is sized for
    /// `batch_class(n)` and stays valid — merely sub-optimal — beyond it.
    pub n: usize,
    /// Worker threads the execute path may use (clamped per family).
    pub threads: usize,
    /// How hard `build_plan` searches for a schedule (see
    /// [`TuneMode`]); deliberately *not* part of [`PlanKey`] — tuning
    /// changes which plan gets cached, never how it is keyed.
    pub tune: TuneMode,
    /// Absolute+relative tolerance (`|a−b| ≤ tol·(1+|b|)` per element)
    /// under which the search may admit candidates that *re-associate the
    /// inner reduction* (k-split partial-sum trees, accumulator fanning).
    /// `None` — the default — keeps the strict bit-identity contract: no
    /// reduction-reordering candidate is ever generated, let alone
    /// admitted. Candidates over tolerance at search-time validation are
    /// rejected and counted (`autotune::tolerance_rejections`).
    pub reduce_tol: Option<f64>,
    /// Persistent tuning cache consulted before measuring and appended to
    /// after a search (see [`TuneCache`]). `None` falls back to whatever
    /// cache is attached to the [`PlanCache`] this request resolves
    /// through, then to "no persistence".
    pub tune_cache: Option<Arc<TuneCache>>,
}

impl PlanRequest {
    /// A request with the default tune mode ([`TuneMode::Quick`]).
    pub fn new(n: usize, threads: usize) -> PlanRequest {
        PlanRequest {
            n,
            threads,
            tune: TuneMode::default(),
            reduce_tol: None,
            tune_cache: None,
        }
    }

    pub fn with_tune(mut self, tune: TuneMode) -> PlanRequest {
        self.tune = tune;
        self
    }

    /// Admit reduction-reordering candidates validated at search time
    /// against the heuristic plan's output under `tol`.
    pub fn with_reduce_tol(mut self, tol: f64) -> PlanRequest {
        self.reduce_tol = Some(tol);
        self
    }

    /// Consult (and append to) a persistent [`TuneCache`] during the
    /// search.
    pub fn with_tune_cache(mut self, cache: Arc<TuneCache>) -> PlanRequest {
        self.tune_cache = Some(cache);
        self
    }
}

/// Family-specific prepared state (the part of a plan the kernels read).
#[derive(Clone)]
pub(crate) enum PlanState {
    /// Dense needs no derived structure beyond the thread count.
    Dense,
    /// CSR/BSR: nnz-balanced contiguous (block-)row ranges, one per
    /// worker, plus an output column block width (`0` = unblocked) and an
    /// accumulator fan width (`1` = strict left-to-right reduction; `> 1`
    /// is tolerance-gated — it re-associates the per-row sum into `fan`
    /// interleaved partial accumulators combined as a balanced tree).
    Ranges {
        ranges: Vec<(usize, usize)>,
        col_block: usize,
        fan: usize,
    },
    /// RBGP4: the full succinct-index derivation (see `rbgp4mm::Rbgp4Plan`).
    Rbgp4(Box<crate::kernels::rbgp4mm::Rbgp4Plan>),
}

/// A built execution plan: everything derivable from `(structure, batch
/// class, threads)`, including reusable scratch arenas. Executing from a
/// plan performs no allocation and no index derivation.
///
/// `Clone` copies the derived structure *and* the scratch — executors that
/// run concurrently (the serving worker pool) each detach a working copy
/// from the shared cache entry instead of serializing on its mutex.
#[derive(Clone)]
pub struct KernelPlan {
    pub pattern: Pattern,
    pub rows: usize,
    pub cols: usize,
    pub batch_class: usize,
    pub threads: usize,
    /// Wall-clock cost of building this plan — including any tuning
    /// search (reported by benches so the amortization claim stays
    /// measurable).
    pub build_seconds: f64,
    /// What the tuning search learned, when one ran ([`TuneMode::Off`]
    /// leaves `None`). Cached with the plan, so the roofline numbers are
    /// free to read on every later resolve.
    pub tuned: Option<TunedConfig>,
    pub(crate) state: PlanState,
}

/// Cache key: structure + shape + batch class + threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub family: u8,
    pub structure: u64,
    pub rows: usize,
    pub cols: usize,
    pub batch_class: usize,
    pub threads: usize,
}

impl PlanKey {
    pub fn of(w: &SparseMatrix, req: &PlanRequest) -> PlanKey {
        let family = match w.pattern() {
            Pattern::Dense => 0,
            Pattern::Unstructured => 1,
            Pattern::Block(_, _) => 2,
            Pattern::Rbgp4 => 3,
        };
        PlanKey {
            family,
            structure: w.structure_hash(),
            rows: w.rows(),
            cols: w.cols(),
            batch_class: batch_class(req.n),
            threads: req.threads.max(1),
        }
    }
}

/// Concurrent plan cache shared across the system: the server batcher, the
/// native trainer, the bench harness and ad-hoc callers all pull plans from
/// here instead of re-deriving structure per call.
///
/// The cache is *namespaced by structure hash*: every key carries the hash
/// of the connectivity it was derived from, so a caller whose structure
/// changes (the gradual trainer tightening its mask at a milestone, a
/// serving pool retiring a checkpoint) can evict exactly the plans of the
/// dead structure with [`PlanCache::invalidate_structure`] — or keep a
/// live set with [`PlanCache::retain_structures`] — without touching plans
/// other models still execute from. Eviction is accounted
/// ([`PlanCache::eviction_stats`]) so a long gradual run can assert it
/// leaks no plans for dead structures.
///
/// Every lock here is taken through the poison-recovering guard: a thread
/// that panics while holding a plan (or mid-insert) degrades one entry
/// instead of poisoning the whole cache for every other worker.
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<Mutex<KernelPlan>>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Calls to `invalidate_structure` (one per structure re-key).
    invalidations: AtomicUsize,
    /// Plans removed by invalidation/retention, total.
    evicted_plans: AtomicUsize,
    /// Bumped on every invalidation/retention — a cheap "the structure set
    /// changed" signal for callers that cache derived state of their own.
    generation: AtomicUsize,
    /// Optional persistent tuning cache every `plan_for` build consults
    /// (unless the request carries its own). Set once at startup
    /// ([`PlanCache::attach_tune_cache`]); later attaches are no-ops.
    tune_cache: OnceLock<Arc<TuneCache>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Attach a persistent [`TuneCache`] consulted by every build this
    /// cache performs (a request's own `tune_cache` still wins). First
    /// attach wins; returns whether this call attached.
    pub fn attach_tune_cache(&self, cache: Arc<TuneCache>) -> bool {
        self.tune_cache.set(cache).is_ok()
    }

    /// The attached persistent tuning cache, if any.
    pub fn tune_cache(&self) -> Option<Arc<TuneCache>> {
        self.tune_cache.get().cloned()
    }

    /// Fetch (or build and insert) the plan for `(w, req)`.
    pub fn plan_for(
        &self,
        registry: &crate::kernels::registry::KernelRegistry,
        w: &SparseMatrix,
        req: &PlanRequest,
    ) -> anyhow::Result<Arc<Mutex<KernelPlan>>> {
        let key = PlanKey::of(w, req);
        if let Some(plan) = lock_recover(&self.plans).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        // Build outside the map lock: plan construction can be slow and
        // must not serialize unrelated lookups. Two threads racing on the
        // same key may both build; the loser's plan is dropped and its
        // call counts as a hit (benign duplicated work, consistent stats).
        let kernel = registry.for_matrix(w)?;
        let built = kernel.build_plan(
            w,
            &PlanRequest {
                n: key.batch_class,
                threads: req.threads,
                tune: req.tune,
                reduce_tol: req.reduce_tol,
                tune_cache: req
                    .tune_cache
                    .clone()
                    .or_else(|| self.tune_cache.get().cloned()),
            },
        )?;
        let arc = Arc::new(Mutex::new(built));
        let mut map = lock_recover(&self.plans);
        match map.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(e.get()))
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(v.insert(arc)))
            }
        }
    }

    /// One-call convenience: plan lookup + execute.
    ///
    /// Note two costs a latency-critical caller can avoid by holding the
    /// `Arc` from [`PlanCache::plan_for`] — or, like
    /// [`crate::coordinator::serving::NativeSparseModel`], by detaching a
    /// private clone of the built plan: the key computation re-hashes the
    /// matrix structure (O(nnz index words) for CSR/BSR), and the plan's
    /// mutex is held for the whole execution — correct because RBGP4 plans
    /// carry mutable scratch arenas, but it serializes concurrent users of
    /// one plan.
    pub fn execute(
        &self,
        registry: &crate::kernels::registry::KernelRegistry,
        w: &SparseMatrix,
        input: &[f32],
        output: &mut [f32],
        n: usize,
        threads: usize,
    ) -> anyhow::Result<()> {
        let kernel = registry.for_matrix(w)?;
        let plan = self.plan_for(registry, w, &PlanRequest::new(n, threads))?;
        // Recover a poisoned plan lock: a peer that panicked mid-execute
        // left scratch (not derived structure) torn; the next execute
        // overwrites scratch entirely.
        let mut plan = lock_recover(&plan);
        kernel.execute(w, &mut plan, input, output, n)
    }

    /// Evict every plan derived from `structure` (all shapes, batch
    /// classes and thread counts), returning how many were removed. This
    /// is the re-key primitive: when a mask tightens (gradual training) or
    /// a served checkpoint is retired, its structure hash dies and its
    /// plans must not linger for the lifetime of a long run.
    ///
    /// Callers must quiesce their own builders for the dead structure
    /// first — a `plan_for` racing this call may re-insert a plan it
    /// started building before the eviction (it stays correct, merely
    /// resurrected; the next invalidation removes it).
    pub fn invalidate_structure(&self, structure: u64) -> usize {
        let removed = {
            let mut map = lock_recover(&self.plans);
            let before = map.len();
            map.retain(|key, _| key.structure != structure);
            before - map.len()
        };
        self.invalidations.fetch_add(1, Ordering::Relaxed);
        self.evicted_plans.fetch_add(removed, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Relaxed);
        removed
    }

    /// Keep only plans whose structure hash appears in `keep`, evicting
    /// everything else; returns how many were removed. The multi-model
    /// serving shape: one pool serving several checkpoints retires all
    /// dead namespaces in one sweep.
    pub fn retain_structures(&self, keep: &[u64]) -> usize {
        let removed = {
            let mut map = lock_recover(&self.plans);
            let before = map.len();
            map.retain(|key, _| keep.contains(&key.structure));
            before - map.len()
        };
        self.evicted_plans.fetch_add(removed, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Relaxed);
        removed
    }

    /// Distinct structure hashes currently cached (sorted, deduped).
    pub fn structures(&self) -> Vec<u64> {
        let mut s: Vec<u64> = lock_recover(&self.plans)
            .keys()
            .map(|k| k.structure)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Plans currently cached for one structure hash (over all shapes,
    /// batch classes and thread counts).
    pub fn structure_plan_count(&self, structure: u64) -> usize {
        lock_recover(&self.plans)
            .keys()
            .filter(|k| k.structure == structure)
            .count()
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// `(invalidate_structure calls, plans evicted)` since construction —
    /// the counters a gradual run checks to prove it re-keyed once per
    /// milestone and retained nothing for dead structures.
    pub fn eviction_stats(&self) -> (usize, usize) {
        (
            self.invalidations.load(Ordering::Relaxed),
            self.evicted_plans.load(Ordering::Relaxed),
        )
    }

    /// Monotone counter bumped by every invalidation/retention sweep.
    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.plans).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Split `indptr`-described rows into at most `threads` contiguous ranges
/// with approximately equal non-zero counts (work-balanced partition for
/// CSR rows / BSR block rows). Ranges are ascending, non-empty, cover
/// `0..rows` exactly, and — unless the matrix stores no non-zeros at all —
/// each carries at least one stored non-zero: with more threads than
/// non-empty rows the nnz targets degenerate and would hand some workers
/// all-empty ranges (a spawned thread that only zeroes output rows), so
/// zero-work ranges are folded into a neighbor. An all-empty matrix
/// collapses to a single covering range.
pub fn balanced_row_ranges(indptr: &[usize], threads: usize) -> Vec<(usize, usize)> {
    let rows = indptr.len().saturating_sub(1);
    if rows == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(rows);
    // analyze: allow(panic-freedom, reason="indptr is a CSR row pointer of len rows+1, so rows is in bounds")
    let total = indptr[rows];
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(threads);
    let mut r0 = 0usize;
    for t in 0..threads {
        if r0 >= rows {
            break;
        }
        // Cumulative-nnz boundary this chunk should reach.
        let target = total * (t + 1) / threads;
        let mut r1 = r0 + 1;
        // analyze: allow(panic-freedom, reason="r1 < rows is checked first and indptr has rows+1 entries")
        while r1 < rows && indptr[r1] < target {
            r1 += 1;
        }
        if t + 1 == threads {
            r1 = rows;
        }
        // Fold zero-work ranges: merge this range into the previous one
        // when either side carries no non-zeros (an empty head range is
        // extended by its non-empty successor, an empty tail absorbed by
        // its predecessor).
        // analyze: allow(panic-freedom, reason="r0, r1, and stored range bounds never exceed rows, and indptr has rows+1 entries")
        match ranges.last_mut() {
            Some(prev) if indptr[r1] == indptr[r0] || indptr[prev.1] == indptr[prev.0] => {
                prev.1 = r1;
            }
            _ => ranges.push((r0, r1)),
        }
        r0 = r1;
    }
    if let Some(last) = ranges.last_mut() {
        last.1 = rows;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn batch_class_rounds_up() {
        assert_eq!(batch_class(0), 1);
        assert_eq!(batch_class(1), 1);
        assert_eq!(batch_class(3), 4);
        assert_eq!(batch_class(256), 256);
        assert_eq!(batch_class(257), 512);
    }

    #[test]
    fn balanced_ranges_cover_and_balance() {
        // 6 rows, nnz = [10, 0, 0, 0, 0, 10].
        let indptr = vec![0, 10, 10, 10, 10, 10, 20];
        let r = balanced_row_ranges(&indptr, 2);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 6);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges contiguous");
        }
        for &(a, b) in &r {
            assert!(a < b, "non-empty range");
        }
        // The heavy first row ends the first chunk quickly.
        assert!(r[0].1 <= 5);
    }

    #[test]
    fn balanced_ranges_degenerate_cases() {
        assert!(balanced_row_ranges(&[0], 4).is_empty());
        let r = balanced_row_ranges(&[0, 3], 8);
        assert_eq!(r, vec![(0, 1)]);
        // All-empty rows still get covered — by a single collapsed range.
        let r = balanced_row_ranges(&[0, 0, 0, 0], 2);
        assert_eq!(r, vec![(0, 3)]);
    }

    #[test]
    fn balanced_ranges_collapse_zero_work_splits() {
        // All nnz in row 0, rows = 4: at threads ∈ {1, rows, rows+3} every
        // trailing range would be empty work — they fold into one range.
        let indptr = vec![0, 100, 100, 100, 100];
        for threads in [1usize, 4, 7] {
            let r = balanced_row_ranges(&indptr, threads);
            assert_eq!(r, vec![(0, 4)], "threads={threads}");
        }
        // Leading empty rows fold forward into the first working range.
        let indptr = vec![0, 0, 0, 50, 100];
        let r = balanced_row_ranges(&indptr, 4);
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 4);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "ranges contiguous");
        }
        for &(a, b) in &r {
            assert!(a < b);
            assert!(indptr[b] > indptr[a], "every range owns stored nnz");
        }
        // All-empty matrix: one covering range, even at high thread counts.
        assert_eq!(balanced_row_ranges(&[0, 0, 0, 0, 0], 16), vec![(0, 4)]);
    }

    #[test]
    fn bytes_touched_counts_weights_index_and_io() {
        // Dense 3×4 at n=5: values + input + output, no index.
        let d = SparseMatrix::dense(vec![1.0; 12], 3, 4);
        let io = 4.0 * ((4 * 5) + (3 * 5)) as f64;
        assert_eq!(d.bytes_touched(5), 4.0 * 12.0 + io);

        // CSR: values + per-nnz column index + row pointers.
        let mut rng = Rng::new(31);
        let c = crate::sparsity::csr::CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng);
        let nnz = c.nnz();
        let w = SparseMatrix::Csr(c);
        let io = 4.0 * ((16 * 8) + (16 * 8)) as f64;
        assert_eq!(w.bytes_touched(8), 4.0 * (2 * nnz + 17) as f64 + io);

        // RBGP4's succinct index beats a per-nnz index: its total traffic
        // at equal nnz must be below a CSR-style 2·nnz accounting.
        let cfg = crate::sparsity::rbgp4::Rbgp4Config {
            go: crate::sparsity::rbgp4::GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: crate::sparsity::rbgp4::GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let mask = crate::sparsity::rbgp4::Rbgp4Mask::sample(cfg, &mut rng).unwrap();
        let r = SparseMatrix::Rbgp4(crate::sparsity::rbgp4::Rbgp4Matrix::random(
            mask, &mut rng,
        ));
        let n = 8;
        let io = 4.0 * ((r.cols() * n) + (r.rows() * n)) as f64;
        let csr_style = 4.0 * (2 * r.nnz() + r.rows() + 1) as f64 + io;
        assert!(r.bytes_touched(n) < csr_style, "succinct index is smaller");
        assert!(r.bytes_touched(n) > io, "but not free");

        // AI rises with n as weight traffic amortizes.
        assert!(w.arithmetic_intensity(64) > w.arithmetic_intensity(1));
        assert!(r.arithmetic_intensity(64) > r.arithmetic_intensity(1));
    }

    #[test]
    fn structure_hash_ignores_dense_values_but_not_shape() {
        let a = SparseMatrix::dense(vec![1.0; 12], 3, 4);
        let b = SparseMatrix::dense(vec![2.0; 12], 3, 4);
        let c = SparseMatrix::dense(vec![1.0; 12], 4, 3);
        assert_eq!(a.structure_hash(), b.structure_hash());
        assert_ne!(a.structure_hash(), c.structure_hash());
    }

    #[test]
    fn structure_hash_sees_csr_pattern() {
        let mut rng = Rng::new(11);
        let a = crate::sparsity::csr::CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng);
        let b = crate::sparsity::csr::CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng);
        let (ha, hb) = (
            SparseMatrix::Csr(a).structure_hash(),
            SparseMatrix::Csr(b).structure_hash(),
        );
        assert_ne!(ha, hb, "independent samples should differ");
    }

    fn two_structures(rng: &mut Rng) -> (SparseMatrix, SparseMatrix) {
        (
            SparseMatrix::Csr(crate::sparsity::csr::CsrMatrix::random_row_uniform(
                16, 16, 0.5, rng,
            )),
            SparseMatrix::Csr(crate::sparsity::csr::CsrMatrix::random_row_uniform(
                16, 16, 0.75, rng,
            )),
        )
    }

    #[test]
    fn invalidate_structure_evicts_exactly_one_namespace() {
        let registry = crate::kernels::registry::KernelRegistry::builtin();
        let cache = PlanCache::new();
        let mut rng = Rng::new(21);
        let (a, b) = two_structures(&mut rng);
        // Structure `a` at two batch classes + two thread counts, `b` at one.
        for (n, threads) in [(4usize, 1usize), (16, 1), (4, 3)] {
            cache.plan_for(&registry, &a, &PlanRequest::new(n, threads)).unwrap();
        }
        cache.plan_for(&registry, &b, &PlanRequest::new(4, 1)).unwrap();
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.structures().len(), 2);
        assert_eq!(cache.structure_plan_count(a.structure_hash()), 3);

        let gen0 = cache.generation();
        let removed = cache.invalidate_structure(a.structure_hash());
        assert_eq!(removed, 3, "all of a's plans gone, b's untouched");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.structures(), vec![b.structure_hash()]);
        assert_eq!(cache.structure_plan_count(a.structure_hash()), 0);
        assert_eq!(cache.eviction_stats(), (1, 3));
        assert_eq!(cache.generation(), gen0 + 1);

        // Invalidating a dead (or never-seen) structure is a counted no-op.
        assert_eq!(cache.invalidate_structure(a.structure_hash()), 0);
        assert_eq!(cache.eviction_stats(), (2, 3));

        // Rebuilding after the re-key is a fresh miss, not a stale hit.
        let (_, misses0) = cache.stats();
        cache.plan_for(&registry, &a, &PlanRequest::new(4, 1)).unwrap();
        let (_, misses1) = cache.stats();
        assert_eq!(misses1, misses0 + 1, "evicted structure rebuilds");
    }

    #[test]
    fn retain_structures_sweeps_dead_namespaces() {
        let registry = crate::kernels::registry::KernelRegistry::builtin();
        let cache = PlanCache::new();
        let mut rng = Rng::new(22);
        let (a, b) = two_structures(&mut rng);
        let c = SparseMatrix::dense(vec![1.0; 16 * 16], 16, 16);
        for w in [&a, &b, &c] {
            cache.plan_for(&registry, w, &PlanRequest::new(8, 2)).unwrap();
        }
        assert_eq!(cache.len(), 3);
        let keep = [b.structure_hash(), c.structure_hash()];
        assert_eq!(cache.retain_structures(&keep), 1);
        assert_eq!(cache.structure_plan_count(a.structure_hash()), 0);
        assert_eq!(cache.structures().len(), 2);
        let (invalidations, evicted) = cache.eviction_stats();
        assert_eq!(invalidations, 0, "retain is not an invalidate call");
        assert_eq!(evicted, 1);
    }

    #[test]
    fn poisoned_plan_lock_does_not_poison_the_cache() {
        let registry = crate::kernels::registry::KernelRegistry::builtin();
        let cache = PlanCache::new();
        let mut rng = Rng::new(23);
        let w = SparseMatrix::Csr(crate::sparsity::csr::CsrMatrix::random_row_uniform(
            16, 16, 0.5, &mut rng,
        ));
        let req = PlanRequest::new(4, 1);
        let shared = cache.plan_for(&registry, &w, &req).unwrap();
        // A builder/executor dies while holding the plan lock.
        let poisoner = Arc::clone(&shared);
        let _ = std::thread::spawn(move || {
            let _guard = lock_recover(&poisoner);
            panic!("die mid-execute");
        })
        .join();
        assert!(shared.lock().is_err(), "plan mutex must be poisoned");
        // The cache keeps working through the recovering guard: the cached
        // execute path re-locks the same poisoned plan …
        let input = rng.normal_vec_f32(16 * 4, 1.0);
        let mut out = vec![0.0f32; 16 * 4];
        cache.execute(&registry, &w, &input, &mut out, 4, 1).unwrap();
        let mut oracle = vec![0.0f32; 16 * 4];
        crate::kernels::dense::gemm_naive(&w.to_dense(), &input, &mut oracle, 16, 16, 4);
        assert_eq!(out, oracle, "execute from the recovered plan is correct");
        // … and the namespace API still answers (map lock untouched by the
        // dead executor, but every accessor goes through recovery anyway).
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.invalidate_structure(w.structure_hash()), 1);
        assert!(cache.is_empty());
    }
}
