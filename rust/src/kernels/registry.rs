//! The [`SparseKernel`] trait and the [`Pattern`]-keyed kernel registry.
//!
//! Every kernel family (dense / CSR / BSR / RBGP4) is one implementation of
//! [`SparseKernel`]: `build_plan` derives the reusable structure once,
//! `execute` runs allocation-free from that plan, and `execute_naive` is the
//! family's reference path (the oracle side of the property tests and the
//! per-call baseline of the benches). The naive / blocked / parallel
//! variants that used to be separate free functions are *plan strategies*:
//! the plan's thread count and precomputed partitions select among them.
//!
//! The registry is keyed by [`Pattern`] — the same key
//! [`crate::gpusim::KernelKind::pattern`] exposes — so the V100 cost model
//! and the measured CPU kernels dispatch off one shared key, and
//! [`KernelRegistry::kind_for`] maps a concrete matrix to the cost-model
//! kind for apples-to-apples model-vs-measured rows in the bench harness.

use crate::gpusim::KernelKind;
use crate::kernels::autotune::{self, TunedConfig};
use crate::kernels::plan::{batch_class, KernelPlan, PlanRequest, PlanState, SparseMatrix};
use crate::kernels::{bsr_sdmm, csr_sdmm, dense, rbgp4mm};
use crate::sparsity::memory::Pattern;
use std::time::Instant;

/// One kernel family, dispatchable by [`Pattern`].
pub trait SparseKernel: Send + Sync {
    /// The registry key this family serves (block sizes are ignored when
    /// matching — `Pattern::Block(4,4)` and `Pattern::Block(2,3)` are one
    /// family).
    fn pattern(&self) -> Pattern;

    /// Stable display name (bench rows, error messages).
    fn name(&self) -> &'static str;

    /// Derive the execution plan for `(w, batch class, threads)`. Called
    /// once per cache key; everything input-independent happens here.
    fn build_plan(&self, w: &SparseMatrix, req: &PlanRequest) -> anyhow::Result<KernelPlan>;

    /// Hot path: `o = W · i` from a prebuilt plan. `i` is (cols × n)
    /// row-major, `o` is (rows × n). No allocation, no index derivation.
    fn execute(
        &self,
        w: &SparseMatrix,
        plan: &mut KernelPlan,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()>;

    /// Reference path (oracle / per-call baseline) without a plan.
    fn execute_naive(
        &self,
        w: &SparseMatrix,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()>;
}

fn check_shapes(w: &SparseMatrix, i: &[f32], o: &[f32], n: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        i.len() == w.cols() * n,
        "input length {} != cols {} × n {}",
        i.len(),
        w.cols(),
        n
    );
    anyhow::ensure!(
        o.len() == w.rows() * n,
        "output length {} != rows {} × n {}",
        o.len(),
        w.rows(),
        n
    );
    Ok(())
}

/// Does this plan state re-associate the inner reduction relative to the
/// heuristic (and therefore need search-time tolerance validation)?
fn reorders_reduction(state: &PlanState) -> bool {
    match state {
        PlanState::Ranges { fan, .. } => *fan > 1,
        PlanState::Rbgp4(p) => p.ksplit > 1,
        PlanState::Dense => false,
    }
}

/// Shared `build_plan` body for every family: generate the candidate
/// schedules for `(w, req)` (candidate 0 is always the fixed heuristic),
/// and — unless `req.tune` is [`autotune::TuneMode::Off`] — run the short
/// measured search on a synthetic non-zero batch at the request's batch
/// class, keep the fastest candidate, and record what the search learned
/// as a [`TunedConfig`] against the machine probe's roofline. Every
/// candidate is bit-identical in output (see `kernels::autotune`) unless
/// the caller opted into `reduce_tol`, in which case reduction-reordering
/// candidates are validated here against the heuristic's output and
/// rejected (counted) when over tolerance — so a noisy measurement can
/// pick a slower schedule, never a wrong one. The winning plan's
/// `build_seconds` includes the whole search; stored in the `PlanCache`,
/// the search cost amortizes to once per key.
///
/// With a [`autotune::TuneCache`] on the request, the persisted winner for
/// this `(structure, shape, batch class, threads, probe fingerprint)` is
/// adopted *without a single measurement rep* when its label still names a
/// candidate in the current space (the warm-cache property); otherwise the
/// search runs and its winner is appended to the cache file.
fn tuned_build(
    kernel: &dyn SparseKernel,
    w: &SparseMatrix,
    req: &PlanRequest,
) -> anyhow::Result<KernelPlan> {
    let t0 = Instant::now();
    let mut candidates = autotune::candidate_plans(w, req);
    anyhow::ensure!(
        !candidates.is_empty(),
        "{}: no candidate plans",
        kernel.name()
    );
    let mut plan = match autotune::SearchBudget::for_mode(req.tune) {
        None => candidates.swap_remove(0).1,
        Some(budget) => {
            let n = batch_class(req.n);
            let tune_key = autotune::TuneKey::of(w, req);
            let cached = req
                .tune_cache
                .as_ref()
                .and_then(|tc| tc.lookup(&tune_key))
                .and_then(|cfg| {
                    candidates
                        .iter()
                        .position(|(label, _)| *label == cfg.params)
                        .map(|ix| (ix, cfg))
                });
            if let Some((ix, cfg)) = cached {
                // Warm path: adopt the persisted winner. A cached
                // reduction-reordering winner is still re-validated below
                // (cheap, one execute) before being trusted; bit-identical
                // winners need nothing.
                let (_, mut winner) = candidates.swap_remove(ix);
                // analyze: allow(panic-freedom, reason="candidates[0] is the heuristic seed; a reordering winner has ix > 0, so slot 0 survives the swap_remove")
                let valid = if reorders_reduction(&winner.state) {
                    let tol = req.reduce_tol.unwrap_or(0.0);
                    let input = autotune::synth_input(w.cols() * n);
                    let mut reference = vec![0.0f32; w.rows() * n];
                    let mut output = vec![0.0f32; w.rows() * n];
                    // candidates[0] is still the heuristic: `ix` can never
                    // be 0 for a reordering winner.
                    kernel.execute(w, &mut candidates[0].1, &input, &mut reference, n)?;
                    kernel.execute(w, &mut winner, &input, &mut output, n)?;
                    within_tolerance(&output, &reference, tol)
                } else {
                    true
                };
                if valid {
                    winner.tuned = Some(cfg);
                    winner.build_seconds = t0.elapsed().as_secs_f64();
                    return Ok(winner);
                }
                autotune::count_tolerance_rejection();
                // Re-insert so index bookkeeping below starts clean.
                candidates.insert(ix, (cfg.params, winner));
            }

            let input = autotune::synth_input(w.cols() * n);
            let mut output = vec![0.0f32; w.rows() * n];
            // Tolerance gate: a reduction-reordering candidate must match
            // the heuristic's output under the caller's tolerance before
            // it may enter the timed race at all.
            let mut admitted = vec![true; candidates.len()];
            // analyze: allow(panic-freedom, reason="every ix ranges over 0..candidates.len()")
            let check: Vec<usize> = (0..candidates.len())
                .filter(|&ix| reorders_reduction(&candidates[ix].1.state))
                .collect();
            // analyze: allow(panic-freedom, reason="check holds indices from 0..candidates.len() and admitted has candidates.len() slots")
            if !check.is_empty() {
                let tol = req.reduce_tol.unwrap_or(0.0);
                kernel.execute(w, &mut candidates[0].1, &input, &mut output, n)?;
                let reference = output.clone();
                for ix in check {
                    kernel.execute(w, &mut candidates[ix].1, &input, &mut output, n)?;
                    if !within_tolerance(&output, &reference, tol) {
                        admitted[ix] = false;
                        autotune::count_tolerance_rejection();
                    }
                }
            }
            let mut best_secs = f64::INFINITY;
            let mut best_ix = 0usize;
            for (ix, (_, cand)) in candidates.iter_mut().enumerate() {
                // analyze: allow(panic-freedom, reason="admitted was sized to candidates.len() and ix enumerates candidates")
                if !admitted[ix] {
                    continue;
                }
                let secs = autotune::measure_seconds(&budget, || {
                    kernel.execute(w, cand, &input, &mut output, n)
                })?;
                if secs < best_secs {
                    best_secs = secs;
                    best_ix = ix;
                }
            }
            let (params, mut winner) = candidates.swap_remove(best_ix);
            let flops = w.flops(n);
            let gflops = flops / best_secs.max(1e-12) / 1e9;
            let attainable = autotune::machine_probe().attainable_gflops(w.arithmetic_intensity(n));
            let cfg = TunedConfig {
                params,
                gflops,
                roofline_fraction: gflops / attainable,
            };
            if let Some(tc) = &req.tune_cache {
                tc.record(&tune_key, &cfg);
            }
            winner.tuned = Some(cfg);
            winner
        }
    };
    plan.build_seconds = t0.elapsed().as_secs_f64();
    Ok(plan)
}

/// Element-wise absolute+relative comparison: `|a−b| ≤ tol·(1+|b|)`.
fn within_tolerance(got: &[f32], reference: &[f32], tol: f64) -> bool {
    got.iter()
        .zip(reference)
        .all(|(a, b)| ((a - b).abs() as f64) <= tol * (1.0 + b.abs() as f64))
}

/// Dense GEMM family (cuBLAS stand-in). Plan: thread count only — the
/// blocked kernel's panels are computed from the shape on the fly.
pub struct DenseKernel;

impl SparseKernel for DenseKernel {
    fn pattern(&self) -> Pattern {
        Pattern::Dense
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn build_plan(&self, w: &SparseMatrix, req: &PlanRequest) -> anyhow::Result<KernelPlan> {
        anyhow::ensure!(
            matches!(w, SparseMatrix::Dense { .. }),
            "dense kernel got a {} matrix",
            w.pattern().name()
        );
        tuned_build(self, w, req)
    }

    fn execute(
        &self,
        w: &SparseMatrix,
        plan: &mut KernelPlan,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match w {
            SparseMatrix::Dense { data, rows, cols } => {
                if plan.threads > 1 {
                    dense::gemm_parallel(data, i, o, *rows, *cols, n, plan.threads);
                } else {
                    dense::gemm_blocked(data, i, o, *rows, *cols, n);
                }
                Ok(())
            }
            _ => anyhow::bail!("dense kernel got a {} matrix", w.pattern().name()),
        }
    }

    fn execute_naive(
        &self,
        w: &SparseMatrix,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match w {
            SparseMatrix::Dense { data, rows, cols } => {
                dense::gemm_naive(data, i, o, *rows, *cols, n);
                Ok(())
            }
            _ => anyhow::bail!("dense kernel got a {} matrix", w.pattern().name()),
        }
    }
}

/// Unstructured CSR family (cuSparse-CSR stand-in). Plan: contiguous row
/// ranges balanced by non-zero count, one per worker.
pub struct CsrKernel;

impl SparseKernel for CsrKernel {
    fn pattern(&self) -> Pattern {
        Pattern::Unstructured
    }

    fn name(&self) -> &'static str {
        "csr"
    }

    fn build_plan(&self, w: &SparseMatrix, req: &PlanRequest) -> anyhow::Result<KernelPlan> {
        anyhow::ensure!(
            matches!(w, SparseMatrix::Csr(_)),
            "csr kernel got a {} matrix",
            w.pattern().name()
        );
        tuned_build(self, w, req)
    }

    fn execute(
        &self,
        w: &SparseMatrix,
        plan: &mut KernelPlan,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match (w, &plan.state) {
            (
                SparseMatrix::Csr(m),
                PlanState::Ranges {
                    ranges,
                    col_block,
                    fan,
                },
            ) => {
                csr_sdmm::csr_sdmm_ranges_fanned(m, i, o, n, ranges, *col_block, *fan);
                Ok(())
            }
            _ => anyhow::bail!("csr kernel/plan mismatch"),
        }
    }

    fn execute_naive(
        &self,
        w: &SparseMatrix,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match w {
            SparseMatrix::Csr(m) => {
                csr_sdmm::csr_sdmm(m, i, o, n);
                Ok(())
            }
            _ => anyhow::bail!("csr kernel got a {} matrix", w.pattern().name()),
        }
    }
}

/// Block BSR family (cuSparse-BSR stand-in). Plan: contiguous block-row
/// ranges balanced by stored-block count.
pub struct BsrKernel;

impl SparseKernel for BsrKernel {
    fn pattern(&self) -> Pattern {
        Pattern::Block(4, 4)
    }

    fn name(&self) -> &'static str {
        "bsr"
    }

    fn build_plan(&self, w: &SparseMatrix, req: &PlanRequest) -> anyhow::Result<KernelPlan> {
        anyhow::ensure!(
            matches!(w, SparseMatrix::Bsr(_)),
            "bsr kernel got a {} matrix",
            w.pattern().name()
        );
        tuned_build(self, w, req)
    }

    fn execute(
        &self,
        w: &SparseMatrix,
        plan: &mut KernelPlan,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match (w, &plan.state) {
            (
                SparseMatrix::Bsr(m),
                PlanState::Ranges {
                    ranges,
                    col_block,
                    fan,
                },
            ) => {
                bsr_sdmm::bsr_sdmm_ranges_fanned(m, i, o, n, ranges, *col_block, *fan);
                Ok(())
            }
            _ => anyhow::bail!("bsr kernel/plan mismatch"),
        }
    }

    fn execute_naive(
        &self,
        w: &SparseMatrix,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match w {
            SparseMatrix::Bsr(m) => {
                bsr_sdmm::bsr_sdmm(m, i, o, n);
                Ok(())
            }
            _ => anyhow::bail!("bsr kernel got a {} matrix", w.pattern().name()),
        }
    }
}

/// RBGP4 family (the paper's Algorithm 1). Plan: the full succinct-index
/// derivation — flattened local columns, reverse tile adjacency with
/// k-offsets, pack layout and per-worker arenas.
pub struct Rbgp4Kernel;

impl SparseKernel for Rbgp4Kernel {
    fn pattern(&self) -> Pattern {
        Pattern::Rbgp4
    }

    fn name(&self) -> &'static str {
        "rbgp4mm"
    }

    fn build_plan(&self, w: &SparseMatrix, req: &PlanRequest) -> anyhow::Result<KernelPlan> {
        anyhow::ensure!(
            matches!(w, SparseMatrix::Rbgp4(_)),
            "rbgp4 kernel got a {} matrix",
            w.pattern().name()
        );
        tuned_build(self, w, req)
    }

    fn execute(
        &self,
        w: &SparseMatrix,
        plan: &mut KernelPlan,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match (w, &mut plan.state) {
            (SparseMatrix::Rbgp4(m), PlanState::Rbgp4(p)) => {
                rbgp4mm::rbgp4mm_parallel_with_plan(m, p, i, o, n);
                Ok(())
            }
            _ => anyhow::bail!("rbgp4 kernel/plan mismatch"),
        }
    }

    fn execute_naive(
        &self,
        w: &SparseMatrix,
        i: &[f32],
        o: &mut [f32],
        n: usize,
    ) -> anyhow::Result<()> {
        check_shapes(w, i, o, n)?;
        match w {
            SparseMatrix::Rbgp4(m) => {
                rbgp4mm::rbgp4mm_naive(m, i, o, n);
                Ok(())
            }
            _ => anyhow::bail!("rbgp4 kernel got a {} matrix", w.pattern().name()),
        }
    }
}

/// Do two patterns name the same kernel family (block sizes disregarded)?
fn same_family(a: Pattern, b: Pattern) -> bool {
    std::mem::discriminant(&a) == std::mem::discriminant(&b)
}

/// The set of registered kernel families, looked up by [`Pattern`].
pub struct KernelRegistry {
    kernels: Vec<Box<dyn SparseKernel>>,
}

impl KernelRegistry {
    /// All four built-in families.
    pub fn builtin() -> KernelRegistry {
        KernelRegistry {
            kernels: vec![
                Box::new(DenseKernel),
                Box::new(CsrKernel),
                Box::new(BsrKernel),
                Box::new(Rbgp4Kernel),
            ],
        }
    }

    /// Look up the family serving `pattern`.
    pub fn get(&self, pattern: Pattern) -> anyhow::Result<&dyn SparseKernel> {
        self.kernels
            .iter()
            .map(|k| k.as_ref())
            .find(|k| same_family(k.pattern(), pattern))
            .ok_or_else(|| anyhow::anyhow!("no kernel registered for pattern {}", pattern.name()))
    }

    /// Look up the family serving a concrete matrix.
    pub fn for_matrix(&self, w: &SparseMatrix) -> anyhow::Result<&dyn SparseKernel> {
        self.get(w.pattern())
    }

    /// Look up the family serving a cost-model kind — cost model and
    /// measured kernels share the `Pattern` key.
    pub fn for_kind(&self, kind: &KernelKind) -> anyhow::Result<&dyn SparseKernel> {
        self.get(kind.pattern())
    }

    /// The cost-model [`KernelKind`] describing `w` (for model-vs-measured
    /// table rows driven from one matrix value).
    pub fn kind_for(&self, w: &SparseMatrix) -> KernelKind {
        match w {
            SparseMatrix::Dense { .. } => KernelKind::DenseCublas,
            SparseMatrix::Csr(m) => KernelKind::UnstructuredCsr { sp: m.sparsity() },
            SparseMatrix::Bsr(m) => KernelKind::BlockBsr {
                sp: m.sparsity(),
                bh: m.bh,
                bw: m.bw,
            },
            SparseMatrix::Rbgp4(m) => KernelKind::Rbgp4 {
                config: m.mask.config,
            },
        }
    }

    /// Registered family names, registry order.
    pub fn names(&self) -> Vec<&'static str> {
        self.kernels.iter().map(|k| k.name()).collect()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }
}

impl Default for KernelRegistry {
    fn default() -> Self {
        KernelRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::plan::PlanCache;
    use crate::sparsity::bsr::BsrMatrix;
    use crate::sparsity::csr::CsrMatrix;
    use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
    use crate::util::rng::Rng;

    fn sample_matrices(rng: &mut Rng) -> Vec<SparseMatrix> {
        let cfg = Rbgp4Config {
            go: GraphSpec::new(4, 4, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(4, 4, 0.5),
            gb: (2, 2),
        };
        let mask = Rbgp4Mask::sample(cfg, rng).unwrap();
        let rb = Rbgp4Matrix::random(mask, rng);
        let (m, k) = (rb.mask.rows(), rb.mask.cols());
        vec![
            SparseMatrix::dense(rng.normal_vec_f32(m * k, 1.0), m, k),
            SparseMatrix::Csr(CsrMatrix::random_row_uniform(m, k, 0.75, rng)),
            SparseMatrix::Bsr(BsrMatrix::random_block_uniform(m, k, 4, 4, 0.5, rng)),
            SparseMatrix::Rbgp4(rb),
        ]
    }

    #[test]
    fn registry_covers_all_families() {
        let reg = KernelRegistry::builtin();
        assert_eq!(reg.len(), 4);
        for p in [
            Pattern::Dense,
            Pattern::Unstructured,
            Pattern::Block(2, 3),
            Pattern::Rbgp4,
        ] {
            assert!(reg.get(p).is_ok(), "missing kernel for {}", p.name());
        }
    }

    #[test]
    fn plans_execute_and_match_naive() {
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(400);
        let n = 6;
        for w in sample_matrices(&mut rng) {
            let kernel = reg.for_matrix(&w).unwrap();
            let i = rng.normal_vec_f32(w.cols() * n, 1.0);
            let mut o_plan = vec![0.0; w.rows() * n];
            let mut o_naive = vec![0.0; w.rows() * n];
            let mut plan = kernel.build_plan(&w, &PlanRequest::new(n, 3)).unwrap();
            kernel.execute(&w, &mut plan, &i, &mut o_plan, n).unwrap();
            kernel.execute_naive(&w, &i, &mut o_naive, n).unwrap();
            for (idx, (a, b)) in o_plan.iter().zip(&o_naive).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{} idx {idx}: {a} vs {b}",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn mismatched_matrix_is_rejected() {
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(401);
        let w = SparseMatrix::dense(rng.normal_vec_f32(16, 1.0), 4, 4);
        let kernel = reg.get(Pattern::Rbgp4).unwrap();
        assert!(kernel.build_plan(&w, &PlanRequest::new(4, 1)).is_err());
    }

    #[test]
    fn tuned_build_records_roofline_and_off_does_not() {
        use crate::kernels::autotune::TuneMode;
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(404);
        let n = 8;
        for w in sample_matrices(&mut rng) {
            let kernel = reg.for_matrix(&w).unwrap();
            let off = kernel
                .build_plan(&w, &PlanRequest::new(n, 2).with_tune(TuneMode::Off))
                .unwrap();
            assert!(off.tuned.is_none(), "{}: Off must not search", kernel.name());
            let tuned = kernel
                .build_plan(&w, &PlanRequest::new(n, 2).with_tune(TuneMode::Quick))
                .unwrap();
            let cfg = tuned
                .tuned
                .as_ref()
                .unwrap_or_else(|| panic!("{}: Quick must record TunedConfig", kernel.name()));
            assert!(cfg.gflops.is_finite() && cfg.gflops > 0.0);
            assert!(cfg.roofline_fraction.is_finite() && cfg.roofline_fraction > 0.0);
            assert!(!cfg.params.is_empty());
            assert!(
                tuned.build_seconds >= 0.0,
                "search time folds into build_seconds"
            );
            // Whatever the search picked executes bit-identically to the
            // heuristic plan (the candidate contract).
            let i = rng.normal_vec_f32(w.cols() * n, 1.0);
            let (mut a, mut b) = (vec![0.0; w.rows() * n], vec![0.0; w.rows() * n]);
            let mut off = off;
            let mut tuned = tuned;
            kernel.execute(&w, &mut off, &i, &mut a, n).unwrap();
            kernel.execute(&w, &mut tuned, &i, &mut b, n).unwrap();
            assert_eq!(a, b, "{}: tuned ≠ heuristic bits", kernel.name());
        }
    }

    #[test]
    fn cache_hits_on_second_call() {
        let reg = KernelRegistry::builtin();
        let cache = PlanCache::new();
        let mut rng = Rng::new(402);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(16, 16, 0.5, &mut rng));
        let n = 4;
        let i = rng.normal_vec_f32(w.cols() * n, 1.0);
        let mut o = vec![0.0; w.rows() * n];
        cache.execute(&reg, &w, &i, &mut o, n, 2).unwrap();
        cache.execute(&reg, &w, &i, &mut o, n, 2).unwrap();
        // Batch 3 shares the class-4 plan; batch 5 builds a new one.
        let i3 = rng.normal_vec_f32(w.cols() * 3, 1.0);
        let mut o3 = vec![0.0; w.rows() * 3];
        cache.execute(&reg, &w, &i3, &mut o3, 3, 2).unwrap();
        let i5 = rng.normal_vec_f32(w.cols() * 5, 1.0);
        let mut o5 = vec![0.0; w.rows() * 5];
        cache.execute(&reg, &w, &i5, &mut o5, 5, 2).unwrap();
        let (hits, misses) = cache.stats();
        assert_eq!(hits, 2);
        assert_eq!(misses, 2);
        assert_eq!(cache.len(), 2);
    }

    fn tmp_cache_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "rbgp_registry_{tag}_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn warm_cache_second_build_performs_zero_reps() {
        use crate::kernels::autotune::{search_reps, TuneCache, TuneMode};
        use std::sync::Arc;
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(405);
        let n = 6;
        let path = tmp_cache_path("warm");
        let _ = std::fs::remove_file(&path);
        for w in sample_matrices(&mut rng) {
            let kernel = reg.for_matrix(&w).unwrap();
            // Cold process: search runs and the winner is persisted.
            let cold = TuneCache::open(&path);
            let req = PlanRequest::new(n, 2)
                .with_tune(TuneMode::Quick)
                .with_tune_cache(cold);
            let first = kernel.build_plan(&w, &req).unwrap();
            let first_cfg = first.tuned.clone().unwrap();
            // Second process: a fresh handle on the same file must adopt
            // the persisted winner without a single measurement rep.
            let warm = TuneCache::open(&path);
            assert!(!warm.is_empty(), "{}: cache file not loaded", kernel.name());
            let req = PlanRequest::new(n, 2)
                .with_tune(TuneMode::Quick)
                .with_tune_cache(Arc::clone(&warm));
            let reps_before = search_reps();
            let second = kernel.build_plan(&w, &req).unwrap();
            assert_eq!(
                search_reps(),
                reps_before,
                "{}: warm cache must not re-measure",
                kernel.name()
            );
            let second_cfg = second.tuned.clone().unwrap();
            assert_eq!(first_cfg.params, second_cfg.params, "{}", kernel.name());
            assert_eq!(
                first_cfg.gflops.to_bits(),
                second_cfg.gflops.to_bits(),
                "{}: gflops must round-trip bit-exactly",
                kernel.name()
            );
            let (hits, _, _) = warm.stats();
            assert_eq!(hits, 1, "{}", kernel.name());
            // The adopted plan still matches the heuristic bit for bit
            // (default mode admits only bit-identical candidates).
            let off = kernel
                .build_plan(&w, &PlanRequest::new(n, 2).with_tune(TuneMode::Off))
                .unwrap();
            let i = rng.normal_vec_f32(w.cols() * n, 1.0);
            let (mut a, mut b) = (vec![0.0; w.rows() * n], vec![0.0; w.rows() * n]);
            let (mut off, mut second) = (off, second);
            kernel.execute(&w, &mut off, &i, &mut a, n).unwrap();
            kernel.execute(&w, &mut second, &i, &mut b, n).unwrap();
            assert_eq!(a, b, "{}: cache-loaded plan ≠ heuristic bits", kernel.name());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn two_plan_caches_share_one_tune_file_with_zero_warm_reps() {
        use crate::kernels::autotune::{search_reps, TuneCache};
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(406);
        let w = SparseMatrix::Csr(CsrMatrix::random_row_uniform(32, 32, 0.75, &mut rng));
        let n = 4;
        let i = rng.normal_vec_f32(w.cols() * n, 1.0);
        let path = tmp_cache_path("two_caches");
        let _ = std::fs::remove_file(&path);

        let first = PlanCache::new();
        assert!(first.attach_tune_cache(TuneCache::open(&path)));
        let mut o1 = vec![0.0; w.rows() * n];
        first.execute(&reg, &w, &i, &mut o1, n, 2).unwrap();

        // A second PlanCache (second server process) with a fresh handle on
        // the same file: every plan builds warm, zero measurement reps.
        let second = PlanCache::new();
        assert!(second.attach_tune_cache(TuneCache::open(&path)));
        let reps_before = search_reps();
        let mut o2 = vec![0.0; w.rows() * n];
        second.execute(&reg, &w, &i, &mut o2, n, 2).unwrap();
        assert_eq!(search_reps(), reps_before, "warm PlanCache re-measured");
        assert_eq!(o1, o2, "warm plan must be bit-identical");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn over_tolerance_reduction_candidates_are_rejected() {
        use crate::kernels::autotune::{tolerance_rejections, TuneMode};
        use crate::sparsity::csr::CsrMatrix;
        let reg = KernelRegistry::builtin();
        // Catastrophic-cancellation rows: every row is [1e8, 1, -1e8, 1]
        // against the SAME two columns, so any re-association of the row
        // sum loses the small terms and lands ~O(1) away from the strict
        // order — far over a 1e-9 tolerance.
        let rows = 8usize;
        let cols = 4usize;
        let mut values = Vec::new();
        let mut indices = Vec::new();
        let mut indptr = vec![0usize];
        for _ in 0..rows {
            values.extend_from_slice(&[1.0e8, 1.0, -1.0e8, 1.0]);
            indices.extend_from_slice(&[0, 1, 0, 1]);
            indptr.push(values.len());
        }
        let w = SparseMatrix::Csr(CsrMatrix {
            values,
            indices,
            indptr,
            rows,
            cols,
        });
        let kernel = reg.for_matrix(&w).unwrap();
        let n = 5;
        let req = PlanRequest::new(n, 2)
            .with_tune(TuneMode::Full)
            .with_reduce_tol(1e-9);
        let before = tolerance_rejections();
        let tuned = kernel.build_plan(&w, &req).unwrap();
        assert!(
            tolerance_rejections() > before,
            "fanned candidates must be rejected on this matrix"
        );
        // The winner — whatever survived — is bit-identical to the
        // heuristic: over-tolerance schedules never enter the race.
        let off = kernel
            .build_plan(&w, &PlanRequest::new(n, 2).with_tune(TuneMode::Off))
            .unwrap();
        let mut rng = Rng::new(407);
        let i = rng.normal_vec_f32(w.cols() * n, 1.0);
        let (mut a, mut b) = (vec![0.0; w.rows() * n], vec![0.0; w.rows() * n]);
        let (mut off, mut tuned) = (off, tuned);
        kernel.execute(&w, &mut off, &i, &mut a, n).unwrap();
        kernel.execute(&w, &mut tuned, &i, &mut b, n).unwrap();
        assert_eq!(a, b, "surviving winner must keep the strict order");
    }

    #[test]
    fn kind_for_round_trips_through_pattern() {
        let reg = KernelRegistry::builtin();
        let mut rng = Rng::new(403);
        for w in sample_matrices(&mut rng) {
            let kind = reg.kind_for(&w);
            assert!(same_family(kind.pattern(), w.pattern()));
            let via_kind = reg.for_kind(&kind).unwrap();
            let via_matrix = reg.for_matrix(&w).unwrap();
            assert_eq!(via_kind.name(), via_matrix.name());
        }
    }
}
