//! Device descriptions for the cost model.

/// A GPU-like device: the handful of numbers the roofline model needs.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    /// Peak FP32 throughput, FLOP/s.
    pub fp32_flops: f64,
    /// DRAM bandwidth, bytes/s.
    pub dram_bw: f64,
    /// L2 capacity, bytes.
    pub l2_bytes: f64,
    /// Aggregate shared-memory bandwidth, bytes/s.
    pub smem_bw: f64,
    /// Number of SMs (for per-step overhead amortization).
    pub sms: f64,
    /// Per-tile-step overhead (tile setup, barrier), seconds.
    pub step_overhead: f64,
    /// Kernel launch overhead, seconds.
    pub launch_overhead: f64,
}

impl Device {
    /// Tesla V100-SXM2 16 GB — the paper's testbed.
    /// 15.7 TFLOP/s FP32, 900 GB/s HBM2, 6 MB L2, ~14 TB/s aggregate shared
    /// memory (80 SMs × 128 B/clk × 1.38 GHz).
    pub fn v100() -> Device {
        Device {
            name: "V100",
            fp32_flops: 15.7e12,
            dram_bw: 900e9,
            l2_bytes: 6.0 * 1024.0 * 1024.0,
            smem_bw: 14.1e12,
            sms: 80.0,
            step_overhead: 0.4e-6,
            launch_overhead: 5e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_numbers_sane() {
        let d = Device::v100();
        assert!(d.fp32_flops > 1e13 && d.fp32_flops < 2e13);
        assert!(d.dram_bw > 8e11 && d.dram_bw < 1e12);
        assert!(d.smem_bw > d.dram_bw);
    }
}
