//! GPU cost-model simulator — the V100 stand-in (DESIGN.md §Substitutions).
//!
//! The paper benchmarks SDMM kernels on a V100 with cuBLAS / cuSparse. We
//! have no GPU, so Tables 1–3's *time* columns are regenerated from a
//! mechanistic roofline model of the same memory hierarchy the paper's §5
//! reasons about: DRAM ←→ L2 ←→ shared memory ←→ registers.
//!
//! The model is deliberately simple — four terms per kernel —
//! and its constants are calibrated once against the paper's dense anchor
//! (cuBLAS 4096³ ≈ 11.2 ms ⇒ 78 % of FP32 peak) and documented here:
//!
//! * `t_compute` — FLOPs / (peak · eff_kind). `eff` captures instruction
//!   overhead of each kernel family (indexed loads, predication).
//! * `t_dram`   — compulsory + re-fetch traffic at DRAM bandwidth, with
//!   re-fetches waived when the working set fits in L2.
//! * `t_smem`   — shared-memory→register traffic, divided by the register
//!   reuse each pattern offers (row repetition `|G_r.U|·|G_b.U|` on the
//!   W-side for RBGP4; fixed 8-wide N-register tiling on the I-side).
//! * `t_step`   — per-tile-step overhead (tile setup + __syncthreads),
//!   the term that makes `G_o` sparsity pay even at equal FLOPs.
//!
//! `t_total = max(t_compute, t_dram, t_smem) + t_step + launch`.

pub mod costmodel;
pub mod device;

pub use costmodel::{estimate, explain_fig1, CostBreakdown, KernelKind, SdmmShape};
pub use device::Device;
