//! Roofline cost model for SDMM kernels on a GPU-like memory hierarchy.
//!
//! See the module docs in [`crate::gpusim`] for the model and its
//! calibration. Everything here is analytic — no randomness — so Tables
//! 1–3 regenerate deterministically.

use crate::gpusim::device::Device;
use crate::sparsity::rbgp4::Rbgp4Config;

/// Shape of one SDMM `O(M×N) = W(M×K) · I(K×N)`.
#[derive(Clone, Copy, Debug)]
pub struct SdmmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Which kernel family executes the SDMM.
#[derive(Clone, Debug)]
pub enum KernelKind {
    /// cuBLAS dense GEMM (sparsity ignored; computes all MKN).
    DenseCublas,
    /// cuSparse CSR SpMM at fractional sparsity `sp`.
    UnstructuredCsr { sp: f64 },
    /// cuSparse BSR SpMM, block (bh, bw), at sparsity `sp`.
    BlockBsr { sp: f64, bh: usize, bw: usize },
    /// The paper's RBGP4MM (Algorithm 1) under `config`; the shape must be
    /// consistent with `config.rows()/cols()` scaled to (m, k).
    Rbgp4 { config: Rbgp4Config },
}

impl KernelKind {
    /// The [`Pattern`](crate::sparsity::memory::Pattern) key this kernel
    /// family shares with the measured-kernel registry
    /// ([`crate::kernels::registry::KernelRegistry`]): the cost model and
    /// the CPU kernels dispatch off the same key, so a bench row can pair a
    /// model estimate with the measured kernel for one matrix value.
    pub fn pattern(&self) -> crate::sparsity::memory::Pattern {
        use crate::sparsity::memory::Pattern;
        match self {
            KernelKind::DenseCublas => Pattern::Dense,
            KernelKind::UnstructuredCsr { .. } => Pattern::Unstructured,
            KernelKind::BlockBsr { bh, bw, .. } => Pattern::Block(*bh, *bw),
            KernelKind::Rbgp4 { .. } => Pattern::Rbgp4,
        }
    }
}

/// Per-term cost decomposition, seconds.
#[derive(Clone, Copy, Debug)]
pub struct CostBreakdown {
    pub flops: f64,
    pub dram_bytes: f64,
    pub smem_bytes: f64,
    pub steps: f64,
    pub t_compute: f64,
    pub t_dram: f64,
    pub t_smem: f64,
    pub t_step: f64,
    pub t_total: f64,
}

/// Instruction-efficiency factors per kernel family (module docs).
/// Calibrated once: dense anchors to the paper's 11.2 ms @ 4096³ (78 % of
/// peak); RBGP4's indexed-but-regular inner loop reaches ~50 %; BSR's small
/// 4×4 blocks under-fill warps (~20 %); CSR's gather pipeline stalls (~5 %).
const EFF_DENSE: f64 = 0.78;
const EFF_RBGP4: f64 = 0.50;
const EFF_BSR: f64 = 0.20;
const EFF_CSR: f64 = 0.05;

/// Register tile width in N shared by all tiled kernels (the I-side reuse
/// every kernel gets from output register blocking, pattern or not).
const N_REG: f64 = 8.0;

fn finish(
    dev: &Device,
    flops: f64,
    dram_bytes: f64,
    smem_bytes: f64,
    steps: f64,
    eff: f64,
) -> CostBreakdown {
    let t_compute = flops / (dev.fp32_flops * eff);
    let t_dram = dram_bytes / dev.dram_bw;
    let t_smem = smem_bytes / dev.smem_bw;
    let t_step = steps * dev.step_overhead / dev.sms;
    let t_total = t_compute.max(t_dram).max(t_smem) + t_step + dev.launch_overhead;
    CostBreakdown {
        flops,
        dram_bytes,
        smem_bytes,
        steps,
        t_compute,
        t_dram,
        t_smem,
        t_step,
        t_total,
    }
}

/// Estimate the runtime of `kind` on `dev` for `shape`.
pub fn estimate(dev: &Device, shape: SdmmShape, kind: &KernelKind) -> CostBreakdown {
    let (m, k, n) = (shape.m as f64, shape.k as f64, shape.n as f64);
    let out_bytes = 4.0 * m * n;
    match kind {
        KernelKind::DenseCublas => {
            let flops = 2.0 * m * k * n;
            // 128×128 output tiling: W re-read N/128 times, I re-read M/128
            // times, both capped below by compulsory traffic.
            let tile = 128.0;
            let dram = 4.0 * (m * k * (n / tile).max(1.0) + k * n * (m / tile).max(1.0)) + out_bytes;
            // Register blocking 8×8: both operands reused 8× out of smem.
            let smem = 4.0 * (flops / 2.0) * (2.0 / 8.0);
            let steps = (m / tile).max(1.0) * (n / tile).max(1.0) * (k / tile).max(1.0);
            finish(dev, flops, dram, smem, steps, EFF_DENSE)
        }
        KernelKind::UnstructuredCsr { sp } => {
            let nnz = m * k * (1.0 - sp);
            let flops = 2.0 * nnz * n;
            // Values + column indices stream once; every non-zero gathers a
            // row segment of I with poor temporal locality — model an L2
            // hit rate that decays with how much of I a row-slab touches.
            let i_bytes = k * n * 4.0;
            let l2_resident = (dev.l2_bytes / i_bytes).min(1.0);
            let gather_refetch = nnz * n * 4.0 * (1.0 - l2_resident) * 0.5;
            let dram = nnz * 8.0 + i_bytes + gather_refetch + out_bytes;
            // No pattern ⇒ no W-side register reuse; I-side N_REG only.
            let smem = 4.0 * (flops / 2.0) * (1.0 + 1.0 / N_REG);
            let steps = nnz / 32.0; // warp-sized gather batches
            finish(dev, flops, dram, smem, steps, EFF_CSR)
        }
        KernelKind::BlockBsr { sp, bh, bw } => {
            let nnz = m * k * (1.0 - sp);
            let flops = 2.0 * nnz * n;
            let nblocks = nnz / (*bh as f64 * *bw as f64);
            // Each non-zero block streams its values and bw rows of I; L2
            // absorbs 75 % of re-reads but never below the compulsory
            // traffic of the rows actually touched.
            let touched_rows = (nblocks * *bw as f64).min(k);
            let i_traffic = (nblocks * (*bw as f64) * n * 4.0 * 0.25).max(touched_rows * n * 4.0);
            let dram = nnz * 4.0 + nblocks * 4.0 + i_traffic + out_bytes;
            // W elements reused bh-wide (block row repetition within block).
            let smem = 4.0 * (flops / 2.0) * (1.0 / (*bh as f64) + 1.0 / N_REG);
            let steps = nblocks;
            finish(dev, flops, dram, smem, steps, EFF_BSR)
        }
        KernelKind::Rbgp4 { config } => {
            let c = config;
            // Scale factor if shape is a multiple of the config grid (the
            // bench uses 4096² matrices built by tiling the config).
            let row_nnz = k * (1.0 - c.sparsity());
            let nnz = m * row_nnz;
            let flops = 2.0 * nnz * n;
            let tm = c.tile_m() as f64;
            let tk = c.tile_k() as f64;
            let tn = 128.0f64.min(n);
            let d_o = (k / tk) * (1.0 - c.go.sp);
            let ots = (m / tm).max(1.0) * (n / tn).max(1.0);
            // Per step one IT (TK×TN) panel moves into shared memory.
            let it_loads = ots * d_o * tk * tn * 4.0;
            // W streams once (compulsory; re-reads across N-tiles hit L2).
            // I tile loads partially hit L2 across adjacent output tiles —
            // model a flat 50 % hit rate, floored at compulsory traffic.
            let dram = nnz * 4.0 + (it_loads * 0.5).max(k * n * 4.0) + out_bytes;
            // Register reuse: W-side = row repetition, I-side = N_REG.
            let rep = c.row_repetition() as f64;
            let smem = 4.0 * (flops / 2.0) * (1.0 / rep.min(N_REG) + 1.0 / N_REG)
                + it_loads; // writing IT into shared costs bandwidth too
            let steps = ots * d_o;
            finish(dev, flops, dram, smem, steps, EFF_RBGP4)
        }
    }
}

/// The Figure-1 walkthrough: for a given RBGP4 config, report the tiled-
/// execution decomposition the figure illustrates — tile sizes, steps per
/// output tile with/without `G_o` skipping, and the register-reuse factors
/// from `G_r`/`G_b`.
pub struct Fig1Explain {
    pub tile_m: usize,
    pub tile_k: usize,
    pub steps_dense: usize,
    pub steps_skipped: usize,
    pub row_repetition: usize,
    pub regw_reuse: usize,
    pub regi_reuse: usize,
}

pub fn explain_fig1(config: &Rbgp4Config) -> Fig1Explain {
    Fig1Explain {
        tile_m: config.tile_m(),
        tile_k: config.tile_k(),
        steps_dense: config.go.nv,
        steps_skipped: config.d_o(),
        row_repetition: config.row_repetition(),
        // Paper Fig 1: RegW elements reused |G_b.V| times (BN columns),
        // RegI elements reused |G_r.U|·|G_b.U| times (repeated rows).
        regw_reuse: config.gb.1,
        regi_reuse: config.row_repetition(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::rbgp4::GraphSpec;

    fn shape4096() -> SdmmShape {
        SdmmShape {
            m: 4096,
            k: 4096,
            n: 4096,
        }
    }

    /// Paper Table-2 config scaled to 4096²: sizes (32,128),(4,1),(32,32),(1,1).
    fn paper_cfg(sp_o: f64, sp_i: f64) -> Rbgp4Config {
        Rbgp4Config::paper_default(sp_o, sp_i)
    }

    #[test]
    fn dense_anchor_near_paper() {
        // cuBLAS 4096³ on V100 ≈ 11.2 ms in Table 2.
        let t = estimate(&Device::v100(), shape4096(), &KernelKind::DenseCublas).t_total;
        assert!(
            (t - 11.2e-3).abs() / 11.2e-3 < 0.15,
            "dense model {:.2} ms vs paper 11.2 ms",
            t * 1e3
        );
    }

    #[test]
    fn table2_trend_sparsity_to_go_is_faster() {
        // At fixed total sparsity, shifting sparsity into G_o reduces time.
        let dev = Device::v100();
        for &(total, splits) in &[
            (0.875f64, [(0.0, 0.875), (0.5, 0.75), (0.75, 0.5)]),
        ] {
            let _ = total;
            let mut last = f64::INFINITY;
            for &(sp_o, sp_i) in &splits {
                let cfg = paper_cfg(sp_o, sp_i);
                let t = estimate(&dev, shape4096(), &KernelKind::Rbgp4 { config: cfg }).t_total;
                assert!(t < last, "sp_o={sp_o}: {t} !< {last}");
                last = t;
            }
        }
    }

    #[test]
    fn table2_rbgp4_beats_dense_and_factors_in_range() {
        let dev = Device::v100();
        let dense = estimate(&dev, shape4096(), &KernelKind::DenseCublas).t_total;
        // Paper: 93.75% (87.5, 50) split achieves 9.2x over dense.
        let best = estimate(
            &dev,
            shape4096(),
            &KernelKind::Rbgp4 {
                config: paper_cfg(0.875, 0.5),
            },
        )
        .t_total;
        let speedup = dense / best;
        assert!(speedup > 4.0 && speedup < 16.0, "speedup {speedup}");
    }

    #[test]
    fn table3_row_repetition_helps_with_diminishing_returns() {
        let dev = Device::v100();
        let mk = |gr: (usize, usize), gb: (usize, usize)| {
            // Keep G_t = (128, 32) fixed as in Table 3, sp_o=50%.
            let gi_u = 128 / (gr.0 * gb.0);
            let gi_v = 32 / (gr.1 * gb.1);
            Rbgp4Config {
                go: GraphSpec::new(32, 128, 0.5),
                gr,
                gi: GraphSpec::new(gi_u, gi_v, 0.5),
                gb,
            }
        };
        let t1 = estimate(&dev, shape4096(), &KernelKind::Rbgp4 { config: mk((1, 1), (1, 1)) }).t_total;
        let t2 = estimate(&dev, shape4096(), &KernelKind::Rbgp4 { config: mk((2, 1), (1, 1)) }).t_total;
        let t4 = estimate(&dev, shape4096(), &KernelKind::Rbgp4 { config: mk((4, 1), (1, 1)) }).t_total;
        assert!(t2 < t1, "rep2 {t2} !< rep1 {t1}");
        assert!(t4 <= t2, "rep4 {t4} !<= rep2 {t2}");
        // Diminishing: gain 1→2 exceeds gain 2→4 (paper: 7.07→4.89→4.47).
        assert!((t1 - t2) > (t2 - t4));
    }

    #[test]
    fn pattern_ordering_matches_table1() {
        // At equal sparsity: unstructured slowest, block middle, RBGP4
        // fastest; RBGP4 faster than dense at >=75%.
        let dev = Device::v100();
        let s = shape4096();
        for &sp in &[0.75, 0.875, 0.9375] {
            let csr = estimate(&dev, s, &KernelKind::UnstructuredCsr { sp }).t_total;
            let bsr = estimate(&dev, s, &KernelKind::BlockBsr { sp, bh: 4, bw: 4 }).t_total;
            let (sp_o, sp_i) = match sp {
                x if x == 0.75 => (0.5, 0.5),
                x if x == 0.875 => (0.75, 0.5),
                _ => (0.875, 0.5),
            };
            let rbgp = estimate(&dev, s, &KernelKind::Rbgp4 { config: paper_cfg(sp_o, sp_i) }).t_total;
            let dense = estimate(&dev, s, &KernelKind::DenseCublas).t_total;
            assert!(csr > bsr, "sp={sp}: csr {csr} !> bsr {bsr}");
            assert!(bsr > rbgp, "sp={sp}: bsr {bsr} !> rbgp {rbgp}");
            assert!(rbgp < dense, "sp={sp}: rbgp {rbgp} !< dense {dense}");
            // Paper's headline: 5-9x vs unstructured, 2-5x vs block.
            let vs_csr = csr / rbgp;
            let vs_bsr = bsr / rbgp;
            assert!(vs_csr > 3.0, "sp={sp}: vs_csr {vs_csr}");
            assert!(vs_bsr > 1.5, "sp={sp}: vs_bsr {vs_bsr}");
        }
    }

    #[test]
    fn kernel_kind_exposes_registry_pattern() {
        use crate::sparsity::memory::Pattern;
        assert_eq!(KernelKind::DenseCublas.pattern(), Pattern::Dense);
        assert_eq!(
            KernelKind::UnstructuredCsr { sp: 0.5 }.pattern(),
            Pattern::Unstructured
        );
        assert_eq!(
            KernelKind::BlockBsr { sp: 0.5, bh: 4, bw: 4 }.pattern(),
            Pattern::Block(4, 4)
        );
        assert_eq!(
            KernelKind::Rbgp4 { config: paper_cfg(0.5, 0.5) }.pattern(),
            Pattern::Rbgp4
        );
    }

    #[test]
    fn fig1_explain_example() {
        // Fig 1's toy config: G_o 2x2 @50%, G_r (2,1), G_i 2x2 @50%, G_b (2,2).
        let c = Rbgp4Config {
            go: GraphSpec::new(2, 2, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(2, 2, 0.5),
            gb: (2, 2),
        };
        let e = explain_fig1(&c);
        assert_eq!(e.steps_dense, 2);
        assert_eq!(e.steps_skipped, 1); // "reduced from two to one"
        assert_eq!(e.row_repetition, 4); // "row repetition pattern with 4 rows"
        assert_eq!(e.regi_reuse, 4); // RegI reused 4 times
        assert_eq!(e.regw_reuse, 2); // RegW reused 2 times
    }
}
