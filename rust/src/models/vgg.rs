//! VGG19 for CIFAR, the Liu et al. [20] adaptation the paper trains:
//! 16 conv layers (cfg 64,64,M,128,128,M,256×4,M,512×4,M,512×4) + one
//! classifier. First conv and classifier stay dense (§6).

use crate::models::{Layer, Network};

/// Build VGG19-CIFAR with `num_classes` outputs (10 or 100).
pub fn vgg19(num_classes: usize) -> Network {
    let cfg: &[(usize, usize)] = &[
        // (channels, output spatial side) per conv layer; pooling after
        // layers 2, 4, 8, 12 halves the map (CIFAR input 32×32).
        (64, 32),
        (64, 32),
        (128, 16),
        (128, 16),
        (256, 8),
        (256, 8),
        (256, 8),
        (256, 8),
        (512, 4),
        (512, 4),
        (512, 4),
        (512, 4),
        (512, 2),
        (512, 2),
        (512, 2),
        (512, 2),
    ];
    const NAMES: [&str; 16] = [
        "conv1", "conv2", "conv3", "conv4", "conv5", "conv6", "conv7", "conv8", "conv9", "conv10",
        "conv11", "conv12", "conv13", "conv14", "conv15", "conv16",
    ];
    let mut layers = Vec::with_capacity(17);
    let mut c_in = 3;
    for (idx, &(c_out, hw)) in cfg.iter().enumerate() {
        layers.push(Layer::conv(NAMES[idx], c_in, c_out, 3, hw, idx != 0));
        c_in = c_out;
    }
    layers.push(Layer::fc(
        if num_classes == 100 { "fc100" } else { "fc10" },
        512,
        num_classes,
        false,
    ));
    Network {
        name: "VGG19",
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::memory::{network_bytes, Pattern};
    use crate::util::fmt_mb;

    #[test]
    fn parameter_count_near_paper() {
        // Paper Table 1: dense VGG19 = 77.39 MB. Weight-only accounting
        // gives ~76.4 MB (the ~1 MB delta is bias/BN parameters we do not
        // sparsify or count). Assert within 2 %.
        let net = vgg19(10);
        let bytes = network_bytes(&net.memory_layers(), 0.0, Pattern::Dense);
        let mb: f64 = fmt_mb(bytes).parse().unwrap();
        assert!((mb - 77.39).abs() / 77.39 < 0.02, "VGG19 dense {mb} MB");
        assert_eq!(net.layers.len(), 17);
    }

    #[test]
    fn first_and_last_stay_dense() {
        let net = vgg19(100);
        assert!(!net.layers[0].sparsified);
        assert!(!net.layers.last().unwrap().sparsified);
        assert!(net.layers[1..16].iter().all(|l| l.sparsified));
    }

    #[test]
    fn table1_memory_column_shape() {
        // Ratios from the paper's Table 1 at 75 %: unstructured ≈ 38.71,
        // block ≈ 20.57, RBGP4 ≈ 19.40 (MB). Our weight-only model should
        // land within ~6 % of each.
        let net = vgg19(10);
        let layers = net.memory_layers();
        let cases = [
            (Pattern::Unstructured, 38.71),
            (Pattern::Block(4, 4), 20.57),
            (Pattern::Rbgp4, 19.40),
        ];
        for (pat, paper) in cases {
            let mb: f64 = fmt_mb(network_bytes(&layers, 0.75, pat)).parse().unwrap();
            assert!(
                (mb - paper).abs() / paper < 0.06,
                "{}: model {mb} MB vs paper {paper} MB",
                pat.name()
            );
        }
    }
}
