//! Architecture descriptions for the paper's evaluation networks.
//!
//! Table 1 needs per-layer weight shapes for VGG19 (the Liu et al. CIFAR
//! adaptation) and WideResNet-40-4 — memory is exact arithmetic over these,
//! and runtime is the sum of per-layer SDMM estimates (im2col view: a conv
//! `C_out × C_in × kh × kw` on a `H×W` map with batch `B` is an SDMM with
//! `M = C_out`, `K = C_in·kh·kw`, `N = B·H·W`).

pub mod vgg;
pub mod wideresnet;

/// One weight layer of a network.
#[derive(Clone, Copy, Debug)]
pub struct Layer {
    pub name: &'static str,
    /// Output channels (conv) or output features (fc).
    pub c_out: usize,
    /// Input channels × kernel area (conv) or input features (fc).
    pub k: usize,
    /// Spatial positions of the *output* map for one sample (1 for fc).
    pub spatial: usize,
    /// Whether the paper sparsifies this layer (first conv and final
    /// classifier stay dense).
    pub sparsified: bool,
}

impl Layer {
    pub const fn conv(
        name: &'static str,
        c_in: usize,
        c_out: usize,
        ksize: usize,
        out_hw: usize,
        sparsified: bool,
    ) -> Layer {
        Layer {
            name,
            c_out,
            k: c_in * ksize * ksize,
            spatial: out_hw * out_hw,
            sparsified,
        }
    }

    pub const fn fc(name: &'static str, c_in: usize, c_out: usize, sparsified: bool) -> Layer {
        Layer {
            name,
            c_out,
            k: c_in,
            spatial: 1,
            sparsified,
        }
    }

    /// Weight parameter count (biases/BN excluded, matching the paper's
    /// sparsifiable-parameter accounting).
    pub fn params(&self) -> usize {
        self.c_out * self.k
    }

    /// SDMM shape for batch `b` (im2col view).
    pub fn sdmm_shape(&self, b: usize) -> crate::gpusim::SdmmShape {
        crate::gpusim::SdmmShape {
            m: self.c_out,
            k: self.k,
            n: b * self.spatial,
        }
    }

    /// FLOPs of one forward pass at batch `b`, dense.
    pub fn flops_dense(&self, b: usize) -> f64 {
        2.0 * (self.c_out * self.k * self.spatial * b) as f64
    }
}

/// A whole network as a layer list.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(Layer::params).sum()
    }

    /// `(params, sparsified)` pairs for the memory calculator.
    pub fn memory_layers(&self) -> Vec<(usize, bool)> {
        self.layers.iter().map(|l| (l.params(), l.sparsified)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_layer_arithmetic() {
        let l = Layer::conv("c", 64, 128, 3, 16, true);
        assert_eq!(l.params(), 128 * 64 * 9);
        let s = l.sdmm_shape(4);
        assert_eq!((s.m, s.k, s.n), (128, 576, 4 * 256));
        assert_eq!(l.flops_dense(1), 2.0 * (128 * 576 * 256) as f64);
    }

    #[test]
    fn fc_layer_arithmetic() {
        let l = Layer::fc("fc", 512, 10, false);
        assert_eq!(l.params(), 5120);
        assert_eq!(l.sdmm_shape(8).n, 8);
    }
}
