//! WideResNet-40-4 (Zagoruyko & Komodakis [37]) for CIFAR: depth 40 ⇒
//! n = 6 basic blocks per group, widen factor 4 ⇒ widths (64, 128, 256).
//! First conv and classifier stay dense; every other conv (including the
//! 1×1 projection shortcuts) is sparsified, as in the paper's §6 setup.

use crate::models::{Layer, Network};

/// Build WRN-40-4 with `num_classes` outputs.
pub fn wrn40_4(num_classes: usize) -> Network {
    let n = 6; // (40 - 4) / (6*2) blocks per group... depth = 6n+4
    let widths = [64usize, 128, 256];
    let spatial = [32usize, 16, 8];
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv0", 3, 16, 3, 32, false));
    let mut c_in = 16;
    // Leaked names keep Layer's &'static str simple; the set of names is
    // small and built once per process.
    let name = |s: String| -> &'static str { Box::leak(s.into_boxed_str()) };
    for (g, (&w, &hw)) in widths.iter().zip(spatial.iter()).enumerate() {
        for b in 0..n {
            let cin_blk = if b == 0 { c_in } else { w };
            layers.push(Layer::conv(
                name(format!("g{}b{}c1", g + 1, b)),
                cin_blk,
                w,
                3,
                hw,
                true,
            ));
            layers.push(Layer::conv(
                name(format!("g{}b{}c2", g + 1, b)),
                w,
                w,
                3,
                hw,
                true,
            ));
            if b == 0 && cin_blk != w {
                layers.push(Layer::conv(
                    name(format!("g{}short", g + 1)),
                    cin_blk,
                    w,
                    1,
                    hw,
                    true,
                ));
            }
        }
        c_in = w;
    }
    layers.push(Layer::fc(
        if num_classes == 100 { "fc100" } else { "fc10" },
        256,
        num_classes,
        false,
    ));
    Network {
        name: "WideResnet-40-4",
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::memory::{network_bytes, Pattern};
    use crate::util::fmt_mb;

    #[test]
    fn parameter_count_near_paper() {
        // Paper Table 1: dense WRN-40-4 = 34.10 MB (≈ 8.94 M params).
        let net = wrn40_4(10);
        let bytes = network_bytes(&net.memory_layers(), 0.0, Pattern::Dense);
        let mb: f64 = fmt_mb(bytes).parse().unwrap();
        assert!((mb - 34.10).abs() / 34.10 < 0.02, "WRN-40-4 dense {mb} MB");
    }

    #[test]
    fn structure_counts() {
        let net = wrn40_4(10);
        // conv0 + 3 groups * (6 blocks * 2 convs) + 3 shortcuts + fc = 41.
        assert_eq!(net.layers.len(), 1 + 36 + 3 + 1);
        assert!(!net.layers[0].sparsified);
        assert!(!net.layers.last().unwrap().sparsified);
    }

    #[test]
    fn table1_memory_column_shape() {
        // Paper 87.5 %: unstructured 8.53, block 4.54, RBGP4 4.30 (MB).
        let net = wrn40_4(10);
        let layers = net.memory_layers();
        for (pat, paper) in [
            (Pattern::Unstructured, 8.53),
            (Pattern::Block(4, 4), 4.54),
            (Pattern::Rbgp4, 4.30),
        ] {
            let mb: f64 = fmt_mb(network_bytes(&layers, 0.875, pat)).parse().unwrap();
            assert!(
                (mb - paper).abs() / paper < 0.07,
                "{}: model {mb} MB vs paper {paper} MB",
                pat.name()
            );
        }
    }
}
