//! Bipartite graph representation (§3 of the paper).
//!
//! A layer's connectivity is a bipartite graph `G = (U, V, E)` whose
//! biadjacency matrix `BA` (|U| × |V|) is the layer's sparsity mask:
//! left vertices = output neurons (rows), right vertices = input neurons
//! (columns). Biregular graphs have constant left degree `d_l` and right
//! degree `d_r`; biregularity requires `|U|·d_l == |V|·d_r`.

use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// An undirected bipartite graph stored as sorted left-adjacency lists.
#[derive(Clone, Debug, PartialEq)]
pub struct BipartiteGraph {
    /// Number of left vertices (|U|) — mask rows.
    pub nu: usize,
    /// Number of right vertices (|V|) — mask columns.
    pub nv: usize,
    /// `adj[u]` = sorted right-neighbours of left vertex `u`.
    pub adj: Vec<Vec<usize>>,
}

impl BipartiteGraph {
    /// Build from an explicit edge list; duplicates are rejected.
    pub fn from_edges(nu: usize, nv: usize, edges: &[(usize, usize)]) -> anyhow::Result<Self> {
        let mut adj = vec![Vec::new(); nu];
        let mut seen = BTreeSet::new();
        for &(u, v) in edges {
            anyhow::ensure!(u < nu && v < nv, "edge ({u},{v}) out of range {nu}x{nv}");
            anyhow::ensure!(seen.insert((u, v)), "duplicate edge ({u},{v})");
            adj[u].push(v);
        }
        for a in &mut adj {
            a.sort_unstable();
        }
        Ok(BipartiteGraph { nu, nv, adj })
    }

    /// The complete bipartite graph K_{nu,nv}.
    pub fn complete(nu: usize, nv: usize) -> Self {
        let adj = (0..nu).map(|_| (0..nv).collect()).collect();
        BipartiteGraph { nu, nv, adj }
    }

    /// Identity-like graph: requires nu == nv, edge (i, i).
    pub fn identity(n: usize) -> Self {
        let adj = (0..n).map(|i| vec![i]).collect();
        BipartiteGraph { nu: n, nv: n, adj }
    }

    /// A random `(d_l, d_r)`-biregular bipartite graph via random perfect
    /// matchings on the edge-slot model: take `d_l` copies of the left slots
    /// and `d_r` copies of the right slots, randomly match, resample on
    /// collisions. Requires `nu*d_l == nv*d_r`, `d_l <= nv`, `d_r <= nu`.
    pub fn random_biregular(
        nu: usize,
        nv: usize,
        dl: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(nu > 0 && nv > 0 && dl > 0, "empty graph");
        anyhow::ensure!(dl <= nv, "left degree {dl} exceeds |V|={nv}");
        anyhow::ensure!((nu * dl) % nv == 0, "degrees not integral: {nu}*{dl} % {nv} != 0");
        let dr = nu * dl / nv;
        anyhow::ensure!(dr <= nu, "right degree {dr} exceeds |U|={nu}");
        // Configuration-model sampling with rejection on multi-edges; falls
        // back to a randomly relabeled cyclic-window construction (always
        // valid) when the rejection loop stalls at high density.
        'attempt: for _ in 0..200 {
            let mut right_slots: Vec<usize> = (0..nv).flat_map(|v| std::iter::repeat_n(v, dr)).collect();
            rng.shuffle(&mut right_slots);
            let mut adj = vec![Vec::with_capacity(dl); nu];
            for (slot, &v) in right_slots.iter().enumerate() {
                let u = slot / dl;
                if adj[u].contains(&v) {
                    continue 'attempt; // multi-edge: resample
                }
                adj[u].push(v);
            }
            for a in &mut adj {
                a.sort_unstable();
            }
            return Ok(BipartiteGraph { nu, nv, adj });
        }
        // Cyclic-window construction: left vertex u connects to columns
        // [u·dl, u·dl + dl) mod nv. Because nv | nu·dl the windows tile the
        // cycle exactly dr times, giving a simple biregular graph for any
        // valid (nu, nv, dl). Random left/right relabelings decorrelate it.
        let pl = rng.permutation(nu);
        let pr = rng.permutation(nv);
        let mut adj = vec![Vec::with_capacity(dl); nu];
        for u in 0..nu {
            for j in 0..dl {
                adj[pl[u]].push(pr[(u * dl + j) % nv]);
            }
        }
        for a in &mut adj {
            a.sort_unstable();
        }
        Ok(BipartiteGraph { nu, nv, adj })
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(Vec::len).sum()
    }

    /// Left degree if regular on the left, else None.
    pub fn left_degree(&self) -> Option<usize> {
        let d = self.adj.first()?.len();
        self.adj.iter().all(|a| a.len() == d).then_some(d)
    }

    /// Right degree if regular on the right, else None.
    pub fn right_degree(&self) -> Option<usize> {
        let mut deg = vec![0usize; self.nv];
        for a in &self.adj {
            for &v in a {
                deg[v] += 1;
            }
        }
        let d = *deg.first()?;
        deg.iter().all(|&x| x == d).then_some(d)
    }

    /// True iff the graph is (d_l, d_r)-biregular.
    pub fn is_biregular(&self) -> bool {
        self.left_degree().is_some() && self.right_degree().is_some()
    }

    /// Degrees `(d_l, d_r)`; errors if not biregular.
    pub fn degrees(&self) -> anyhow::Result<(usize, usize)> {
        match (self.left_degree(), self.right_degree()) {
            (Some(dl), Some(dr)) => Ok((dl, dr)),
            _ => anyhow::bail!("graph is not biregular"),
        }
    }

    /// Fractional sparsity `1 − |E| / (|U|·|V|)`.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.num_edges() as f64 / (self.nu * self.nv) as f64
    }

    /// True iff this is the complete bipartite graph.
    pub fn is_complete(&self) -> bool {
        self.num_edges() == self.nu * self.nv
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&v).is_ok()
    }

    /// Dense biadjacency matrix (row-major |U| × |V|, 0/1 as f32).
    pub fn biadjacency(&self) -> Vec<f32> {
        let mut ba = vec![0.0f32; self.nu * self.nv];
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                ba[u * self.nv + v] = 1.0;
            }
        }
        ba
    }

    /// Edge list in (u, v) lexicographic order.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut e = Vec::with_capacity(self.num_edges());
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                e.push((u, v));
            }
        }
        e
    }

    /// Right-adjacency lists (`radj[v]` = sorted left-neighbours of v).
    pub fn right_adj(&self) -> Vec<Vec<usize>> {
        let mut radj = vec![Vec::new(); self.nv];
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                radj[v].push(u);
            }
        }
        radj // already sorted since u ascends
    }

    /// Is the graph connected (treating edges as undirected, over U ∪ V)?
    /// Connectivity of the mask matters for information flow (§4).
    pub fn is_connected(&self) -> bool {
        if self.nu == 0 || self.nv == 0 {
            return false;
        }
        let radj = self.right_adj();
        let mut seen_u = vec![false; self.nu];
        let mut seen_v = vec![false; self.nv];
        let mut stack = vec![(true, 0usize)]; // (is_left, index)
        seen_u[0] = true;
        while let Some((left, i)) = stack.pop() {
            if left {
                for &v in &self.adj[i] {
                    if !seen_v[v] {
                        seen_v[v] = true;
                        stack.push((false, v));
                    }
                }
            } else {
                for &u in &radj[i] {
                    if !seen_u[u] {
                        seen_u[u] = true;
                        stack.push((true, u));
                    }
                }
            }
        }
        seen_u.iter().all(|&b| b) && seen_v.iter().all(|&b| b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_graph_properties() {
        let g = BipartiteGraph::complete(3, 5);
        assert_eq!(g.num_edges(), 15);
        assert_eq!(g.degrees().unwrap(), (5, 3));
        assert!(g.is_complete());
        assert_eq!(g.sparsity(), 0.0);
        assert!(g.is_connected());
    }

    #[test]
    fn from_edges_rejects_bad_input() {
        assert!(BipartiteGraph::from_edges(2, 2, &[(0, 0), (0, 0)]).is_err());
        assert!(BipartiteGraph::from_edges(2, 2, &[(2, 0)]).is_err());
    }

    #[test]
    fn biadjacency_matches_edges() {
        let g = BipartiteGraph::from_edges(2, 3, &[(0, 1), (1, 0), (1, 2)]).unwrap();
        let ba = g.biadjacency();
        assert_eq!(ba, vec![0., 1., 0., 1., 0., 1.]);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn random_biregular_is_biregular() {
        let mut rng = Rng::new(42);
        for &(nu, nv, dl) in &[(8, 8, 4), (16, 8, 2), (8, 16, 8), (32, 32, 4)] {
            let g = BipartiteGraph::random_biregular(nu, nv, dl, &mut rng).unwrap();
            let (gdl, gdr) = g.degrees().unwrap();
            assert_eq!(gdl, dl);
            assert_eq!(gdr, nu * dl / nv);
            assert_eq!(g.num_edges(), nu * dl);
        }
    }

    #[test]
    fn random_biregular_rejects_impossible() {
        let mut rng = Rng::new(1);
        assert!(BipartiteGraph::random_biregular(3, 2, 1, &mut rng).is_err()); // 3*1 % 2 != 0
        assert!(BipartiteGraph::random_biregular(2, 2, 3, &mut rng).is_err()); // dl > nv
    }

    #[test]
    fn sparsity_of_half_graph() {
        let mut rng = Rng::new(2);
        let g = BipartiteGraph::random_biregular(8, 8, 4, &mut rng).unwrap();
        assert!((g.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn right_adj_transposes() {
        let g = BipartiteGraph::from_edges(2, 2, &[(0, 1), (1, 1)]).unwrap();
        let r = g.right_adj();
        assert_eq!(r[0], Vec::<usize>::new());
        assert_eq!(r[1], vec![0, 1]);
    }

    #[test]
    fn disconnected_detected() {
        // Two disjoint K_{1,1}'s: u0-v0, u1-v1.
        let g = BipartiteGraph::from_edges(2, 2, &[(0, 0), (1, 1)]).unwrap();
        assert!(!g.is_connected());
        let c = BipartiteGraph::complete(2, 2);
        assert!(c.is_connected());
    }

    #[test]
    fn identity_graph() {
        let g = BipartiteGraph::identity(4);
        assert_eq!(g.degrees().unwrap(), (1, 1));
        assert!(!g.is_connected()); // disjoint matchings
    }
}
