//! 2-lift operation (Appendix 8.1 / Bilu–Linial [3]).
//!
//! A 2-lift of `G` produces `G_L` twice as large in vertices and edges:
//! clone the graph, then for each edge `(u, v)` independently keep either the
//! identity pair `{(u,v), (u^c,v^c)}` or the crossover pair
//! `{(u,v^c), (u^c,v)}`. Lifting preserves biregularity and left/right
//! degrees, so repeated lifting of a complete bipartite graph
//! `K_{(1−sp)·m, (1−sp)·n}` yields an `m × n` biregular graph with sparsity
//! `sp` after `log2(1/(1−sp))` lifts.

use crate::graph::bipartite::BipartiteGraph;
use crate::util::rng::Rng;

/// Which half a lifted vertex came from. Vertex `x` of `G` maps to `x`
/// (original) and `x + n` (clone) in `G_L`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LiftSign {
    /// Keep `{(u,v), (u^c,v^c)}`.
    Identity,
    /// Keep `{(u,v^c), (u^c,v)}`.
    Crossover,
}

/// Apply a 2-lift with explicit per-edge signs (edge order =
/// `g.edges()` lexicographic order). Exposed for deterministic tests; use
/// [`lift2`] for random lifts.
pub fn lift2_with_signs(g: &BipartiteGraph, signs: &[LiftSign]) -> anyhow::Result<BipartiteGraph> {
    let edges = g.edges();
    anyhow::ensure!(
        signs.len() == edges.len(),
        "need {} signs, got {}",
        edges.len(),
        signs.len()
    );
    let mut out = Vec::with_capacity(edges.len() * 2);
    for (&(u, v), &sign) in edges.iter().zip(signs) {
        let (uc, vc) = (u + g.nu, v + g.nv);
        match sign {
            LiftSign::Identity => {
                out.push((u, v));
                out.push((uc, vc));
            }
            LiftSign::Crossover => {
                out.push((u, vc));
                out.push((uc, v));
            }
        }
    }
    BipartiteGraph::from_edges(g.nu * 2, g.nv * 2, &out)
}

/// Apply one uniformly-random 2-lift.
pub fn lift2(g: &BipartiteGraph, rng: &mut Rng) -> BipartiteGraph {
    let signs: Vec<LiftSign> = (0..g.num_edges())
        .map(|_| {
            if rng.bool(0.5) {
                LiftSign::Crossover
            } else {
                LiftSign::Identity
            }
        })
        .collect();
    lift2_with_signs(g, &signs).expect("lift of a valid graph is valid")
}

/// Number of 2-lifts needed to reach sparsity `sp` starting from a complete
/// graph: `log2(1 / (1 − sp))`. Errors unless `1/(1−sp)` is a power of two
/// (the paper's generator only supports dyadic sparsities: 0, 1/2, 3/4,
/// 7/8, 15/16, …).
pub fn lifts_for_sparsity(sp: f64) -> anyhow::Result<u32> {
    anyhow::ensure!((0.0..1.0).contains(&sp), "sparsity {sp} out of [0,1)");
    let inv = 1.0 / (1.0 - sp);
    let k = inv.log2().round() as u32;
    let back = 1.0 - 0.5f64.powi(k as i32);
    anyhow::ensure!(
        (back - sp).abs() < 1e-9,
        "sparsity {sp} is not dyadic (1 - 2^-k); nearest is {back}"
    );
    Ok(k)
}

/// Generate a random `(m × n)` biregular bipartite graph of dyadic sparsity
/// `sp` by repeatedly 2-lifting the complete graph
/// `K_{(1−sp)·m, (1−sp)·n}` (Appendix 8.1, "Generating sparse biregular
/// bipartite graph"). The result has `d_l = (1−sp)·n`, `d_r = (1−sp)·m`.
pub fn sparse_biregular_by_lifts(
    m: usize,
    n: usize,
    sp: f64,
    rng: &mut Rng,
) -> anyhow::Result<BipartiteGraph> {
    let k = lifts_for_sparsity(sp)?;
    let frac = 0.5f64.powi(k as i32); // = 1 - sp
    let base_m = ((m as f64) * frac).round() as usize;
    let base_n = ((n as f64) * frac).round() as usize;
    anyhow::ensure!(
        base_m >= 1 && base_n >= 1,
        "sparsity {sp} too high for {m}x{n}: base graph would be empty"
    );
    anyhow::ensure!(
        base_m << k == m && base_n << k == n,
        "{m}x{n} not divisible by 2^{k}; cannot reach sparsity {sp} by 2-lifts"
    );
    let mut g = BipartiteGraph::complete(base_m, base_n);
    for _ in 0..k {
        g = lift2(&g, rng);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lift_doubles_vertices_and_edges() {
        let g = BipartiteGraph::complete(3, 4);
        let mut rng = Rng::new(1);
        let gl = lift2(&g, &mut rng);
        assert_eq!(gl.nu, 6);
        assert_eq!(gl.nv, 8);
        assert_eq!(gl.num_edges(), 24);
    }

    #[test]
    fn lift_preserves_biregularity_and_degrees() {
        let mut rng = Rng::new(2);
        let g = BipartiteGraph::random_biregular(8, 4, 2, &mut rng).unwrap();
        let (dl, dr) = g.degrees().unwrap();
        let gl = lift2(&g, &mut rng);
        assert_eq!(gl.degrees().unwrap(), (dl, dr));
    }

    #[test]
    fn identity_signs_give_two_disjoint_copies() {
        let g = BipartiteGraph::complete(2, 2);
        let signs = vec![LiftSign::Identity; 4];
        let gl = lift2_with_signs(&g, &signs).unwrap();
        // Edges stay within {orig} x {orig} or {clone} x {clone}.
        for (u, v) in gl.edges() {
            assert_eq!(u < 2, v < 2);
        }
        assert!(!gl.is_connected());
    }

    #[test]
    fn crossover_signs_give_bipartite_double_cover_structure() {
        let g = BipartiteGraph::complete(2, 2);
        let signs = vec![LiftSign::Crossover; 4];
        let gl = lift2_with_signs(&g, &signs).unwrap();
        for (u, v) in gl.edges() {
            assert_ne!(u < 2, v < 2); // all edges cross halves
        }
        assert_eq!(gl.num_edges(), 8);
    }

    #[test]
    fn figure4_example_shape() {
        // Figure 4: a graph where two edges cross over. Start from K_{2,2},
        // cross edges (u1,v1)=(0,0) and (u2,v2)=(1,1) (paper's labels 1-based).
        let g = BipartiteGraph::complete(2, 2);
        let signs = vec![
            LiftSign::Crossover, // (0,0)
            LiftSign::Identity,  // (0,1)
            LiftSign::Identity,  // (1,0)
            LiftSign::Crossover, // (1,1)
        ];
        let gl = lift2_with_signs(&g, &signs).unwrap();
        assert!(gl.has_edge(0, 2)); // u1 — v1^c
        assert!(gl.has_edge(2, 0)); // u1^c — v1
        assert!(gl.has_edge(0, 1)); // identity edge kept
        assert!(gl.has_edge(1, 3)); // u2 — v2^c
        assert!(gl.has_edge(3, 1)); // u2^c — v2
        assert_eq!(gl.degrees().unwrap(), (2, 2));
    }

    #[test]
    fn lifts_for_sparsity_dyadic() {
        assert_eq!(lifts_for_sparsity(0.0).unwrap(), 0);
        assert_eq!(lifts_for_sparsity(0.5).unwrap(), 1);
        assert_eq!(lifts_for_sparsity(0.75).unwrap(), 2);
        assert_eq!(lifts_for_sparsity(0.875).unwrap(), 3);
        assert_eq!(lifts_for_sparsity(0.9375).unwrap(), 4);
        assert!(lifts_for_sparsity(0.6).is_err());
        assert!(lifts_for_sparsity(1.0).is_err());
    }

    #[test]
    fn sparse_biregular_by_lifts_reaches_target() {
        let mut rng = Rng::new(7);
        for &(m, n, sp) in &[(32usize, 32usize, 0.5f64), (32, 128, 0.75), (64, 64, 0.875)] {
            let g = sparse_biregular_by_lifts(m, n, sp, &mut rng).unwrap();
            assert_eq!(g.nu, m);
            assert_eq!(g.nv, n);
            assert!((g.sparsity() - sp).abs() < 1e-12, "sp={}", g.sparsity());
            let (dl, dr) = g.degrees().unwrap();
            assert_eq!(dl, ((1.0 - sp) * n as f64).round() as usize);
            assert_eq!(dr, ((1.0 - sp) * m as f64).round() as usize);
        }
    }

    #[test]
    fn sparse_biregular_rejects_nondivisible() {
        let mut rng = Rng::new(3);
        assert!(sparse_biregular_by_lifts(6, 6, 0.75, &mut rng).is_err());
    }
}
