//! Ramanujan certification and rejection-sampling generation (§3, App. 8.1).
//!
//! A `(d_l, d_r)`-biregular bipartite graph is *Ramanujan* when its second
//! largest adjacency eigenvalue satisfies
//! `λ₂ ≤ √(d_l − 1) + √(d_r − 1)`.
//! The paper generates candidates by repeated 2-lifts of a complete graph
//! and resamples until the bound holds (Bilu–Linial lifts are Ramanujan with
//! good probability; Marcus–Spielman–Srivastava prove good lifts always
//! exist).

use crate::graph::bipartite::BipartiteGraph;
use crate::graph::lift::sparse_biregular_by_lifts;
use crate::graph::spectral::spectrum;
use crate::util::rng::Rng;

/// The Ramanujan bound `√(d_l − 1) + √(d_r − 1)` for a `(d_l, d_r)`-biregular
/// graph.
pub fn ramanujan_bound(dl: usize, dr: usize) -> f64 {
    ((dl as f64 - 1.0).max(0.0)).sqrt() + ((dr as f64 - 1.0).max(0.0)).sqrt()
}

/// Certification result for one graph.
#[derive(Clone, Copy, Debug)]
pub struct Certificate {
    pub dl: usize,
    pub dr: usize,
    pub lambda1: f64,
    pub lambda2: f64,
    pub bound: f64,
    pub is_ramanujan: bool,
}

/// Check whether `g` is a Ramanujan bipartite graph. Complete bipartite
/// graphs (λ₂ = 0) and trivial (1,·)-regular graphs certify trivially.
///
/// `tol` absorbs power-iteration error; 1e-7 relative is plenty for the
/// graph sizes we use.
pub fn certify(g: &BipartiteGraph, seed: u64) -> anyhow::Result<Certificate> {
    let (dl, dr) = g.degrees()?;
    let s = spectrum(g, seed);
    let bound = ramanujan_bound(dl, dr);
    let tol = 1e-7 * s.lambda1.max(1.0);
    Ok(Certificate {
        dl,
        dr,
        lambda1: s.lambda1,
        lambda2: s.lambda2,
        bound,
        is_ramanujan: s.lambda2 <= bound + tol,
    })
}

/// Outcome of [`generate`]: the graph plus how many samples it took.
#[derive(Clone, Debug)]
pub struct Generated {
    pub graph: BipartiteGraph,
    pub cert: Certificate,
    pub attempts: usize,
}

/// Generate an `(m × n)` Ramanujan bipartite graph of dyadic sparsity `sp`
/// by rejection sampling over random 2-lift chains (Appendix 8.1,
/// "Generating RBG graph").
///
/// Complete graphs (sp = 0) are returned immediately — they are Ramanujan
/// (λ₂ = 0). `max_attempts` bounds the rejection loop; in practice a handful
/// of attempts suffice for the sizes the paper uses.
pub fn generate(
    m: usize,
    n: usize,
    sp: f64,
    rng: &mut Rng,
    max_attempts: usize,
) -> anyhow::Result<Generated> {
    if sp == 0.0 {
        let graph = BipartiteGraph::complete(m, n);
        let cert = certify(&graph, rng.next_u64())?;
        return Ok(Generated {
            graph,
            cert,
            attempts: 1,
        });
    }
    let mut best: Option<(f64, BipartiteGraph, Certificate)> = None;
    for attempt in 1..=max_attempts {
        let g = sparse_biregular_by_lifts(m, n, sp, rng)?;
        let cert = certify(&g, rng.next_u64())?;
        if cert.is_ramanujan {
            return Ok(Generated {
                graph: g,
                cert,
                attempts: attempt,
            });
        }
        if best.as_ref().map(|(l2, _, _)| cert.lambda2 < *l2).unwrap_or(true) {
            best = Some((cert.lambda2, g, cert));
        }
    }
    let (_, _g, cert) = best.expect("max_attempts >= 1");
    anyhow::bail!(
        "no Ramanujan graph in {max_attempts} samples for {m}x{n} sp={sp}: best λ₂={:.4} > bound {:.4}",
        cert.lambda2,
        cert.bound
    )
}

/// Like [`generate`] but falls back to the best (lowest-λ₂) sample instead of
/// failing — used by mask construction where a near-Ramanujan expander is
/// still a perfectly usable mask. Returns `(generated, fell_back)`.
pub fn generate_best_effort(
    m: usize,
    n: usize,
    sp: f64,
    rng: &mut Rng,
    max_attempts: usize,
) -> anyhow::Result<(Generated, bool)> {
    if sp == 0.0 {
        return Ok((generate(m, n, sp, rng, 1)?, false));
    }
    let mut best: Option<(BipartiteGraph, Certificate)> = None;
    for attempt in 1..=max_attempts {
        let g = sparse_biregular_by_lifts(m, n, sp, rng)?;
        let cert = certify(&g, rng.next_u64())?;
        if cert.is_ramanujan {
            return Ok((
                Generated {
                    graph: g,
                    cert,
                    attempts: attempt,
                },
                false,
            ));
        }
        if best
            .as_ref()
            .map(|(_, c)| cert.lambda2 < c.lambda2)
            .unwrap_or(true)
        {
            best = Some((g, cert));
        }
    }
    let (graph, cert) = best.expect("max_attempts >= 1");
    Ok((
        Generated {
            graph,
            cert,
            attempts: max_attempts,
        },
        true,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_values() {
        assert_eq!(ramanujan_bound(1, 1), 0.0);
        assert!((ramanujan_bound(4, 4) - 2.0 * 3f64.sqrt()).abs() < 1e-12);
        assert!((ramanujan_bound(2, 5) - (1.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn complete_graph_is_ramanujan() {
        let g = BipartiteGraph::complete(8, 4);
        let c = certify(&g, 1).unwrap();
        assert!(c.is_ramanujan);
        assert!(c.lambda2 < 1e-5);
    }

    #[test]
    fn perfect_matching_is_not_ramanujan_for_d1() {
        // d=1 bound is 0 but λ₂ = 1 (identity matrix) → not Ramanujan.
        let g = BipartiteGraph::identity(8);
        let c = certify(&g, 1).unwrap();
        assert!(!c.is_ramanujan);
    }

    #[test]
    fn generate_small_ramanujan_graphs() {
        let mut rng = Rng::new(2024);
        for &(m, n, sp) in &[(16usize, 16usize, 0.5f64), (32, 32, 0.75), (32, 128, 0.75)] {
            let gen = generate(m, n, sp, &mut rng, 200).unwrap();
            assert!(gen.cert.is_ramanujan);
            assert!((gen.graph.sparsity() - sp).abs() < 1e-12);
            let (dl, dr) = gen.graph.degrees().unwrap();
            assert_eq!(dl, gen.cert.dl);
            assert_eq!(dr, gen.cert.dr);
            assert!(gen.cert.lambda2 <= gen.cert.bound + 1e-6);
        }
    }

    #[test]
    fn generated_graph_is_connected() {
        let mut rng = Rng::new(5);
        let gen = generate(32, 32, 0.875, &mut rng, 500).unwrap();
        // Ramanujan ⇒ spectral gap ⇒ connected.
        assert!(gen.graph.is_connected());
    }

    #[test]
    fn best_effort_never_fails_on_valid_shapes() {
        let mut rng = Rng::new(6);
        let (gen, _fellback) = generate_best_effort(16, 16, 0.875, &mut rng, 50).unwrap();
        assert_eq!(gen.graph.nu, 16);
        assert!((gen.graph.sparsity() - 0.875).abs() < 1e-12);
    }
}
