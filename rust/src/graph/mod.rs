//! Graph-theory substrate: bipartite graphs, 2-lifts, spectral analysis,
//! Ramanujan certification/generation, and the bipartite graph product —
//! everything §3–§4 and Appendix 8.1 of the paper rely on.

pub mod bipartite;
pub mod lift;
pub mod product;
pub mod ramanujan;
pub mod spectral;

pub use bipartite::BipartiteGraph;
pub use product::{product, product_many};
pub use ramanujan::{certify, generate, ramanujan_bound, Certificate};
pub use spectral::{spectrum, Spectrum};
