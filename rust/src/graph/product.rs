//! Bipartite graph product ⊗_b (§3, Figure 2).
//!
//! `G_p = G_1 ⊗_b G_2` has `U_p = U_1 × U_2`, `V_p = V_1 × V_2` and an edge
//! `((u_1,u_2),(v_1,v_2))` iff `(u_1,v_1) ∈ E_1` and `(u_2,v_2) ∈ E_2`.
//! Vertex `(a, b)` of the product is flattened to index `a·|·_2| + b`, which
//! makes the biadjacency of the product exactly the tensor (Kronecker)
//! product `BA_p = BA_1 ⊗ BA_2`.

use crate::graph::bipartite::BipartiteGraph;

/// Bipartite graph product of two graphs.
pub fn product(g1: &BipartiteGraph, g2: &BipartiteGraph) -> BipartiteGraph {
    let nu = g1.nu * g2.nu;
    let nv = g1.nv * g2.nv;
    let mut adj = vec![Vec::new(); nu];
    for (u1, n1) in g1.adj.iter().enumerate() {
        for (u2, n2) in g2.adj.iter().enumerate() {
            let u = u1 * g2.nu + u2;
            let lst = &mut adj[u];
            lst.reserve(n1.len() * n2.len());
            for &v1 in n1 {
                for &v2 in n2 {
                    lst.push(v1 * g2.nv + v2);
                }
            }
            lst.sort_unstable();
        }
    }
    BipartiteGraph { nu, nv, adj }
}

/// K-way product `G_1 ⊗_b … ⊗_b G_K` (left-associated; ⊗_b is associative
/// under the flattening convention, which the tests verify).
pub fn product_many(gs: &[&BipartiteGraph]) -> anyhow::Result<BipartiteGraph> {
    anyhow::ensure!(!gs.is_empty(), "product of zero graphs");
    let mut acc = gs[0].clone();
    for g in &gs[1..] {
        acc = product(&acc, g);
    }
    Ok(acc)
}

/// Tensor (Kronecker) product of two dense row-major matrices — the matrix
/// view of ⊗_b. Used as the test oracle for [`product`] and by the sparsity
/// pattern validators.
pub fn kronecker(a: &[f32], (am, an): (usize, usize), b: &[f32], (bm, bn): (usize, usize)) -> Vec<f32> {
    assert_eq!(a.len(), am * an);
    assert_eq!(b.len(), bm * bn);
    let (m, n) = (am * bm, an * bn);
    let mut out = vec![0.0f32; m * n];
    for i1 in 0..am {
        for j1 in 0..an {
            let aij = a[i1 * an + j1];
            if aij == 0.0 {
                continue;
            }
            for i2 in 0..bm {
                let row = (i1 * bm + i2) * n + j1 * bn;
                let brow = i2 * bn;
                for j2 in 0..bn {
                    out[row + j2] = aij * b[brow + j2];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::spectral::singular_values_dense_oracle;
    use crate::util::rng::Rng;

    #[test]
    fn product_sizes_and_edges() {
        let g1 = BipartiteGraph::complete(2, 3);
        let g2 = BipartiteGraph::complete(4, 5);
        let p = product(&g1, &g2);
        assert_eq!((p.nu, p.nv), (8, 15));
        assert_eq!(p.num_edges(), g1.num_edges() * g2.num_edges());
    }

    #[test]
    fn product_biadjacency_is_kronecker() {
        let mut rng = Rng::new(4);
        let g1 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let g2 = BipartiteGraph::random_biregular(4, 2, 1, &mut rng).unwrap();
        let p = product(&g1, &g2);
        let kron = kronecker(
            &g1.biadjacency(),
            (g1.nu, g1.nv),
            &g2.biadjacency(),
            (g2.nu, g2.nv),
        );
        assert_eq!(p.biadjacency(), kron);
    }

    #[test]
    fn figure2_example() {
        // Figure 2: G_1 is a 2x2 graph with edges forming an X-ish pattern,
        // G_2 = K_{2,2}. Product biadjacency has CBS blocks of size (2,2):
        // wherever BA_1 is 1, a full 2x2 block appears.
        let g1 = BipartiteGraph::from_edges(2, 2, &[(0, 0), (1, 0), (1, 1)]).unwrap();
        let g2 = BipartiteGraph::complete(2, 2);
        let p = product(&g1, &g2);
        let ba = p.biadjacency();
        for bi in 0..2 {
            for bj in 0..2 {
                let expect = if g1.has_edge(bi, bj) { 1.0 } else { 0.0 };
                for i in 0..2 {
                    for j in 0..2 {
                        assert_eq!(ba[(bi * 2 + i) * 4 + bj * 2 + j], expect);
                    }
                }
            }
        }
    }

    #[test]
    fn product_degrees_multiply() {
        let mut rng = Rng::new(8);
        let g1 = BipartiteGraph::random_biregular(8, 8, 2, &mut rng).unwrap();
        let g2 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let p = product(&g1, &g2);
        assert_eq!(p.degrees().unwrap(), (4, 4));
    }

    #[test]
    fn product_sparsity_composes() {
        // sparsity(G) = 1 - (1-α1)(1-α2)
        let mut rng = Rng::new(9);
        let g1 = BipartiteGraph::random_biregular(8, 8, 4, &mut rng).unwrap(); // α=0.5
        let g2 = BipartiteGraph::random_biregular(8, 8, 2, &mut rng).unwrap(); // α=0.75
        let p = product(&g1, &g2);
        assert!((p.sparsity() - (1.0 - 0.5 * 0.25)).abs() < 1e-12);
    }

    #[test]
    fn product_associative_under_flattening() {
        let mut rng = Rng::new(10);
        let a = BipartiteGraph::random_biregular(2, 4, 2, &mut rng).unwrap();
        let b = BipartiteGraph::random_biregular(4, 2, 1, &mut rng).unwrap();
        let c = BipartiteGraph::complete(2, 2);
        let left = product(&product(&a, &b), &c);
        let right = product(&a, &product(&b, &c));
        assert_eq!(left, right);
    }

    #[test]
    fn product_many_matches_fold() {
        let a = BipartiteGraph::complete(2, 2);
        let b = BipartiteGraph::identity(2);
        let c = BipartiteGraph::complete(1, 3);
        let p = product_many(&[&a, &b, &c]).unwrap();
        assert_eq!(p.nu, 4);
        assert_eq!(p.nv, 12);
        assert_eq!(p.num_edges(), 4 * 2 * 3);
    }

    #[test]
    fn eigenvalues_of_product_are_products() {
        // Theorem 1's engine: singular values of a Kronecker product are the
        // pairwise products of singular values.
        let mut rng = Rng::new(12);
        let g1 = BipartiteGraph::random_biregular(6, 6, 3, &mut rng).unwrap();
        let g2 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng).unwrap();
        let p = product(&g1, &g2);
        let s1 = singular_values_dense_oracle(&g1);
        let s2 = singular_values_dense_oracle(&g2);
        let sp = singular_values_dense_oracle(&p);
        let mut expect: Vec<f64> = s1.iter().flat_map(|a| s2.iter().map(move |b| a * b)).collect();
        expect.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (i, (got, want)) in sp.iter().zip(expect.iter()).enumerate() {
            assert!((got - want).abs() < 1e-6, "sv[{i}]: {got} vs {want}");
        }
    }

    #[test]
    fn kronecker_small_oracle() {
        let a = vec![1.0, 0.0, 0.0, 1.0]; // I2
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let k = kronecker(&a, (2, 2), &b, (2, 2));
        #[rustfmt::skip]
        let expect = vec![
            1., 2., 0., 0.,
            3., 4., 0., 0.,
            0., 0., 1., 2.,
            0., 0., 3., 4.,
        ];
        assert_eq!(k, expect);
    }
}
