//! Spectral analysis of bipartite graphs (§3, §4, Theorem 1).
//!
//! The eigenvalues of a bipartite graph's (symmetric) adjacency matrix come
//! in ± pairs and equal ± the singular values of the biadjacency matrix
//! `BA`. We therefore compute singular values of `BA` by power iteration on
//! `BAᵀ·BA` with Hotelling deflation — no external linear-algebra crate.
//!
//! For a `(d_l, d_r)`-biregular graph, `λ₁ = √(d_l·d_r)` exactly (the
//! all-ones vector pair); the connectivity measure is the second singular
//! value `λ₂` and the spectral gap `λ₁ − λ₂`.

use crate::graph::bipartite::BipartiteGraph;
use crate::util::rng::Rng;

/// Result of a spectral computation.
#[derive(Clone, Copy, Debug)]
pub struct Spectrum {
    /// Largest singular value of the biadjacency matrix.
    pub lambda1: f64,
    /// Second-largest singular value.
    pub lambda2: f64,
}

impl Spectrum {
    pub fn gap(&self) -> f64 {
        self.lambda1 - self.lambda2
    }
}

/// y = BAᵀ·(BA·x) using adjacency lists; x has length nv.
fn ata_matvec(g: &BipartiteGraph, x: &[f64], tmp_u: &mut [f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), g.nv);
    debug_assert_eq!(tmp_u.len(), g.nu);
    debug_assert_eq!(out.len(), g.nv);
    tmp_u.fill(0.0);
    for (u, nbrs) in g.adj.iter().enumerate() {
        let mut s = 0.0;
        for &v in nbrs {
            s += x[v];
        }
        tmp_u[u] = s;
    }
    out.fill(0.0);
    for (u, nbrs) in g.adj.iter().enumerate() {
        let t = tmp_u[u];
        for &v in nbrs {
            out[v] += t;
        }
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|a| a * a).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) -> f64 {
    let n = norm(x);
    if n > 0.0 {
        for a in x.iter_mut() {
            *a /= n;
        }
    }
    n
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Project `x` orthogonal to each (unit) vector in `basis`.
fn deflate(x: &mut [f64], basis: &[Vec<f64>]) {
    for b in basis {
        let c = dot(x, b);
        for (xi, bi) in x.iter_mut().zip(b) {
            *xi -= c * bi;
        }
    }
}

/// Top-`k` singular values of the biadjacency matrix by power iteration on
/// `BAᵀBA` with deflation. Deterministic given `seed`.
pub fn singular_values(g: &BipartiteGraph, k: usize, seed: u64) -> Vec<f64> {
    let nv = g.nv;
    let mut rng = Rng::new(seed);
    let mut found: Vec<f64> = Vec::new();
    let mut basis: Vec<Vec<f64>> = Vec::new();
    let mut tmp_u = vec![0.0; g.nu];
    let mut y = vec![0.0; nv];
    for _ in 0..k.min(nv) {
        let mut x: Vec<f64> = (0..nv).map(|_| rng.normal()).collect();
        deflate(&mut x, &basis);
        normalize(&mut x);
        let mut eig = 0.0f64;
        // Power iteration with periodic re-orthogonalization.
        for it in 0..600 {
            ata_matvec(g, &x, &mut tmp_u, &mut y);
            deflate(&mut y, &basis);
            let new_eig = normalize(&mut y);
            std::mem::swap(&mut x, &mut y);
            if it > 20 && (new_eig - eig).abs() <= 1e-11 * new_eig.max(1.0) {
                eig = new_eig;
                break;
            }
            eig = new_eig;
        }
        found.push(eig.max(0.0).sqrt());
        basis.push(x.clone());
    }
    found
}

/// λ₁ and λ₂ of `g`. For biregular graphs λ₁ is pinned to its analytic value
/// `√(d_l·d_r)` and λ₂ is computed with the all-ones singular pair deflated
/// exactly — this is both faster and more accurate than generic iteration.
pub fn spectrum(g: &BipartiteGraph, seed: u64) -> Spectrum {
    if let Ok((dl, dr)) = g.degrees() {
        let lambda1 = ((dl * dr) as f64).sqrt();
        // Top singular pair of a biregular BA is (1/√nu · 1, 1/√nv · 1).
        let ones = vec![1.0 / (g.nv as f64).sqrt(); g.nv];
        let basis = vec![ones];
        let mut rng = Rng::new(seed);
        let mut x: Vec<f64> = (0..g.nv).map(|_| rng.normal()).collect();
        deflate(&mut x, &basis);
        normalize(&mut x);
        let mut tmp_u = vec![0.0; g.nu];
        let mut y = vec![0.0; g.nv];
        let mut eig = 0.0f64;
        for it in 0..600 {
            ata_matvec(g, &x, &mut tmp_u, &mut y);
            deflate(&mut y, &basis);
            let new_eig = normalize(&mut y);
            std::mem::swap(&mut x, &mut y);
            if it > 20 && (new_eig - eig).abs() <= 1e-12 * new_eig.max(1.0) {
                eig = new_eig;
                break;
            }
            eig = new_eig;
        }
        Spectrum {
            lambda1,
            lambda2: eig.max(0.0).sqrt(),
        }
    } else {
        let sv = singular_values(g, 2, seed);
        Spectrum {
            lambda1: sv.first().copied().unwrap_or(0.0),
            lambda2: sv.get(1).copied().unwrap_or(0.0),
        }
    }
}

/// Exact singular values for tiny graphs via Jacobi eigenvalue iteration on
/// the dense `BAᵀBA` (test oracle; O(nv³), keep nv ≤ ~64).
pub fn singular_values_dense_oracle(g: &BipartiteGraph) -> Vec<f64> {
    let n = g.nv;
    let ba = g.biadjacency();
    // M = BAᵀ BA (n x n, symmetric PSD)
    let mut m = vec![0.0f64; n * n];
    for u in 0..g.nu {
        for i in 0..n {
            let a = ba[u * n + i] as f64;
            if a == 0.0 {
                continue;
            }
            for j in 0..n {
                m[i * n + j] += a * ba[u * n + j] as f64;
            }
        }
    }
    // Cyclic Jacobi.
    for _sweep in 0..100 {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off < 1e-20 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
            }
        }
    }
    let mut eigs: Vec<f64> = (0..n).map(|i| m[i * n + i].max(0.0).sqrt()).collect();
    eigs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    eigs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_graph_spectrum() {
        // K_{m,n}: singular values are √(mn), 0, 0, ...
        let g = BipartiteGraph::complete(4, 6);
        let s = spectrum(&g, 1);
        assert!((s.lambda1 - 24f64.sqrt()).abs() < 1e-9);
        assert!(s.lambda2.abs() < 1e-6, "lambda2={}", s.lambda2);
    }

    #[test]
    fn identity_graph_spectrum() {
        // Perfect matching: BA = I, all singular values 1 → gap 0.
        let g = BipartiteGraph::identity(6);
        let s = spectrum(&g, 1);
        assert!((s.lambda1 - 1.0).abs() < 1e-9);
        assert!((s.lambda2 - 1.0).abs() < 1e-6);
        assert!(s.gap().abs() < 1e-6);
    }

    #[test]
    fn power_iteration_matches_dense_oracle() {
        let mut rng = Rng::new(11);
        for seed in 0..5u64 {
            let g = BipartiteGraph::random_biregular(16, 16, 4, &mut rng).unwrap();
            let oracle = singular_values_dense_oracle(&g);
            let s = spectrum(&g, seed + 100);
            assert!(
                (s.lambda1 - oracle[0]).abs() < 1e-6,
                "λ1 {} vs oracle {}",
                s.lambda1,
                oracle[0]
            );
            assert!(
                (s.lambda2 - oracle[1]).abs() < 1e-5,
                "λ2 {} vs oracle {}",
                s.lambda2,
                oracle[1]
            );
        }
    }

    #[test]
    fn generic_singular_values_match_oracle_nonregular() {
        // Non-biregular graph exercises the generic path.
        let g = BipartiteGraph::from_edges(4, 4, &[(0, 0), (0, 1), (1, 1), (2, 2), (3, 3), (3, 0)])
            .unwrap();
        let oracle = singular_values_dense_oracle(&g);
        let sv = singular_values(&g, 2, 5);
        assert!((sv[0] - oracle[0]).abs() < 1e-6);
        assert!((sv[1] - oracle[1]).abs() < 1e-5);
    }

    #[test]
    fn biregular_lambda1_analytic() {
        let mut rng = Rng::new(3);
        let g = BipartiteGraph::random_biregular(32, 16, 4, &mut rng).unwrap();
        let (dl, dr) = g.degrees().unwrap();
        let s = spectrum(&g, 9);
        assert!((s.lambda1 - ((dl * dr) as f64).sqrt()).abs() < 1e-12);
        assert!(s.lambda2 <= s.lambda1 + 1e-9);
    }
}
