//! Findings, the unsafe inventory, and the machine-readable report.
//!
//! A finding is *allowed* when an `analyze: allow(rule, reason="…")`
//! annotation covers its line — it still appears in the report (annotated
//! debt is visible debt) but does not fail the pass unless the rule is on
//! the `--deny` list, which ignores annotations for that rule.

use crate::util::json::Json;

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// The annotation reason when an escape covers this site.
    pub allowed: Option<String>,
}

/// One `unsafe` site; `safety` holds the adjacent `// SAFETY:` text.
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    pub file: String,
    pub line: u32,
    pub kind: &'static str,
    pub safety: Option<String>,
}

/// One observed lock-acquisition edge: `acquired` taken while `held` was
/// in scope, at `file:line` (the inner acquisition site).
#[derive(Clone, Debug)]
pub struct LockEdge {
    pub held: String,
    pub acquired: String,
    pub file: String,
    pub held_line: u32,
    pub line: u32,
    pub allowed: Option<String>,
}

/// Full pass output over a file set.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub unsafe_inventory: Vec<UnsafeSite>,
    pub lock_edges: Vec<LockEdge>,
}

impl Report {
    /// Findings that fail the pass: unannotated ones, plus annotated ones
    /// whose rule is denied (`--deny rule` ignores its escapes), plus every
    /// malformed annotation (rule name "annotation", never suppressible).
    pub fn denied<'a>(&'a self, deny: &'a [String]) -> impl Iterator<Item = &'a Finding> {
        let deny_all = deny.iter().any(|d| d == "all");
        self.findings.iter().filter(move |f| {
            f.allowed.is_none() || deny_all || deny.iter().any(|d| d == f.rule)
        })
    }

    pub fn allowed_count(&self) -> usize {
        self.findings.iter().filter(|f| f.allowed.is_some()).count()
    }

    /// Serialize the whole report (the `analysis_report.json` artifact).
    pub fn to_json(&self, deny: &[String]) -> Json {
        let mut root = Json::obj();
        root.set("version", 1.0);
        root.set("files_scanned", self.files_scanned as f64);
        let denied: Vec<&Finding> = self.denied(deny).collect();
        root.set("clean", denied.is_empty());
        root.set(
            "deny",
            Json::Arr(deny.iter().map(|d| Json::Str(d.clone())).collect()),
        );
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.set("rule", f.rule).set("file", f.file.as_str());
                o.set("line", f.line as f64).set("message", f.message.as_str());
                match &f.allowed {
                    Some(reason) => o.set("allowed", reason.as_str()),
                    None => o.set("allowed", Json::Null),
                };
                o
            })
            .collect();
        root.set("findings", Json::Arr(findings));
        let inventory: Vec<Json> = self
            .unsafe_inventory
            .iter()
            .map(|u| {
                let mut o = Json::obj();
                o.set("file", u.file.as_str()).set("line", u.line as f64);
                o.set("kind", u.kind);
                match &u.safety {
                    Some(s) => o.set("safety", s.as_str()),
                    None => o.set("safety", Json::Null),
                };
                o
            })
            .collect();
        root.set("unsafe_inventory", Json::Arr(inventory));
        let edges: Vec<Json> = self
            .lock_edges
            .iter()
            .map(|e| {
                let mut o = Json::obj();
                o.set("held", e.held.as_str()).set("acquired", e.acquired.as_str());
                o.set("file", e.file.as_str());
                o.set("held_line", e.held_line as f64).set("line", e.line as f64);
                o.set("allowed", e.allowed.is_some());
                o
            })
            .collect();
        root.set("lock_graph_edges", Json::Arr(edges));
        root
    }

    /// Human-readable summary; `verbose` also prints annotated findings.
    pub fn render_text(&self, deny: &[String], verbose: bool) -> String {
        let mut s = String::new();
        let denied: Vec<&Finding> = self.denied(deny).collect();
        for f in &denied {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        if verbose {
            for f in self.findings.iter().filter(|f| f.allowed.is_some()) {
                if denied.iter().any(|d| std::ptr::eq(*d, f)) {
                    continue;
                }
                let reason = f.allowed.as_deref().unwrap_or("");
                s.push_str(&format!(
                    "{}:{}: [{}] allowed ({reason}): {}\n",
                    f.file, f.line, f.rule, f.message
                ));
            }
        }
        let justified = self
            .unsafe_inventory
            .iter()
            .filter(|u| u.safety.is_some())
            .count();
        s.push_str(&format!(
            "analyzed {} files: {} finding(s) denied, {} allowed by annotation\n",
            self.files_scanned,
            denied.len(),
            self.allowed_count(),
        ));
        s.push_str(&format!(
            "unsafe inventory: {} site(s), {justified} with SAFETY justification; \
             lock graph: {} edge(s)\n",
            self.unsafe_inventory.len(),
            self.lock_edges.len(),
        ));
        s
    }
}
