//! **lock-order** — a static acquisition graph over the crate's named
//! locks, built from guard-in-scope analysis of function bodies.
//!
//! Per function, every acquisition is located and given a live token
//! range: a plain `let g = lock_recover(&…);` guard lives to the end of
//! its enclosing block (or an explicit `drop(g)`); a chained temporary
//! (`lock_recover(&…).get(k)`, or the scrutinee of an `if let`) lives to
//! the end of its statement — including a block the statement heads,
//! which is exactly why `PlanCache::plan_for`'s peek-then-insert pattern
//! does *not* count as re-entry. An acquisition B inside the live range
//! of acquisition A records the edge `class(A) → class(B)`; taking the
//! same class while it is already held is reported directly. After all
//! files are scanned, any cycle in the merged graph — a lock-order
//! inversion the property suites can't reliably provoke — fails the pass.
//!
//! Known limits (by construction, documented in ARCHITECTURE.md): the
//! scanner does not see acquisitions hidden behind `Drop` impls or
//! uncurated method calls, and guards moved across function boundaries
//! are treated as function-local. The curated tables below name the
//! repo's lock-taking entry points so the common cross-module shapes
//! (queue pops, latency recording, drain waits, plan eviction) are edges.

use super::lexer::Kind;
use super::report::{Finding, LockEdge};
use super::rules::{finding, matching_paren};
use super::scan::{statement_end, SourceModel};

/// Curated lock classes: (path suffix, receiver field) → class name.
/// Both halves must match; per-file keying keeps `queue.rs`'s `state`
/// mutex distinct from `registry.rs`'s.
const CLASSES: [(&str, &str, &str); 14] = [
    ("serving/queue.rs", "state", "queue-state"),
    ("serving/queue.rs", "slots", "queue-slots"),
    ("serving/registry.rs", "state", "registry-state"),
    ("serving/registry.rs", "drain_lock", "registry-drain"),
    ("serving/mod.rs", "handles", "serving-handles"),
    ("serving/backend.rs", "shared", "kernel-plan"),
    ("coordinator/metrics.rs", "latencies", "metrics-latency-ring"),
    ("coordinator/metrics.rs", "ring", "metrics-latency-ring"),
    ("coordinator/metrics.rs", "models", "metrics-models"),
    ("coordinator/metrics.rs", "aliases", "metrics-aliases"),
    ("kernels/autotune.rs", "entries", "tune-cache"),
    ("kernels/plan.rs", "plans", "plan-cache"),
    ("kernels/plan.rs", "plan", "kernel-plan"),
    ("util/threadpool.rs", "rx", "threadpool-queue"),
];

/// Curated lock-taking methods: calling `x.method(…)` acquires (and
/// releases) the named class internally. Only distinctively-named entry
/// points are listed — generic names like `push` or `execute` would drown
/// the graph in false edges.
const PROPAGATES: [(&str, &str); 8] = [
    ("pop_blocking", "queue-state"),
    ("pop_until", "queue-state"),
    ("pop_model_or_steal", "queue-state"),
    ("record_latency", "metrics-latency-ring"),
    ("wait_drained", "registry-drain"),
    ("plan_for", "plan-cache"),
    ("invalidate_structure", "plan-cache"),
    ("retain_structures", "plan-cache"),
];

fn classify(path: &str, receiver: &str) -> String {
    for (suffix, recv, class) in CLASSES {
        if path.ends_with(suffix) && receiver == recv {
            return class.to_string();
        }
    }
    let mut parts: Vec<&str> = path.split('/').collect();
    let tail = parts.split_off(parts.len().saturating_sub(2)).join("/");
    format!("{tail}:{receiver}")
}

/// One acquisition: its token index, source line, lock class, and the
/// token range the guard stays live (`None` for instantaneous curated
/// calls, which acquire and release internally).
struct Acq {
    ix: usize,
    line: u32,
    class: String,
    until: Option<usize>,
}

/// The receiver field of a `lock_recover(&self.x…)` argument list: the
/// identifier after the last `.` (so `&self.latencies[w]` → `latencies`),
/// falling back to the first identifier (`lock_recover(ring)` → `ring`).
fn receiver_in_args(m: &SourceModel, open: usize, close: usize) -> String {
    let toks = &m.toks;
    let mut first = None;
    let mut dotted = None;
    for j in open + 1..close {
        if toks[j].kind == Kind::Ident {
            if first.is_none() {
                first = Some(j);
            }
            if toks[j - 1].is_punct('.') {
                dotted = Some(j);
            }
        }
    }
    match dotted.or(first) {
        Some(j) => toks[j].text.clone(),
        None => "<expr>".to_string(),
    }
}

/// End of a block-scoped guard named `name`, live from token `from`: the
/// close of the enclosing block, or an explicit `drop(name)`.
fn block_guard_end(m: &SourceModel, from: usize, name: &str, fn_end: usize) -> usize {
    let toks = &m.toks;
    let mut depth = 0i32;
    let mut i = from;
    while i <= fn_end && i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Punct {
            match t.text.as_bytes()[0] {
                b'{' | b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        return i.saturating_sub(1);
                    }
                }
                _ => {}
            }
        } else if t.is_ident("drop")
            && toks.get(i + 1).is_some_and(|n| n.is_punct('('))
            && toks.get(i + 2).is_some_and(|n| n.is_ident(name))
        {
            return i;
        }
        i += 1;
    }
    fn_end
}

/// Locate every acquisition in `f`'s body and resolve its live range.
fn collect_acqs(m: &SourceModel, f: &super::scan::FnSpan) -> Vec<Acq> {
    let toks = &m.toks;
    let mut acqs = Vec::new();
    for i in f.start..=f.end.min(toks.len().saturating_sub(1)) {
        if m.in_test(i) {
            continue;
        }
        if m.enclosing_fn(i).map(|g| g.start) != Some(f.start) {
            continue; // a nested fn owns this token
        }
        let t = &toks[i];
        if t.kind != Kind::Ident {
            continue;
        }
        let called = toks.get(i + 1).is_some_and(|n| n.is_punct('('));
        if t.text == "lock_recover" && called {
            let close = matching_paren(toks, i + 1);
            let class = classify(&m.path, &receiver_in_args(m, i + 1, close));
            acqs.push(Acq {
                ix: i,
                line: t.line,
                class,
                until: Some(guard_range(m, i, close, f.end)),
            });
        } else if t.text == "lock"
            && called
            && i >= 2
            && toks[i - 1].is_punct('.')
            && toks[i - 2].kind == Kind::Ident
        {
            let close = matching_paren(toks, i + 1);
            let class = classify(&m.path, &toks[i - 2].text);
            acqs.push(Acq {
                ix: i,
                line: t.line,
                class,
                until: Some(guard_range(m, i, close, f.end)),
            });
        } else if called && i >= 1 && toks[i - 1].is_punct('.') {
            if let Some((_, class)) = PROPAGATES.iter().find(|(meth, _)| t.text == *meth) {
                acqs.push(Acq {
                    ix: i,
                    line: t.line,
                    class: class.to_string(),
                    until: None,
                });
            }
        }
    }
    acqs
}

/// Live range for the guard produced by the call ending at `close`:
/// block-scoped when the call is the exact right-hand side of a `let`
/// (`let [mut] name = call;`), statement-scoped otherwise.
fn guard_range(m: &SourceModel, call_ix: usize, close: usize, fn_end: usize) -> usize {
    let toks = &m.toks;
    let recv_len = if toks[call_ix].text == "lock" { 2 } else { 0 };
    let head = call_ix - recv_len; // start of the full call expression
    let eq = head >= 1 && toks[head - 1].is_punct('=');
    let name_ix = head.wrapping_sub(2);
    let let_bound = eq
        && toks.get(name_ix).is_some_and(|n| n.kind == Kind::Ident)
        && (toks.get(head.wrapping_sub(3)).is_some_and(|n| n.is_ident("let"))
            || (toks.get(head.wrapping_sub(3)).is_some_and(|n| n.is_ident("mut"))
                && toks.get(head.wrapping_sub(4)).is_some_and(|n| n.is_ident("let"))));
    let bare_rhs = toks.get(close + 1).is_some_and(|n| n.is_punct(';'));
    if let_bound && bare_rhs {
        block_guard_end(m, close + 2, &toks[name_ix].text, fn_end)
    } else {
        statement_end(toks, call_ix).min(fn_end)
    }
}

/// Scan one file: record acquisition edges and report same-class
/// re-entry (`class held while re-acquired`) immediately.
pub fn scan_file(m: &SourceModel, edges: &mut Vec<LockEdge>, out: &mut Vec<Finding>) {
    for f in &m.fns {
        if f.name == "lock_recover" {
            continue; // the blessed wrapper's own `.lock()` is not an edge
        }
        let acqs = collect_acqs(m, f);
        for a in &acqs {
            let Some(until) = a.until else { continue };
            for b in &acqs {
                if b.ix <= a.ix || b.ix > until {
                    continue;
                }
                if b.class == a.class {
                    out.push(finding(
                        m,
                        "lock-order",
                        b.line,
                        format!(
                            "lock class `{}` re-acquired while already held \
                             (guard taken line {}) — self-deadlock",
                            a.class, a.line,
                        ),
                    ));
                } else {
                    edges.push(LockEdge {
                        held: a.class.clone(),
                        acquired: b.class.clone(),
                        file: m.path.clone(),
                        held_line: a.line,
                        line: b.line,
                        allowed: m.allow_for("lock-order", b.line).map(|x| x.reason.clone()),
                    });
                }
            }
        }
    }
}

/// Merge pass: find cycles in the acquisition graph (annotated edges are
/// excluded) and report one finding per strongly connected component.
pub fn check_cycles(edges: &[LockEdge], out: &mut Vec<Finding>) {
    let eff: Vec<&LockEdge> = edges.iter().filter(|e| e.allowed.is_none()).collect();
    let reaches = |from: &str, to: &str| {
        let mut stack = vec![from];
        let mut seen: Vec<&str> = Vec::new();
        while let Some(n) = stack.pop() {
            for e in eff.iter().filter(|e| e.held == n) {
                if e.acquired == to {
                    return true;
                }
                if !seen.contains(&e.acquired.as_str()) {
                    seen.push(&e.acquired);
                    stack.push(&e.acquired);
                }
            }
        }
        false
    };
    let mut nodes: Vec<&str> = eff
        .iter()
        .flat_map(|e| [e.held.as_str(), e.acquired.as_str()])
        .collect();
    nodes.sort_unstable();
    nodes.dedup();
    let mut sccs: Vec<Vec<&str>> = Vec::new();
    for n in nodes.into_iter().filter(|n| reaches(n, n)) {
        match sccs.iter_mut().find(|s| reaches(s[0], n) && reaches(n, s[0])) {
            Some(scc) => scc.push(n),
            None => sccs.push(vec![n]),
        }
    }
    for scc in sccs {
        let witnesses: Vec<&&LockEdge> = eff
            .iter()
            .filter(|e| scc.contains(&e.held.as_str()) && scc.contains(&e.acquired.as_str()))
            .collect();
        let sites: Vec<String> = witnesses
            .iter()
            .map(|e| format!("{} -> {} ({}:{})", e.held, e.acquired, e.file, e.line))
            .collect();
        let anchor = witnesses[0];
        out.push(Finding {
            rule: "lock-order",
            file: anchor.file.clone(),
            line: anchor.line,
            message: format!(
                "potential deadlock: acquisition cycle over {{{}}}: {}",
                scc.join(", "),
                sites.join(", "),
            ),
            allowed: None,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> (Vec<LockEdge>, Vec<Finding>) {
        let m = SourceModel::build(path, src);
        let mut edges = Vec::new();
        let mut out = Vec::new();
        scan_file(&m, &mut edges, &mut out);
        check_cycles(&edges, &mut out);
        (edges, out)
    }

    #[test]
    fn seeded_two_lock_cycle_is_a_deadlock_finding() {
        let src = concat!(
            "fn ab(s: &S) { let g = lock_recover(&s.alpha); let h = lock_recover(&s.beta); }\n",
            "fn ba(s: &S) { let g = lock_recover(&s.beta); let h = lock_recover(&s.alpha); }\n",
        );
        let (edges, out) = run("src/x.rs", src);
        assert_eq!(edges.len(), 2, "{edges:?}");
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("potential deadlock"), "{}", out[0].message);
    }

    #[test]
    fn consistent_order_has_edges_but_no_cycle() {
        let src = concat!(
            "fn f(s: &S) { let g = lock_recover(&s.alpha); let h = lock_recover(&s.beta); }\n",
            "fn g(s: &S) { let g = lock_recover(&s.alpha); let h = lock_recover(&s.beta); }\n",
        );
        let (edges, out) = run("src/x.rs", src);
        assert_eq!(edges.len(), 2);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn same_class_reentry_is_reported() {
        let src = "fn f(s: &S) { let g = lock_recover(&s.alpha); let h = lock_recover(&s.alpha); }";
        let (_, out) = run("src/x.rs", src);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("re-acquired"), "{}", out[0].message);
    }

    #[test]
    fn temp_guard_ends_with_its_statement() {
        // The plan_for shape: an `if let` scrutinee guard must not be
        // live at the later re-acquisition.
        let src = concat!(
            "fn plan_for(s: &S) {\n",
            "    if let Some(p) = lock_recover(&s.plans).get(&key) {\n",
            "        return p;\n",
            "    }\n",
            "    let mut map = lock_recover(&s.plans);\n",
            "    map.insert(key, v);\n",
            "}\n",
        );
        let (_, out) = run("src/kernels/plan.rs", src);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn dropped_guard_opens_no_edge() {
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let g = lock_recover(&s.alpha);\n",
            "    drop(g);\n",
            "    let h = lock_recover(&s.beta);\n",
            "}\n",
        );
        let (edges, out) = run("src/x.rs", src);
        assert!(edges.is_empty(), "{edges:?}");
        assert!(out.is_empty());
    }

    #[test]
    fn curated_calls_propagate_their_lock_class() {
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let st = lock_recover(&s.state);\n",
            "    s.queue.pop_blocking();\n",
            "}\n",
        );
        let (edges, _) = run("src/coordinator/serving/registry.rs", src);
        assert_eq!(edges.len(), 1);
        assert_eq!(edges[0].held, "registry-state");
        assert_eq!(edges[0].acquired, "queue-state");
    }

    #[test]
    fn allow_annotation_removes_the_edge_from_the_cycle_graph() {
        let src = concat!(
            "fn ab(s: &S) {\n",
            "    let g = lock_recover(&s.alpha);\n",
            "    // analyze: allow(lock-order, reason=\"beta is a leaf here, b never calls a\")\n",
            "    let h = lock_recover(&s.beta);\n",
            "}\n",
            "fn ba(s: &S) { let g = lock_recover(&s.beta); let h = lock_recover(&s.alpha); }\n",
        );
        let (edges, out) = run("src/x.rs", src);
        assert_eq!(edges.len(), 2);
        assert_eq!(edges.iter().filter(|e| e.allowed.is_some()).count(), 1);
        assert!(out.is_empty(), "annotated edge must not close the cycle: {out:?}");
    }
}
