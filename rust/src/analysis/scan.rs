//! Structural scanner: turns a lexed file into the shape the rules consume.
//!
//! Three lightweight structures are extracted from the token stream:
//!  * **function spans** — `fn name … { … }` token ranges, so rules can ask
//!    "which function am I in" (the `lock_recover` exemption, guard scopes);
//!  * **test spans** — token ranges covered by a `#[cfg(test)]` item, so
//!    rules that only govern production code can skip fixtures;
//!  * **allow annotations** — `// analyze: allow(rule, reason="…")` escapes
//!    with their resolved line scope (the next statement or block; the same
//!    line when trailing). A malformed annotation — unknown shape, missing
//!    or empty reason — is itself reported, and can never be suppressed.

use super::lexer::{lex, Comment, Kind, Tok};

/// A function body: `name` plus the inclusive token range of `fn … }`.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// A parsed `analyze: allow(rule, reason="…")` escape covering `lines`.
#[derive(Clone, Debug)]
pub struct Allow {
    pub rule: String,
    pub reason: String,
    pub lines: (u32, u32),
}

/// One file, scanned: tokens plus the structural overlays above.
#[derive(Debug)]
pub struct SourceModel {
    pub path: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    pub fns: Vec<FnSpan>,
    test_spans: Vec<(usize, usize)>,
    allows: Vec<Allow>,
    /// (line, error) for annotations that parsed as `analyze:` but are
    /// malformed — surfaced as unsuppressible findings.
    pub bad_annotations: Vec<(u32, String)>,
}

impl SourceModel {
    pub fn build(path: &str, src: &str) -> SourceModel {
        let lexed = lex(src);
        let toks = lexed.toks;
        let fns = collect_fns(&toks);
        let test_spans = collect_test_spans(&toks);
        let mut allows = Vec::new();
        let mut bad_annotations = Vec::new();
        collect_allows(&toks, &lexed.comments, &mut allows, &mut bad_annotations);
        SourceModel {
            path: path.to_string(),
            toks,
            comments: lexed.comments,
            fns,
            test_spans,
            allows,
            bad_annotations,
        }
    }

    /// Is token `ix` inside a `#[cfg(test)]` item?
    pub fn in_test(&self, ix: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| ix >= s && ix <= e)
    }

    /// Innermost function containing token `ix`.
    pub fn enclosing_fn(&self, ix: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| ix >= f.start && ix <= f.end)
            .min_by_key(|f| f.end - f.start)
    }

    /// The annotation escape covering `rule` at `line`, if any.
    pub fn allow_for(&self, rule: &str, line: u32) -> Option<&Allow> {
        self.allows
            .iter()
            .find(|a| a.rule == rule && line >= a.lines.0 && line <= a.lines.1)
    }

    /// All parsed allows (for reporting).
    pub fn allows(&self) -> &[Allow] {
        &self.allows
    }
}

/// End of the statement (or item) starting at token `start`: the first `;`
/// at the statement's own depth, or the close of a block it heads —
/// continuing through `else` chains and a trailing `;` after a block.
pub fn statement_end(toks: &[Tok], start: usize) -> usize {
    let mut depth = 0i32;
    let mut i = start;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Punct {
            match t.text.as_bytes()[0] {
                b'{' | b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b';' if depth == 0 => return i,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        // Enclosing block closed: the statement was its tail.
                        return i.saturating_sub(1);
                    }
                    if depth == 0 {
                        match toks.get(i + 1) {
                            Some(n) if n.is_ident("else") => {}
                            Some(n) if n.is_punct(';') => return i + 1,
                            Some(n) if n.is_punct('.') || n.is_punct('?') => {}
                            _ => return i,
                        }
                    }
                }
                _ => {}
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

fn collect_fns(toks: &[Tok]) -> Vec<FnSpan> {
    let mut fns = Vec::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("fn") {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        if name_tok.kind != Kind::Ident {
            continue; // `fn(...)` pointer type
        }
        // Body: first `{` at paren depth 0; a `;` first means a declaration.
        let mut depth = 0i32;
        let mut j = i + 2;
        let mut body_start = None;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == Kind::Punct {
                match t.text.as_bytes()[0] {
                    b'(' | b'[' => depth += 1,
                    b')' | b']' => depth -= 1,
                    b'{' if depth == 0 => {
                        body_start = Some(j);
                        break;
                    }
                    b';' if depth == 0 => break,
                    _ => {}
                }
            }
            j += 1;
        }
        let Some(open) = body_start else { continue };
        let close = matching_close(toks, open);
        fns.push(FnSpan {
            name: name_tok.text.clone(),
            start: i,
            end: close,
        });
    }
    fns
}

/// Index of the `}` matching the `{` at `open` (or the last token).
pub fn matching_close(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.kind == Kind::Punct {
            match t.text.as_bytes()[0] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len().saturating_sub(1)
}

fn collect_test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let mut i = 0;
    while i + 1 < toks.len() {
        if !(toks[i].is_punct('#') && toks[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        // Attribute content up to the matching `]`.
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut has_cfg = false;
        let mut has_test = false;
        let mut has_not = false;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.is_ident("cfg") {
                has_cfg = true;
            } else if t.is_ident("test") {
                has_test = true;
            } else if t.is_ident("not") {
                has_not = true;
            }
            j += 1;
        }
        if !(has_cfg && has_test && !has_not) {
            i = j + 1;
            continue;
        }
        // Skip any further attributes, then take the item's full extent.
        let mut k = j + 1;
        while k + 1 < toks.len() && toks[k].is_punct('#') && toks[k + 1].is_punct('[') {
            let mut d = 0i32;
            while k < toks.len() {
                if toks[k].is_punct('[') {
                    d += 1;
                } else if toks[k].is_punct(']') {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                k += 1;
            }
            k += 1;
        }
        let end = statement_end(toks, k);
        spans.push((i, end));
        i = j + 1; // nested #[cfg(test)] under a test mod is subsumed
    }
    spans
}

fn collect_allows(
    toks: &[Tok],
    comments: &[Comment],
    allows: &mut Vec<Allow>,
    bad: &mut Vec<(u32, String)>,
) {
    for c in comments {
        let body = c
            .text
            .trim_start_matches('/')
            .trim_start_matches('*')
            .trim_end_matches('/')
            .trim_end_matches('*')
            .trim();
        let Some(directive) = body.strip_prefix("analyze:") else {
            continue;
        };
        match parse_allow(directive.trim()) {
            Ok((rule, reason)) => {
                let lines = if c.trailing {
                    (c.line, c.line)
                } else {
                    match toks.iter().position(|t| t.line > c.line) {
                        Some(first) => {
                            let end = statement_end(toks, first);
                            (c.line, toks[end].line)
                        }
                        None => (c.line, c.line),
                    }
                };
                allows.push(Allow { rule, reason, lines });
            }
            Err(e) => bad.push((c.line, e)),
        }
    }
}

/// Parse `allow(rule, reason="…")`. The reason is mandatory and non-empty:
/// an escape without a recorded justification is a finding, not a waiver.
fn parse_allow(s: &str) -> Result<(String, String), String> {
    let inner = s
        .strip_prefix("allow(")
        .and_then(|r| r.strip_suffix(')'))
        .ok_or_else(|| {
            format!("malformed analyze directive '{s}' (want allow(rule, reason=\"…\"))")
        })?;
    let (rule, rest) = inner
        .split_once(',')
        .ok_or_else(|| "allow() is missing the mandatory reason=\"…\"".to_string())?;
    let rule = rule.trim();
    if rule.is_empty() || !rule.bytes().all(|b| b.is_ascii_lowercase() || b == b'-') {
        return Err(format!("'{rule}' is not a rule name (kebab-case)"));
    }
    let reason = rest
        .trim()
        .strip_prefix("reason=")
        .map(str::trim)
        .and_then(|r| r.strip_prefix('"'))
        .and_then(|r| r.strip_suffix('"'))
        .ok_or_else(|| "allow() reason must be reason=\"…\"".to_string())?;
    if reason.trim().is_empty() {
        return Err("allow() reason must not be empty".to_string());
    }
    Ok((rule.to_string(), reason.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_spans_and_enclosing() {
        let m = SourceModel::build(
            "x.rs",
            "fn outer() { let f = |x: u32| x; inner(); }\nfn inner() {}\n",
        );
        assert_eq!(m.fns.len(), 2);
        let ix = m.toks.iter().position(|t| t.is_ident("inner")).unwrap();
        assert_eq!(m.enclosing_fn(ix).unwrap().name, "outer");
    }

    #[test]
    fn cfg_test_spans_cover_the_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n fn t() { x.lock().unwrap(); }\n}\n";
        let m = SourceModel::build("x.rs", src);
        let unwrap_ix = m.toks.iter().position(|t| t.is_ident("unwrap")).unwrap();
        let live_ix = m.toks.iter().position(|t| t.is_ident("live")).unwrap();
        assert!(m.in_test(unwrap_ix));
        assert!(!m.in_test(live_ix));
    }

    #[test]
    fn allow_scope_covers_next_statement_and_blocks() {
        let src = "\
fn f(v: &[f32]) -> f32 {
    // analyze: allow(panic-freedom, reason=\"indices bounded by caller\")
    for i in 0..4 {
        let _ = v[i];
    }
    v[9]
}
";
        let m = SourceModel::build("x.rs", src);
        assert_eq!(m.allows().len(), 1);
        let a = &m.allows()[0];
        assert_eq!(a.rule, "panic-freedom");
        assert_eq!(a.lines, (2, 5), "covers the whole for block: {a:?}");
        assert!(m.allow_for("panic-freedom", 4).is_some());
        assert!(m.allow_for("panic-freedom", 6).is_none(), "v[9] is outside");
        assert!(m.allow_for("lock-discipline", 4).is_none(), "other rules unaffected");
    }

    #[test]
    fn trailing_allow_covers_only_its_line() {
        let src = concat!(
            "fn f() {\n",
            "    x.lock().unwrap(); // analyze: allow(lock-discipline, reason=\"pt\")\n",
            "    y.lock().unwrap();\n",
            "}\n",
        );
        let m = SourceModel::build("x.rs", src);
        assert!(m.allow_for("lock-discipline", 2).is_some());
        assert!(m.allow_for("lock-discipline", 3).is_none());
    }

    #[test]
    fn malformed_allows_are_reported() {
        for bad in [
            "// analyze: allow(panic-freedom)",
            "// analyze: allow(panic-freedom, reason=\"\")",
            "// analyze: allow(Panic, reason=\"x\")",
            "// analyze: deny(panic-freedom)",
        ] {
            let m = SourceModel::build("x.rs", &format!("{bad}\nfn f() {{}}\n"));
            assert_eq!(m.bad_annotations.len(), 1, "{bad}");
            assert!(m.allows().is_empty(), "{bad}");
        }
    }
}
