//! The four token-pattern rules: lock-discipline, panic-freedom,
//! atomic-ordering and unsafe-inventory. (lock-order, which needs guard
//! scopes and a cross-file graph, lives in [`super::lockorder`].)

use super::report::{Finding, UnsafeSite};
use super::scan::SourceModel;
use crate::analysis::lexer::Kind;

/// Build a finding, resolving any covering `analyze: allow` escape.
pub(crate) fn finding(m: &SourceModel, rule: &'static str, line: u32, message: String) -> Finding {
    Finding {
        rule,
        file: m.path.clone(),
        line,
        message,
        allowed: m.allow_for(rule, line).map(|a| a.reason.clone()),
    }
}

/// Index of the `)` matching the `(` at `open` (or the last token).
pub(crate) fn matching_paren(toks: &[crate::analysis::lexer::Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// **lock-discipline** — bare `.lock().unwrap()` / `.lock().expect(…)` is
/// banned everywhere (tests included: a poisoned fixture mutex aborts the
/// whole suite instead of the one test). The only exemption is the body of
/// `lock_recover` itself, which is the blessed wrapper.
pub fn lock_discipline(m: &SourceModel, out: &mut Vec<Finding>) {
    let toks = &m.toks;
    for i in 0..toks.len() {
        if !toks[i].is_ident("lock") {
            continue;
        }
        let dotted = i > 0 && toks[i - 1].is_punct('.');
        let called = toks.get(i + 1).is_some_and(|t| t.is_punct('('))
            && toks.get(i + 2).is_some_and(|t| t.is_punct(')'));
        if !(dotted && called) {
            continue;
        }
        let Some(next) = toks.get(i + 4) else { continue };
        if !(toks[i + 3].is_punct('.') && (next.is_ident("unwrap") || next.is_ident("expect"))) {
            continue;
        }
        if !toks.get(i + 5).is_some_and(|t| t.is_punct('(')) {
            continue;
        }
        if m.enclosing_fn(i).is_some_and(|f| f.name == "lock_recover") {
            continue;
        }
        out.push(finding(
            m,
            "lock-discipline",
            toks[i].line,
            format!(
                "bare `.lock().{}()` — route through `util::lock_recover` so a \
                 poisoned mutex degrades instead of cascading panics",
                next.text
            ),
        ));
    }
}

/// Hot-path modules governed by panic-freedom (path suffix match).
const HOT_MODULES: [&str; 9] = [
    "serving/queue.rs",
    "serving/worker.rs",
    "serving/registry.rs",
    "serving/backend.rs",
    "kernels/plan.rs",
    "kernels/registry.rs",
    "frontend/mod.rs",
    "frontend/protocol.rs",
    "frontend/conn.rs",
];

/// Keywords that can legally precede `[` without it being an index
/// expression (`&mut [f32]`, `let [a, b] = …`, `dyn [T]`-ish positions).
const KEYWORDS: [&str; 33] = [
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub",
    "ref", "return", "static", "struct", "trait", "type", "unsafe", "use", "where",
];

/// **panic-freedom** — no `unwrap`/`expect`/`panic!`/`unreachable!` or
/// unchecked indexing in the designated hot-path modules. `#[cfg(test)]`
/// spans are exempt: a test asserting its own fixture may panic.
pub fn panic_freedom(m: &SourceModel, out: &mut Vec<Finding>) {
    if !HOT_MODULES.iter().any(|s| m.path.ends_with(s)) {
        return;
    }
    let toks = &m.toks;
    for i in 0..toks.len() {
        if m.in_test(i) {
            continue;
        }
        let t = &toks[i];
        let msg = if (t.is_ident("unwrap") || t.is_ident("expect"))
            && i > 0
            && toks[i - 1].is_punct('.')
            && toks.get(i + 1).is_some_and(|n| n.is_punct('('))
        {
            format!("`.{}()` in a hot-path module — return an error instead", t.text)
        } else if (t.is_ident("panic") || t.is_ident("unreachable"))
            && toks.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            format!("`{}!` in a hot-path module — return an error instead", t.text)
        } else if t.is_punct('[')
            && i > 0
            && (toks[i - 1].is_punct(')')
                || toks[i - 1].is_punct(']')
                || (toks[i - 1].kind == Kind::Ident
                    && !KEYWORDS.contains(&toks[i - 1].text.as_str())))
        {
            "unchecked indexing in a hot-path module — use `get`/iterators or \
             annotate the bounds argument"
                .to_string()
        } else {
            continue;
        };
        out.push(finding(m, "panic-freedom", t.line, msg));
    }
}

const ATOMIC_METHODS: [&str; 15] = [
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_nand",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
    "compare_and_swap",
];

const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

struct AtomicOp {
    field: String,
    method: String,
    line: u32,
    orderings: Vec<String>,
    discarded: bool,
}

fn collect_atomic_ops(m: &SourceModel) -> Vec<AtomicOp> {
    let toks = &m.toks;
    let mut ops = Vec::new();
    for i in 0..toks.len() {
        if m.in_test(i) {
            continue;
        }
        let t = &toks[i];
        if t.kind != Kind::Ident || !ATOMIC_METHODS.contains(&t.text.as_str()) {
            continue;
        }
        if !(i > 0 && toks[i - 1].is_punct('.') && i > 1 && toks[i - 2].kind == Kind::Ident) {
            continue;
        }
        if !toks.get(i + 1).is_some_and(|n| n.is_punct('(')) {
            continue;
        }
        let close = matching_paren(toks, i + 1);
        let orderings: Vec<String> = toks[i + 1..close]
            .iter()
            .filter(|a| a.kind == Kind::Ident && ORDERINGS.contains(&a.text.as_str()))
            .map(|a| a.text.clone())
            .collect();
        if orderings.is_empty() {
            continue; // `.load(path)` on a non-atomic: not ours
        }
        ops.push(AtomicOp {
            field: toks[i - 2].text.clone(),
            method: t.text.clone(),
            line: t.line,
            orderings,
            discarded: toks.get(close + 1).is_some_and(|n| n.is_punct(';')),
        });
    }
    ops
}

/// **atomic-ordering** — three checks over the per-file atomic ops:
///  1. `SeqCst` anywhere is flagged: nothing in this crate needs a total
///     order, and SeqCst hides the author's actual intent.
///  2. A *pure counter* — only `fetch_*` ops whose results are discarded,
///     never stored/swapped/CAS'd, and only ever loaded `Relaxed` — must
///     use `Relaxed` throughout. (An `Acquire` load reclassifies the
///     field as an RMW-publish handoff, e.g. the registry's epochs.)
///  3. A *handoff* field must pair a releasing write (Release/AcqRel
///     store or RMW) with Acquire loads; a one-sided or Relaxed/Relaxed
///     pair is flagged with both sites.
pub fn atomic_ordering(m: &SourceModel, out: &mut Vec<Finding>) {
    let ops = collect_atomic_ops(m);
    for op in &ops {
        if op.orderings.iter().any(|o| o == "SeqCst") {
            out.push(finding(
                m,
                "atomic-ordering",
                op.line,
                format!(
                    "`SeqCst` on `{}` — use the weakest correct ordering \
                     (Relaxed for counters, Acquire/Release for handoff)",
                    op.field
                ),
            ));
        }
    }
    let mut fields: Vec<&String> = ops.iter().map(|o| &o.field).collect();
    fields.sort();
    fields.dedup();
    for field in fields {
        let fo: Vec<&AtomicOp> = ops.iter().filter(|o| &o.field == field).collect();
        let strong = |o: &AtomicOp, want: &str| {
            o.orderings.iter().any(|x| x == want || x == "AcqRel" || x == "SeqCst")
        };
        let fetches: Vec<&&AtomicOp> =
            fo.iter().filter(|o| o.method.starts_with("fetch_")).collect();
        let stores: Vec<&&AtomicOp> = fo.iter().filter(|o| o.method == "store").collect();
        let loads: Vec<&&AtomicOp> = fo.iter().filter(|o| o.method == "load").collect();
        let cas = fo.iter().any(|o| {
            matches!(
                o.method.as_str(),
                "swap" | "compare_exchange" | "compare_exchange_weak" | "compare_and_swap"
            )
        });
        let acq_load = loads.iter().any(|o| strong(o, "Acquire"));
        let rel_write = fo
            .iter()
            .filter(|o| o.method != "load")
            .any(|o| strong(o, "Release"));
        if !fetches.is_empty()
            && fetches.iter().all(|o| o.discarded)
            && stores.is_empty()
            && !cas
            && !acq_load
        {
            // Pure counter: every op, loads included, must be Relaxed.
            for o in &fo {
                if o.orderings.iter().any(|x| x != "Relaxed" && x != "SeqCst") {
                    out.push(finding(
                        m,
                        "atomic-ordering",
                        o.line,
                        format!(
                            "monotonic counter `{field}` uses `{}` — counters \
                             synchronize nothing; use Relaxed",
                            o.orderings.join("/"),
                        ),
                    ));
                }
            }
            continue;
        }
        let writes_exist = !stores.is_empty() || !fetches.is_empty() || cas;
        if acq_load && writes_exist && !rel_write {
            let load = loads.iter().find(|o| strong(o, "Acquire")).unwrap_or(&loads[0]);
            let write = fo.iter().find(|o| o.method != "load").map_or(0, |o| o.line);
            out.push(finding(
                m,
                "atomic-ordering",
                load.line,
                format!(
                    "`{field}` is loaded with Acquire (line {}) but no write \
                     releases it (e.g. line {write}) — the pair publishes nothing",
                    load.line,
                ),
            ));
            continue;
        }
        if stores.is_empty() || loads.is_empty() {
            continue;
        }
        if !rel_write && !acq_load {
            out.push(finding(
                m,
                "atomic-ordering",
                loads[0].line,
                format!(
                    "store/load pair on `{field}` is Relaxed on both sides \
                     (store line {}, load line {}) — a cross-thread handoff \
                     needs Release/Acquire",
                    stores[0].line, loads[0].line,
                ),
            ));
        } else if rel_write && !acq_load {
            let write = fo
                .iter()
                .find(|o| o.method != "load" && strong(o, "Release"))
                .map_or(stores[0].line, |o| o.line);
            out.push(finding(
                m,
                "atomic-ordering",
                loads[0].line,
                format!(
                    "`{field}` is written with Release (line {write}) but loaded \
                     Relaxed (line {}) — the pair publishes nothing",
                    loads[0].line,
                ),
            ));
        }
    }
}

/// **unsafe-inventory** — every `unsafe` site needs a `// SAFETY:` line
/// comment immediately above (or trailing on the same line), and all sites
/// are exported into the machine-readable report whether justified or not.
pub fn unsafe_inventory(m: &SourceModel, out: &mut Vec<Finding>, inv: &mut Vec<UnsafeSite>) {
    let toks = &m.toks;
    for i in 0..toks.len() {
        if !toks[i].is_ident("unsafe") {
            continue;
        }
        let kind = match toks.get(i + 1) {
            Some(n) if n.is_ident("impl") => "unsafe impl",
            Some(n) if n.is_ident("fn") => "unsafe fn",
            Some(n) if n.is_ident("trait") => "unsafe trait",
            Some(n) if n.is_punct('{') => "unsafe block",
            _ => "unsafe",
        };
        let line = toks[i].line;
        let safety = safety_comment(m, line);
        if safety.is_none() {
            out.push(finding(
                m,
                "unsafe-inventory",
                line,
                format!("{kind} without an adjacent `// SAFETY:` justification"),
            ));
        }
        inv.push(UnsafeSite {
            file: m.path.clone(),
            line,
            kind,
            safety,
        });
    }
}

/// The `// SAFETY:` text covering an unsafe site at `line`: a trailing
/// comment on the line itself, or the comment block directly above (walked
/// upward through contiguous own-line comments, so a multi-line
/// justification starting with `SAFETY:` counts).
fn safety_comment(m: &SourceModel, line: u32) -> Option<String> {
    let grab = |text: &str| {
        let at = text.find("SAFETY:")?;
        Some(text[at + "SAFETY:".len()..].trim().to_string())
    };
    if let Some(c) = m.comments.iter().find(|c| c.line == line && c.text.contains("SAFETY:")) {
        return grab(&c.text);
    }
    let mut l = line.saturating_sub(1);
    while l > 0 {
        let on_line: Vec<_> = m.comments.iter().filter(|c| c.line == l && !c.trailing).collect();
        if on_line.is_empty() {
            return None; // code or blank: the comment block (if any) ended
        }
        if let Some(c) = on_line.iter().find(|c| c.text.contains("SAFETY:")) {
            return grab(&c.text);
        }
        l -= 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str, rule: fn(&SourceModel, &mut Vec<Finding>)) -> Vec<Finding> {
        let m = SourceModel::build("src/coordinator/serving/queue.rs", src);
        let mut out = Vec::new();
        rule(&m, &mut out);
        out
    }

    #[test]
    fn lock_discipline_fires_and_clears() {
        let bad = "fn f(m: &std::sync::Mutex<u32>) { let _ = m.lock().unwrap(); }";
        let got = run(bad, lock_discipline);
        assert_eq!(got.len(), 1);
        assert!(got[0].allowed.is_none());
        let bad2 = "fn f(m: &std::sync::Mutex<u32>) { let _ = m.lock().expect(\"x\"); }";
        assert_eq!(run(bad2, lock_discipline).len(), 1);
        let fixed = "fn f(m: &std::sync::Mutex<u32>) { let _ = lock_recover(m); }";
        assert!(run(fixed, lock_discipline).is_empty());
        // The blessed wrapper itself is exempt.
        let wrapper = concat!(
            "fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {\n",
            "    m.lock().unwrap()\n",
            "}\n",
        );
        assert!(run(wrapper, lock_discipline).is_empty());
    }

    #[test]
    fn lock_discipline_allow_escape() {
        let src = concat!(
            "fn f(m: &std::sync::Mutex<u32>) {\n",
            "    // analyze: allow(lock-discipline, reason=\"poison fixture\")\n",
            "    let _ = m.lock().unwrap();\n",
            "}\n",
        );
        let got = run(src, lock_discipline);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].allowed.as_deref(), Some("poison fixture"));
    }

    #[test]
    fn panic_freedom_fires_on_each_shape() {
        let src = concat!(
            "fn f(v: &[f32], o: Option<u32>) -> f32 {\n",
            "    let _a = o.unwrap();\n",
            "    let _b = o.expect(\"x\");\n",
            "    if v.is_empty() { panic!(\"empty\"); }\n",
            "    v[0]\n",
            "}\n",
        );
        let got = run(src, panic_freedom);
        assert_eq!(got.len(), 4, "{got:?}");
    }

    #[test]
    fn panic_freedom_ignores_types_tests_and_cold_modules() {
        let src = concat!(
            "fn f(v: &mut [f32]) -> Option<f32> { v.first().copied() }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t(v: &[f32]) -> f32 { v[0] }\n",
            "}\n",
        );
        assert!(run(src, panic_freedom).is_empty());
        // Same violating code in a non-hot module: out of scope.
        let m = SourceModel::build("src/formats.rs", "fn f(v: &[f32]) -> f32 { v[0] }");
        let mut out = Vec::new();
        panic_freedom(&m, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn atomic_ordering_flags_seqcst_and_counter_misuse() {
        let src = concat!(
            "fn f(c: &Ctrs) {\n",
            "    c.hits.fetch_add(1, Ordering::SeqCst);\n",
            "    c.misses.fetch_add(1, Ordering::Acquire);\n",
            "    c.good.fetch_add(1, Ordering::Relaxed);\n",
            "}\n",
        );
        let got = run(src, atomic_ordering);
        assert_eq!(got.len(), 2, "{got:?}");
        assert!(got.iter().any(|f| f.message.contains("SeqCst")));
        assert!(got.iter().any(|f| f.message.contains("monotonic counter")));
    }

    #[test]
    fn atomic_ordering_flags_relaxed_handoff_pairs() {
        let bad = concat!(
            "fn publish(s: &S) { s.ready.store(true, Ordering::Relaxed); }\n",
            "fn consume(s: &S) -> bool { s.ready.load(Ordering::Relaxed) }\n",
        );
        let got = run(bad, atomic_ordering);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("Relaxed on both sides"));
        let one_sided = concat!(
            "fn publish(s: &S) { s.ready.store(true, Ordering::Release); }\n",
            "fn consume(s: &S) -> bool { s.ready.load(Ordering::Relaxed) }\n",
        );
        assert_eq!(run(one_sided, atomic_ordering).len(), 1);
        let fixed = concat!(
            "fn publish(s: &S) { s.ready.store(true, Ordering::Release); }\n",
            "fn consume(s: &S) -> bool { s.ready.load(Ordering::Acquire) }\n",
        );
        assert!(run(fixed, atomic_ordering).is_empty());
    }

    #[test]
    fn atomic_ordering_treats_acquire_loaded_epochs_as_handoffs() {
        // The registry epoch shape: discarded fetch_add + Acquire load is
        // an RMW publish, not a counter — AcqRel bumps are correct…
        let good = concat!(
            "fn bump(s: &S) { s.epoch.fetch_add(1, Ordering::AcqRel); }\n",
            "fn read(s: &S) -> usize { s.epoch.load(Ordering::Acquire) }\n",
        );
        assert!(run(good, atomic_ordering).is_empty());
        // …but a Relaxed bump under an Acquire load publishes nothing.
        let bad = concat!(
            "fn bump(s: &S) { s.epoch.fetch_add(1, Ordering::Relaxed); }\n",
            "fn read(s: &S) -> usize { s.epoch.load(Ordering::Acquire) }\n",
        );
        let got = run(bad, atomic_ordering);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("no write"), "{}", got[0].message);
    }

    #[test]
    fn atomic_ordering_accepts_rmw_handoff_and_used_results() {
        // `fetch_sub(..) == 1` with AcqRel is the drain handoff: the result
        // is used, so the field is not a "pure counter".
        let src = concat!(
            "fn drop_claim(e: &E) {\n",
            "    if e.in_flight.fetch_sub(1, Ordering::AcqRel) == 1 { e.notify(); }\n",
            "}\n",
            "fn wait(e: &E) -> bool { e.in_flight.load(Ordering::Acquire) == 0 }\n",
        );
        assert!(run(src, atomic_ordering).is_empty());
    }

    #[test]
    fn unsafe_inventory_requires_safety_comment() {
        let bad = "fn f(p: *mut f32) { let _ = unsafe { *p }; }";
        let m = SourceModel::build("x.rs", bad);
        let (mut out, mut inv) = (Vec::new(), Vec::new());
        unsafe_inventory(&m, &mut out, &mut inv);
        assert_eq!(out.len(), 1);
        assert_eq!(inv.len(), 1);
        assert!(inv[0].safety.is_none());

        let good = concat!(
            "fn f(p: *mut f32) {\n",
            "    // SAFETY: p is valid for writes; caller guarantees it.\n",
            "    // (second justification line)\n",
            "    let _ = unsafe { *p };\n",
            "}\n",
            "// SAFETY: no shared mutation.\n",
            "unsafe impl Sync for W {}\n",
        );
        let m = SourceModel::build("x.rs", good);
        let (mut out, mut inv) = (Vec::new(), Vec::new());
        unsafe_inventory(&m, &mut out, &mut inv);
        assert!(out.is_empty(), "{out:?}");
        assert_eq!(inv.len(), 2);
        assert_eq!(inv[1].kind, "unsafe impl");
        assert!(inv[0].safety.as_deref().unwrap().starts_with("p is valid"));
    }
}
