//! Hand-rolled Rust lexer for the static-analysis pass.
//!
//! Produces a flat significant-token stream (identifiers, literals,
//! lifetimes, single-char punctuation) plus a side channel of comments with
//! line numbers. The rules only need token shapes and adjacency, so there is
//! no keyword table and no precedence here — but string/char/comment
//! recognition is exact (raw strings, nested block comments, byte literals),
//! because a `.lock().unwrap()` inside a fixture string literal must *not*
//! look like code.

/// Significant-token kind. Punctuation is one token per character; the
/// rules match multi-character operators by adjacency when they need to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Lit,
    Lifetime,
    Punct,
}

/// One significant token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }

    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == Kind::Ident && self.text == name
    }
}

/// One comment (line or block), with `trailing` true when code precedes it
/// on its starting line — that decides the scope of an `analyze: allow`.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub trailing: bool,
}

/// Lexer output: significant tokens plus comments, both in source order.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || c >= 0x80
}

fn ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_' || c >= 0x80
}

fn count_newlines(s: &str) -> u32 {
    s.bytes().filter(|&c| c == b'\n').count() as u32
}

/// Lex `src` into significant tokens + comments. Never fails: unterminated
/// constructs run to end-of-file (the pass lints source that `rustc`
/// already accepted, so this is only reachable on truncated input).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut line_has_code = false;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            out.comments.push(Comment {
                line,
                text: src[start..i].to_string(),
                trailing: line_has_code,
            });
            continue;
        }
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let (start, start_line) = (i, line);
            let mut depth = 1u32;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            out.comments.push(Comment {
                line: start_line,
                text: src[start..i].to_string(),
                trailing: line_has_code,
            });
            continue;
        }
        line_has_code = true;
        // Raw strings / raw identifiers / byte literals share prefixes.
        if c == b'r' || c == b'b' {
            if let Some(end) = raw_or_byte_literal(b, i) {
                out.toks.push(Tok {
                    kind: Kind::Lit,
                    text: src[i..end].to_string(),
                    line,
                });
                line += count_newlines(&src[i..end]);
                i = end;
                continue;
            }
            let raw_ident = c == b'r'
                && i + 1 < b.len()
                && b[i + 1] == b'#'
                && b.get(i + 2).is_some_and(|&c| ident_start(c));
            if raw_ident {
                // Raw identifier `r#type`: lex the ident, drop the sigil.
                let mut j = i + 2;
                while j < b.len() && ident_cont(b[j]) {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: Kind::Ident,
                    text: src[i + 2..j].to_string(),
                    line,
                });
                i = j;
                continue;
            }
        }
        if c == b'"' {
            let end = string_end(b, i + 1);
            out.toks.push(Tok {
                kind: Kind::Lit,
                text: src[i..end].to_string(),
                line,
            });
            line += count_newlines(&src[i..end]);
            i = end;
            continue;
        }
        if c == b'\'' {
            let (end, kind) = char_or_lifetime(b, i);
            out.toks.push(Tok {
                kind,
                text: src[i..end].to_string(),
                line,
            });
            i = end;
            continue;
        }
        if ident_start(c) {
            let mut j = i + 1;
            while j < b.len() && ident_cont(b[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: Kind::Ident,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < b.len() {
                let frac = b[j] == b'.' && b.get(j + 1).is_some_and(u8::is_ascii_digit);
                if !ident_cont(b[j]) && !frac {
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok {
                kind: Kind::Lit,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        out.toks.push(Tok {
            kind: Kind::Punct,
            text: (c as char).to_string(),
            line,
        });
        i += 1;
    }
    out
}

/// Find the byte index just past a string body starting at `i` (after the
/// opening quote), honoring backslash escapes.
fn string_end(b: &[u8], mut i: usize) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    b.len()
}

/// Recognize `r"…"`, `r#"…"#…`, `b"…"`, `br#"…"#`, `b'…'` starting at `i`
/// (which holds `r` or `b`). Returns the end index, or None when the
/// prefix is just an ordinary identifier start.
fn raw_or_byte_literal(b: &[u8], i: usize) -> Option<usize> {
    let rest = &b[i..];
    let (raw, body) = match rest {
        [b'r', b'"', ..] => (0usize, i + 2),
        [b'r', b'#', ..] => {
            let mut n = 0;
            while i + 1 + n < b.len() && b[i + 1 + n] == b'#' {
                n += 1;
            }
            if b.get(i + 1 + n) != Some(&b'"') {
                return None; // raw identifier, not a raw string
            }
            (n, i + 2 + n)
        }
        [b'b', b'"', ..] => return Some(string_end(b, i + 2)),
        [b'b', b'\'', ..] => {
            let (end, _) = char_or_lifetime(b, i + 1);
            return Some(end);
        }
        [b'b', b'r', b'"', ..] | [b'b', b'r', b'#', ..] => {
            let mut n = 0;
            while i + 2 + n < b.len() && b[i + 2 + n] == b'#' {
                n += 1;
            }
            if b.get(i + 2 + n) != Some(&b'"') {
                return None;
            }
            (n, i + 3 + n)
        }
        _ => return None,
    };
    // Scan for `"` followed by `raw` hashes.
    let mut j = body;
    while j < b.len() {
        if b[j] == b'"' && b[j + 1..].iter().take_while(|&&c| c == b'#').count() >= raw {
            return Some(j + 1 + raw);
        }
        j += 1;
    }
    Some(b.len())
}

/// Disambiguate `'a` / `'static` (lifetimes) from `'x'` / `'\n'` (char
/// literals), starting at the `'` at `i`. Returns (end index, kind).
fn char_or_lifetime(b: &[u8], i: usize) -> (usize, Kind) {
    if i + 1 >= b.len() {
        return (b.len(), Kind::Punct);
    }
    if b[i + 1] == b'\\' {
        // Escaped char literal: skip the escape, then run to the close.
        let mut j = i + 2;
        while j < b.len() && b[j] != b'\'' {
            j += 1;
        }
        return ((j + 1).min(b.len()), Kind::Lit);
    }
    if ident_start(b[i + 1]) {
        let mut j = i + 1;
        while j < b.len() && ident_cont(b[j]) {
            j += 1;
        }
        if b.get(j) == Some(&b'\'') {
            return (j + 1, Kind::Lit); // 'a'
        }
        return (j, Kind::Lifetime); // 'a or 'static
    }
    // Non-ident char literal like '(' or '0'… find the closing quote.
    let mut j = i + 1;
    while j < b.len() && b[j] != b'\'' {
        j += 1;
    }
    ((j + 1).min(b.len()), Kind::Lit)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn code_inside_strings_and_comments_is_not_tokenized() {
        let src = r##"
            let a = "x.lock().unwrap()"; // m.lock().unwrap() in a comment
            /* nested /* block */ .lock().unwrap() */
            let b = r#"raw .lock().unwrap() body"#;
        "##;
        let toks = texts(src);
        assert!(!toks.iter().any(|t| t == "unwrap"), "{toks:?}");
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 2);
        assert!(lexed.comments[0].trailing);
        assert!(!lexed.comments[1].trailing);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'y' }");
        let lifes: Vec<_> = toks.toks.iter().filter(|t| t.kind == Kind::Lifetime).collect();
        assert_eq!(lifes.len(), 2);
        assert!(toks.toks.iter().any(|t| t.kind == Kind::Lit && t.text == "'y'"));
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let src = "let s = \"a\nb\";\nx.lock()";
        let toks = lex(src).toks;
        let lock = toks.iter().find(|t| t.is_ident("lock")).unwrap();
        assert_eq!(lock.line, 3);
    }

    #[test]
    fn raw_idents_and_byte_literals() {
        let toks = texts("let r#type = b'x'; let s = br#\"hi\"#;");
        assert!(toks.contains(&"type".to_string()));
        assert!(toks.contains(&"b'x'".to_string()));
    }
}
