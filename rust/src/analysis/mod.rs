//! `rbgp analyze` — a self-contained static-analysis pass over this
//! crate's own sources, enforcing the serving core's concurrency
//! invariants as machine-checked rules instead of ARCHITECTURE.md prose.
//!
//! Five rules (see [`RULES`]): **lock-discipline** (all mutex access goes
//! through `util::lock_recover`), **lock-order** (the static acquisition
//! graph over named locks must be acyclic), **panic-freedom** (no
//! panicking constructs in the hot-path modules), **atomic-ordering**
//! (counters Relaxed, handoffs Release/Acquire, no SeqCst) and
//! **unsafe-inventory** (every `unsafe` carries a `// SAFETY:` argument,
//! and all sites are exported to `analysis_report.json`).
//!
//! Any finding can be waived in place with
//! `// analyze: allow(rule, reason="…")` — the reason is mandatory, the
//! waiver scope is the next statement/block (or the same line when the
//! comment trails code), and waived findings stay visible in the report.
//! `--deny RULE` turns waivers for one rule back into failures.
//!
//! Everything here is hand-rolled over a small lexer — no new crate
//! dependencies, consistent with the vendored-offline build.

pub mod lexer;
pub mod lockorder;
pub mod report;
pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use anyhow::Context;

pub use report::{Finding, Report};
use scan::SourceModel;

/// Rule names accepted by `--deny` and `allow(…)`. `annotation` is the
/// meta-rule for malformed or unknown escapes and is never suppressible.
pub const RULES: [&str; 6] = [
    "lock-discipline",
    "lock-order",
    "panic-freedom",
    "atomic-ordering",
    "unsafe-inventory",
    "annotation",
];

pub struct AnalysisOptions {
    pub roots: Vec<PathBuf>,
    /// Rules whose `allow` annotations are ignored (`all` for every rule).
    pub deny: Vec<String>,
}

/// The default scan roots: `src`/`benches`/`tests` under the current
/// directory, or under `rust/` when invoked from the repo root.
pub fn default_roots() -> Vec<PathBuf> {
    for prefix in ["", "rust"] {
        let roots: Vec<PathBuf> = ["src", "benches", "tests"]
            .iter()
            .map(|d| Path::new(prefix).join(d))
            .filter(|p| p.is_dir())
            .collect();
        if !roots.is_empty() {
            return roots;
        }
    }
    Vec::new()
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if dir.is_file() {
        files.push(dir.to_path_buf());
        return Ok(());
    }
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, files)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    Ok(())
}

/// Run the full pass over `opts.roots` (files and/or directories).
pub fn analyze_tree(opts: &AnalysisOptions) -> anyhow::Result<Report> {
    anyhow::ensure!(
        !opts.roots.is_empty(),
        "no scan roots: run from the repo (src/benches/tests) or pass paths"
    );
    let mut files = Vec::new();
    for root in &opts.roots {
        walk(root, &mut files)?;
    }
    files.sort();
    files.dedup();
    analyze_files(&files)
}

/// Run the full pass over an explicit, pre-sorted file list.
pub fn analyze_files(paths: &[PathBuf]) -> anyhow::Result<Report> {
    let mut report = Report::default();
    for p in paths {
        let src =
            std::fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))?;
        let shown = p.to_string_lossy().replace('\\', "/");
        let m = SourceModel::build(&shown, &src);
        analyze_model(&m, &mut report);
    }
    lockorder::check_cycles(&report.lock_edges, &mut report.findings);
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

/// All per-file rules over one scanned source model.
fn analyze_model(m: &SourceModel, report: &mut Report) {
    rules::lock_discipline(m, &mut report.findings);
    rules::panic_freedom(m, &mut report.findings);
    rules::atomic_ordering(m, &mut report.findings);
    rules::unsafe_inventory(m, &mut report.findings, &mut report.unsafe_inventory);
    lockorder::scan_file(m, &mut report.lock_edges, &mut report.findings);
    for (line, err) in &m.bad_annotations {
        report.findings.push(Finding {
            rule: "annotation",
            file: m.path.clone(),
            line: *line,
            message: err.clone(),
            allowed: None,
        });
    }
    for a in m.allows() {
        if !RULES.contains(&a.rule.as_str()) || a.rule == "annotation" {
            report.findings.push(Finding {
                rule: "annotation",
                file: m.path.clone(),
                line: a.lines.0,
                message: format!("allow() names unknown rule '{}'", a.rule),
                allowed: None,
            });
        }
    }
    report.files_scanned += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analyze_src(path: &str, src: &str) -> Report {
        let mut report = Report::default();
        let m = SourceModel::build(path, src);
        analyze_model(&m, &mut report);
        lockorder::check_cycles(&report.lock_edges, &mut report.findings);
        report
    }

    #[test]
    fn clean_source_is_clean() {
        let r = analyze_src(
            "src/coordinator/serving/queue.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 { *lock_recover(m) }",
        );
        assert!(r.denied(&[]).next().is_none());
        assert_eq!(r.files_scanned, 1);
    }

    #[test]
    fn deny_escalates_annotated_findings() {
        let src = concat!(
            "fn f(m: &std::sync::Mutex<u32>) {\n",
            "    // analyze: allow(lock-discipline, reason=\"fixture\")\n",
            "    let _ = m.lock().unwrap();\n",
            "}\n",
        );
        let r = analyze_src("src/util/x.rs", src);
        assert!(r.denied(&[]).next().is_none(), "annotated finding passes by default");
        assert_eq!(r.denied(&["lock-discipline".to_string()]).count(), 1);
        assert_eq!(r.denied(&["all".to_string()]).count(), 1);
        assert_eq!(r.allowed_count(), 1);
    }

    #[test]
    fn unknown_rule_in_allow_is_a_finding() {
        let r = analyze_src(
            "src/x.rs",
            "// analyze: allow(no-such-rule, reason=\"typo\")\nfn f() {}\n",
        );
        let denied: Vec<_> = r.denied(&[]).collect();
        assert_eq!(denied.len(), 1);
        assert_eq!(denied[0].rule, "annotation");
    }

    #[test]
    fn report_json_shape() {
        let src = concat!(
            "fn f(p: *const f32) -> f32 {\n",
            "    // SAFETY: caller passes a valid pointer.\n",
            "    unsafe { *p }\n",
            "}\n",
        );
        let r = analyze_src("src/x.rs", src);
        let json = r.to_json(&[]).to_string_pretty();
        assert!(json.contains("\"clean\": true"), "{json}");
        assert!(json.contains("\"unsafe_inventory\""), "{json}");
        assert!(json.contains("caller passes a valid pointer."), "{json}");
    }
}
