//! Benchmark harness: regenerates every table of the paper's evaluation
//! (§6). Each table prints (a) the paper's reported numbers, (b) the V100
//! cost-model estimate, and (c) where meaningful, *measured* times of the
//! Rust CPU kernels — so both the absolute paper-vs-model comparison and
//! the machine-local measured ratios are visible side by side.

pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;

pub use report::Table;
