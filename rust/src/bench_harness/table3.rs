//! Table 3: effect of row repetition (sizes of complete graphs `G_r`, `G_b`)
//! on SDMM runtime. `G_t = G_r ⊗ G_i ⊗ G_b` is held at (128, 32) and
//! `Sp(G_o)` at 50 %, as in the paper.
//!
//! The measured column goes through [`measure_rbgp4`], i.e. the
//! `SparseKernel` plan path: each configuration's execution plan is built
//! once outside the timed region, so the row-repetition effect is measured
//! on the amortized hot path.

use crate::bench_harness::report::{ms, Table};
use crate::bench_harness::table2::{measure_kernel, measure_kernel_tuned, rbgp4_matrix};
use crate::gpusim::{estimate, Device, KernelKind, SdmmShape};
use crate::kernels::autotune::TuneMode;
use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config};
use crate::util::rng::Rng;

/// (gr, gb, paper ms at Sp(G)% = 75 / 87.5 / 93.75)
pub const PAPER_ROWS: &[((usize, usize), (usize, usize), [f64; 3])] = &[
    ((1, 1), (1, 1), [7.07, 3.91, 2.45]),
    ((2, 1), (1, 1), [4.89, 3.02, 1.97]),
    ((4, 1), (1, 1), [4.47, 2.75, 1.92]),
    ((1, 1), (2, 1), [4.85, 3.01, 2.03]),
    ((1, 1), (4, 1), [4.47, 2.84, 2.02]),
    ((2, 1), (2, 1), [4.41, 2.75, 1.98]),
];

pub const SPARSITIES: [f64; 3] = [0.75, 0.875, 0.9375];

/// Build the Table-3 config: G_t fixed at (128, 32), G_o = (32, 128) @ 50 %,
/// G_i absorbs what G_r/G_b don't cover; its sparsity sets the total.
/// `scale` shrinks G_o for the measured column (scale 4 ⇒ 1024² matrices).
pub fn config_for(
    gr: (usize, usize),
    gb: (usize, usize),
    total_sp: f64,
    scale: usize,
) -> anyhow::Result<Rbgp4Config> {
    let gi_u = 128 / (gr.0 * gb.0);
    let gi_v = 32 / (gr.1 * gb.1);
    // total = 1 - (1-0.5)(1-sp_i) => sp_i = 1 - (1-total)/0.5
    let sp_i = 1.0 - (1.0 - total_sp) / 0.5;
    let cfg = Rbgp4Config {
        go: GraphSpec::new(32 / scale, 128 / scale, 0.5),
        gr,
        gi: GraphSpec::new(gi_u, gi_v, sp_i),
        gb,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Run Table 3. `measure_n` as in table2 (0 = model only).
pub fn run(measure_n: usize, seed: u64) -> Table {
    run_tuned(measure_n, seed, None)
}

/// [`run`] with an optional tuned column per sparsity: each measured
/// matrix is timed from the heuristic plan and, when `tune` is set, again
/// from the autotuned plan (same matrix, so the delta isolates the
/// schedule).
pub fn run_tuned(measure_n: usize, seed: u64, tune: Option<TuneMode>) -> Table {
    let dev = Device::v100();
    let shape = SdmmShape {
        m: 4096,
        k: 4096,
        n: 4096,
    };
    let tuned_col = tune.filter(|_| measure_n > 0);
    let mut headers: Vec<String> = vec!["G_r".into(), "G_b".into(), "rep".into()];
    for sp in SPARSITIES {
        headers.push(format!("paper {:.2}%", sp * 100.0));
        headers.push(format!("model {:.2}%", sp * 100.0));
        if measure_n > 0 {
            headers.push(format!("meas@{measure_n} {:.2}%", sp * 100.0));
        }
        if tuned_col.is_some() {
            headers.push(format!("tuned@{measure_n} {:.2}%", sp * 100.0));
        }
    }
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(
        "Table 3 — row repetition from complete graphs G_r, G_b (SDMM 4096³, Sp(G_o)=50%)",
        &hdr_refs,
    );
    let mut rng = Rng::new(seed);
    for &(gr, gb, paper) in PAPER_ROWS {
        let mut cells = vec![
            format!("({},{})", gr.0, gr.1),
            format!("({},{})", gb.0, gb.1),
            format!("{}", gr.0 * gb.0),
        ];
        for (si, &sp) in SPARSITIES.iter().enumerate() {
            let cfg = config_for(gr, gb, sp, 1).expect("valid");
            let model = estimate(&dev, shape, &KernelKind::Rbgp4 { config: cfg }).t_total;
            cells.push(format!("{}", paper[si]));
            cells.push(ms(model));
            if measure_n > 0 {
                let scale = 4096 / measure_n;
                match config_for(gr, gb, sp, scale) {
                    Ok(cfg_s) => {
                        let w = rbgp4_matrix(cfg_s, &mut rng);
                        cells.push(ms(measure_kernel(&w, measure_n, &mut rng)));
                        if let Some(mode) = tuned_col {
                            let t = measure_kernel_tuned(&w, measure_n, &mut rng, mode);
                            cells.push(ms(t));
                        }
                    }
                    Err(_) => {
                        cells.push("-".into());
                        if tuned_col.is_some() {
                            cells.push("-".into());
                        }
                    }
                }
            }
        }
        table.row(cells);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_keep_gt_fixed() {
        for &(gr, gb, _) in PAPER_ROWS {
            let cfg = config_for(gr, gb, 0.75, 1).unwrap();
            assert_eq!(cfg.tile_m(), 128, "gr={gr:?} gb={gb:?}");
            assert_eq!(cfg.tile_k(), 32);
            assert_eq!((cfg.rows(), cfg.cols()), (4096, 4096));
            assert!((cfg.sparsity() - 0.75).abs() < 1e-12);
            assert_eq!(cfg.row_repetition(), gr.0 * gb.0);
        }
    }

    #[test]
    fn model_repetition_monotone_within_family() {
        // (1,1)/(1,1) vs (2,1)/(1,1) vs (4,1)/(1,1): model time non-increasing.
        let dev = Device::v100();
        let shape = SdmmShape { m: 4096, k: 4096, n: 4096 };
        let mut last = f64::INFINITY;
        for gr0 in [1usize, 2, 4] {
            let cfg = config_for((gr0, 1), (1, 1), 0.75, 1).unwrap();
            let t = estimate(&dev, shape, &KernelKind::Rbgp4 { config: cfg }).t_total;
            assert!(t <= last);
            last = t;
        }
    }

    #[test]
    fn table_renders_model_only() {
        let t = run(0, 2);
        assert_eq!(t.rows.len(), PAPER_ROWS.len());
        assert!(t.render().contains("Table 3"));
    }
}
