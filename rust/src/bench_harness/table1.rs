//! Table 1: image classification on CIFAR-10/100 with VGG19 and
//! WideResNet-40-4 — memory and per-forward-pass time for dense /
//! unstructured / block(4,4) / RBGP4 at 50–93.75 % sparsity.
//!
//! Regenerated columns:
//! * **Mem** — exact arithmetic over the real layer shapes
//!   (`models::vgg/wideresnet` + `sparsity::memory`).
//! * **Time** — Σ over layers of the V100 cost-model SDMM estimate at the
//!   paper's training batch (256 for VGG19, 128 for WRN-40-4).
//! * **Acc** — the paper's numbers are reprinted; our small-scale accuracy
//!   parity proxy lives in `examples/train_cifar_like.rs` (EXPERIMENTS.md).

use crate::bench_harness::report::{ms, Table};
use crate::gpusim::{estimate, Device, KernelKind};
use crate::models::{vgg::vgg19, wideresnet::wrn40_4, Network};
use crate::sparsity::memory::{network_bytes, Pattern};
use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config};
use crate::util::fmt_mb;

pub const SPARSITIES: [f64; 4] = [0.50, 0.75, 0.875, 0.9375];

/// Paper-reported (mem MB, time ms) per (network, sparsity, pattern).
/// Index: [vgg=0|wrn=1][sparsity 0..4][dense, unstructured, block, rbgp4].
pub const PAPER_MEM_TIME: [[[(f64, f64); 4]; 4]; 2] = [
    // VGG19 (dense: 77.39 MB / 22 ms at every sparsity row for reference)
    [
        [(77.39, 22.0), (77.39, 165.0), (41.12, 94.0), (38.76, 20.0)],
        [(77.39, 22.0), (38.71, 86.0), (20.57, 48.0), (19.40, 13.0)],
        [(77.39, 22.0), (19.37, 79.0), (10.30, 25.0), (9.72, 8.0)],
        [(77.39, 22.0), (9.70, 50.0), (5.16, 14.0), (4.88, 6.0)],
    ],
    // WideResnet-40-4 (dense 34.10 MB / 40 ms)
    [
        [(34.10, 40.0), (34.10, 241.0), (18.12, 165.0), (17.13, 32.0)],
        [(34.10, 40.0), (17.05, 135.0), (9.07, 85.0), (8.57, 20.0)],
        [(34.10, 40.0), (8.53, 102.0), (4.54, 45.0), (4.30, 16.0)],
        [(34.10, 40.0), (4.27, 69.0), (2.27, 26.0), (2.16, 14.0)],
    ],
];

/// Sparsity split used for the RBGP4 time model at a given total sparsity —
/// the best split from Table 2 (more sparsity in G_o).
fn rbgp4_split(total: f64) -> (f64, f64) {
    match total {
        x if (x - 0.50).abs() < 1e-9 => (0.5, 0.0),
        x if (x - 0.75).abs() < 1e-9 => (0.5, 0.5),
        x if (x - 0.875).abs() < 1e-9 => (0.75, 0.5),
        _ => (0.875, 0.5),
    }
}

/// A per-layer RBGP4 config shaped for the cost model. Layer shapes vary,
/// so we keep the paper's tile structure (G_t = (128, 32)) and scale G_o.
fn layer_rbgp4(m: usize, k: usize, total_sp: f64) -> Rbgp4Config {
    let (sp_o, sp_i) = rbgp4_split(total_sp);
    Rbgp4Config {
        go: GraphSpec::new((m / 128).max(1), (k / 32).max(1), sp_o),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, sp_i),
        gb: (1, 1),
    }
}

/// Model the per-forward time of `net` at `batch` under `pattern`/`sp`.
pub fn network_time(net: &Network, batch: usize, sp: f64, pattern: Pattern) -> f64 {
    let dev = Device::v100();
    net.layers
        .iter()
        .map(|layer| {
            let shape = layer.sdmm_shape(batch);
            let kind = if !layer.sparsified || pattern == Pattern::Dense {
                KernelKind::DenseCublas
            } else {
                match pattern {
                    Pattern::Unstructured => KernelKind::UnstructuredCsr { sp },
                    Pattern::Block(bh, bw) => KernelKind::BlockBsr { sp, bh, bw },
                    Pattern::Rbgp4 => KernelKind::Rbgp4 {
                        config: layer_rbgp4(shape.m, shape.k, sp),
                    },
                    Pattern::Dense => unreachable!(),
                }
            };
            estimate(&dev, shape, &kind).t_total
        })
        .sum()
}

/// Render Table 1 for both networks.
pub fn run() -> Vec<Table> {
    let nets = [(vgg19(10), 256usize, 0usize), (wrn40_4(10), 128, 1)];
    let patterns = [
        Pattern::Dense,
        Pattern::Unstructured,
        Pattern::Block(4, 4),
        Pattern::Rbgp4,
    ];
    let mut tables = Vec::new();
    for (net, batch, ni) in nets {
        let mut table = Table::new(
            &format!("Table 1 — {} (batch {batch})", net.name),
            &[
                "Sparsity%",
                "Pattern",
                "paper Mem MB",
                "our Mem MB",
                "paper Time ms",
                "model Time ms",
            ],
        );
        let layers = net.memory_layers();
        for (si, &sp) in SPARSITIES.iter().enumerate() {
            for (pi, &pat) in patterns.iter().enumerate() {
                if pat == Pattern::Dense && si > 0 {
                    continue; // dense row printed once, like the paper
                }
                let (paper_mem, paper_time) = PAPER_MEM_TIME[ni][si][pi];
                let mem = network_bytes(&layers, sp, pat);
                let time = network_time(&net, batch, sp, pat);
                table.row(vec![
                    if pat == Pattern::Dense {
                        "0.00".into()
                    } else {
                        format!("{:.2}", sp * 100.0)
                    },
                    pat.name().into(),
                    format!("{paper_mem}"),
                    fmt_mb(mem),
                    format!("{paper_time}"),
                    ms(time),
                ]);
            }
        }
        tables.push(table);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_time_ordering_holds_for_both_networks() {
        for (net, batch) in [(vgg19(10), 256usize), (wrn40_4(10), 128)] {
            for &sp in &SPARSITIES[1..] {
                let un = network_time(&net, batch, sp, Pattern::Unstructured);
                let bl = network_time(&net, batch, sp, Pattern::Block(4, 4));
                let rb = network_time(&net, batch, sp, Pattern::Rbgp4);
                let de = network_time(&net, batch, sp, Pattern::Dense);
                assert!(un > bl, "{} sp={sp}: un {un} !> bl {bl}", net.name);
                assert!(bl > rb, "{} sp={sp}: bl {bl} !> rb {rb}", net.name);
                assert!(rb < de, "{} sp={sp}: rb {rb} !< de {de}", net.name);
            }
        }
    }

    #[test]
    fn rbgp4_headline_factors_in_paper_range() {
        // Paper: RBGP4 is 5–9x faster than unstructured, 2–5x than block.
        let net = vgg19(10);
        for &sp in &[0.75, 0.875] {
            let un = network_time(&net, 256, sp, Pattern::Unstructured);
            let bl = network_time(&net, 256, sp, Pattern::Block(4, 4));
            let rb = network_time(&net, 256, sp, Pattern::Rbgp4);
            let vs_un = un / rb;
            let vs_bl = bl / rb;
            assert!(vs_un > 3.0 && vs_un < 20.0, "vs unstructured {vs_un}");
            assert!(vs_bl > 1.5 && vs_bl < 8.0, "vs block {vs_bl}");
        }
    }

    #[test]
    fn memory_matches_paper_within_tolerance() {
        // Spot-check the 93.75% row of both networks (tightest values).
        let vgg = vgg19(10).memory_layers();
        let got = network_bytes(&vgg, 0.9375, Pattern::Rbgp4) as f64 / (1024.0 * 1024.0);
        assert!((got - 4.88).abs() / 4.88 < 0.06, "VGG RBGP4 93.75%: {got}");
        let wrn = wrn40_4(10).memory_layers();
        let got = network_bytes(&wrn, 0.9375, Pattern::Unstructured) as f64 / (1024.0 * 1024.0);
        assert!((got - 4.27).abs() / 4.27 < 0.07, "WRN unstructured 93.75%: {got}");
    }

    #[test]
    fn tables_render() {
        let ts = run();
        assert_eq!(ts.len(), 2);
        for t in ts {
            assert_eq!(t.rows.len(), 1 + 3 * SPARSITIES.len());
        }
    }
}
