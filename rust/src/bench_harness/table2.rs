//! Table 2: effect of distributing sparsity between `G_o` and `G_i` on
//! SDMM runtime (4096³, base sizes (32,128),(4,1),(32,32),(1,1)).
//!
//! Columns: the paper's V100 measurement, our V100 cost-model estimate, and
//! the *measured* Rust CPU kernel (optionally at a reduced size — the
//! relative ordering is the claim under test, not absolute milliseconds).
//! Measured cells execute through the `SparseKernel` plan layer with the
//! plan built outside the timed region (see [`measure_kernel`]); model and
//! measurement dispatch off the same `Pattern` key
//! ([`KernelKind::pattern`]).

use crate::bench_harness::report::{ms, speedup, Table};
use crate::gpusim::{estimate, Device, KernelKind, SdmmShape};
use crate::kernels::autotune::TuneMode;
use crate::kernels::plan::{PlanRequest, SparseMatrix};
use crate::kernels::registry::KernelRegistry;
use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
use crate::util::rng::Rng;
use crate::util::threadpool::default_threads;
use crate::util::timing::{bench_fn, BenchConfig};

/// (total sparsity %, sp_o %, sp_i %, paper time ms)
pub const PAPER_ROWS: &[(f64, f64, f64, f64)] = &[
    (75.00, 0.00, 75.00, 5.64),
    (75.00, 50.00, 50.00, 4.44),
    (87.50, 0.00, 87.50, 4.31),
    (87.50, 50.00, 75.00, 2.74),
    (87.50, 75.00, 50.00, 2.29),
    (93.75, 0.00, 93.75, 3.76),
    (93.75, 50.00, 87.50, 1.93),
    (93.75, 75.00, 75.00, 1.44),
    (93.75, 87.50, 50.00, 1.22),
];

pub const PAPER_DENSE_MS: f64 = 11.2;

/// The Table-2 RBGP4 config at `scale` ∈ {1 → 4096², 1/4 → 1024², …}:
/// `G_o` shrinks with scale, per-tile structure fixed.
pub fn config_at(sp_o: f64, sp_i: f64, scale: usize) -> Rbgp4Config {
    Rbgp4Config {
        go: GraphSpec::new(32 / scale, 128 / scale, sp_o),
        gr: (4, 1),
        gi: GraphSpec::new(32, 32, sp_i),
        gb: (1, 1),
    }
}

/// Run Table 2. `measure_n`: matrix size for the measured column (0 skips
/// measurement and prints only the model).
pub fn run(measure_n: usize, seed: u64) -> Table {
    run_tuned(measure_n, seed, None)
}

/// [`run`] with an optional tuned column: when `tune` is set, every
/// measured matrix is timed twice — once from the fixed heuristic plan
/// ([`TuneMode::Off`]) and once from a plan whose schedule the autotune
/// search picked — and the extra column reports the tuned time with its
/// speedup over the heuristic. The two cells share one matrix, so the
/// delta isolates the schedule.
pub fn run_tuned(measure_n: usize, seed: u64, tune: Option<TuneMode>) -> Table {
    let dev = Device::v100();
    let shape = SdmmShape {
        m: 4096,
        k: 4096,
        n: 4096,
    };
    let tuned_col = tune.filter(|_| measure_n > 0);
    let mut headers: Vec<String> = vec![
        "Sp(G)%".into(),
        "Sp(Go)%".into(),
        "Sp(Gi)%".into(),
        "paper ms (x)".into(),
        "model ms (x)".into(),
        format!("measured@{measure_n} ms (x)"),
    ];
    if tuned_col.is_some() {
        headers.push(format!("tuned@{measure_n} ms (x vs heur)"));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(
        "Table 2 — sparsity distribution between G_o and G_i (SDMM 4096³)",
        &hdr_refs,
    );

    let dense_model = estimate(&dev, shape, &KernelKind::DenseCublas).t_total;
    let mut rng = Rng::new(seed);
    let (dense_meas, dense_tuned) = if measure_n > 0 {
        let w = dense_matrix(measure_n, &mut rng);
        let heur = measure_kernel(&w, measure_n, &mut rng);
        let tuned = tuned_col.map(|m| measure_kernel_tuned(&w, measure_n, &mut rng, m));
        (Some(heur), tuned)
    } else {
        (None, None)
    };
    let mut dense_row = vec![
        "0".into(),
        "0".into(),
        "0".into(),
        format!("{PAPER_DENSE_MS} (1x)"),
        format!("{} (1x)", ms(dense_model)),
        dense_meas
            .map(|t| format!("{} (1x)", ms(t)))
            .unwrap_or_else(|| "-".into()),
    ];
    if tuned_col.is_some() {
        dense_row.push(match (dense_tuned, dense_meas) {
            (Some(t), Some(h)) => format!("{} ({})", ms(t), speedup(h, t)),
            _ => "-".into(),
        });
    }
    table.row(dense_row);

    for &(sp, sp_o, sp_i, paper) in PAPER_ROWS {
        let cfg = config_at(sp_o / 100.0, sp_i / 100.0, 1);
        let model = estimate(&dev, shape, &KernelKind::Rbgp4 { config: cfg }).t_total;
        let (measured, tuned) = if measure_n > 0 {
            let scale = 4096 / measure_n;
            let cfg_s = config_at(sp_o / 100.0, sp_i / 100.0, scale);
            let w = rbgp4_matrix(cfg_s, &mut rng);
            let heur = measure_kernel(&w, measure_n, &mut rng);
            let tuned = tuned_col.map(|m| measure_kernel_tuned(&w, measure_n, &mut rng, m));
            (Some(heur), tuned)
        } else {
            (None, None)
        };
        let mut cells = vec![
            format!("{sp:.2}"),
            format!("{sp_o:.2}"),
            format!("{sp_i:.2}"),
            format!("{paper} ({})", speedup(PAPER_DENSE_MS, paper)),
            format!("{} ({})", ms(model), speedup(dense_model, model)),
            match (measured, dense_meas) {
                (Some(t), Some(d)) => format!("{} ({})", ms(t), speedup(d, t)),
                _ => "-".into(),
            },
        ];
        if tuned_col.is_some() {
            cells.push(match (tuned, measured) {
                (Some(t), Some(h)) => format!("{} ({})", ms(t), speedup(h, t)),
                _ => "-".into(),
            });
        }
        table.row(cells);
    }
    table
}

/// Median *execute* time of `w` against an (n-col) input through the
/// `SparseKernel` trait: the plan is built once outside the timed region —
/// what the serving hot path pays per call — and the measured column of
/// Tables 2/3 therefore reports the amortized number the paper's claim is
/// about, not per-call structure rebuilds.
pub fn measure_kernel(w: &SparseMatrix, n: usize, rng: &mut Rng) -> f64 {
    measure_kernel_tuned(w, n, rng, TuneMode::Off)
}

/// [`measure_kernel`] with an explicit tune mode: the plan (and its
/// schedule search, when `tune` measures) is still built outside the
/// timed region, so the cell reports hot-path execute time only.
pub fn measure_kernel_tuned(w: &SparseMatrix, n: usize, rng: &mut Rng, tune: TuneMode) -> f64 {
    let registry = KernelRegistry::builtin();
    let kernel = registry.for_matrix(w).expect("registered kernel");
    let threads = default_threads();
    let i = rng.normal_vec_f32(w.cols() * n, 1.0);
    let mut o = vec![0.0f32; w.rows() * n];
    let mut plan = kernel
        .build_plan(w, &PlanRequest::new(n, threads).with_tune(tune))
        .expect("plan");
    let bench = BenchConfig::from_env();
    bench_fn(&bench, || {
        kernel.execute(w, &mut plan, &i, &mut o, n).expect("execute");
        std::hint::black_box(&o);
    })
    .median
}

/// A dense (n × n) weight with normal entries — the cuBLAS stand-in's input.
pub fn dense_matrix(n: usize, rng: &mut Rng) -> SparseMatrix {
    SparseMatrix::dense(rng.normal_vec_f32(n * n, 1.0), n, n)
}

/// An RBGP4 weight sampled from `cfg` with random values.
pub fn rbgp4_matrix(cfg: Rbgp4Config, rng: &mut Rng) -> SparseMatrix {
    let mask = Rbgp4Mask::sample(cfg, rng).expect("valid config");
    SparseMatrix::Rbgp4(Rbgp4Matrix::random(mask, rng))
}

/// Median time of the parallel blocked dense GEMM at n³ (cuBLAS stand-in).
pub fn measure_dense(n: usize, rng: &mut Rng) -> f64 {
    let w = dense_matrix(n, rng);
    measure_kernel(&w, n, rng)
}

/// Median time of the parallel RBGP4MM kernel for `cfg` tiled to (n × n)·(n × n).
pub fn measure_rbgp4(cfg: Rbgp4Config, n: usize, rng: &mut Rng) -> f64 {
    assert_eq!(cfg.rows(), n, "config rows {} != {n}", cfg.rows());
    assert_eq!(cfg.cols(), n, "config cols {} != {n}", cfg.cols());
    let w = rbgp4_matrix(cfg, rng);
    measure_kernel(&w, n, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_shapes() {
        let c = config_at(0.5, 0.5, 1);
        assert_eq!((c.rows(), c.cols()), (4096, 4096));
        let c4 = config_at(0.5, 0.5, 4);
        assert_eq!((c4.rows(), c4.cols()), (1024, 1024));
    }

    #[test]
    fn model_reproduces_paper_ordering() {
        // Within each sparsity group, more G_o sparsity ⇒ faster (model).
        let dev = Device::v100();
        let shape = SdmmShape { m: 4096, k: 4096, n: 4096 };
        for group in [&PAPER_ROWS[0..2], &PAPER_ROWS[2..5], &PAPER_ROWS[5..9]] {
            let mut last = f64::INFINITY;
            for &(_, sp_o, sp_i, _) in group {
                let cfg = config_at(sp_o / 100.0, sp_i / 100.0, 1);
                let t = estimate(&dev, shape, &KernelKind::Rbgp4 { config: cfg }).t_total;
                assert!(t < last, "sp_o={sp_o}: {t} !< {last}");
                last = t;
            }
        }
    }

    #[test]
    fn table_renders_without_measurement() {
        let t = run(0, 1);
        let s = t.render();
        assert!(s.contains("Table 2"));
        assert_eq!(t.rows.len(), 1 + PAPER_ROWS.len());
    }

    #[test]
    fn tuned_column_appears_only_when_measuring() {
        // With measure_n == 0 there is nothing to compare: the tuned
        // column must not render a header with no cells under it.
        let t = run_tuned(0, 1, Some(TuneMode::Quick));
        assert!(!t.render().contains("tuned@"));
        assert_eq!(t.rows.len(), 1 + PAPER_ROWS.len());
    }
}
