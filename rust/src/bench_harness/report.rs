//! Markdown-ish table formatting for bench reports (diff-friendly,
//! fixed-width columns).

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format milliseconds with sensible precision.
pub fn ms(t_seconds: f64) -> String {
    let v = t_seconds * 1e3;
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

/// Format a speedup like the paper's "(4.9x)".
pub fn speedup(base: f64, t: f64) -> String {
    format!("{:.1}x", base / t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("Demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yy".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4); // header, sep, 2 rows
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn ms_precision() {
        assert_eq!(ms(0.0112), "11.2");
        assert_eq!(ms(0.00122), "1.22");
        assert_eq!(ms(0.165), "165");
    }

    #[test]
    fn speedup_format() {
        assert_eq!(speedup(11.2, 1.22), "9.2x");
    }
}
