//! `rbgp` — CLI for the RBGP block-sparse neural network system.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!   gen-graph   sample + certify a Ramanujan bipartite graph (App. 8.1)
//!   make-mask   sample an RBGP4 mask, write the succinct JSON form
//!   spectral    Theorem-1 numeric check (spectral-gap ratio → 1)
//!   memory      Table-1 memory accounting (+ --fig3 succinctness demo)
//!   explain     Figure-1 tiling/reuse walkthrough for a config
//!   table1/2/3  regenerate the paper's evaluation tables
//!   train       run the AOT train-step artifact on CIFAR-like data
//!   serve       batched inference server demo over the forward artifact
//!   analyze     static-analysis pass enforcing the crate's concurrency invariants

use rbgp::bench_harness::{table1, table2, table3};
use rbgp::coordinator::{
    Frontend, FrontendClient, FrontendConfig, InferenceServer, ServeError, ServerConfig, Status,
    SubmitOptions,
};
use rbgp::data::CifarLike;
use rbgp::graph::{product_many, ramanujan, spectral, BipartiteGraph};
use rbgp::gpusim::explain_fig1;
use rbgp::models::{vgg::vgg19, wideresnet::wrn40_4};
use rbgp::sparsity::memory::{network_bytes, Pattern};
use rbgp::sparsity::rbgp4::{Rbgp4Config, Rbgp4Mask};
use rbgp::util::cli::{split_assign, Args};
use rbgp::util::fmt_mb;
use rbgp::util::rng::Rng;
use std::path::PathBuf;
use std::time::Duration;

use rbgp::kernels::TuneMode;

#[cfg(not(feature = "xla"))]
use rbgp::coordinator::{BatchModel, NativeSparseModel, NativeTrainer};
#[cfg(not(feature = "xla"))]
use rbgp::train_native::NativeTrainConfig;
#[cfg(feature = "xla")]
use rbgp::coordinator::{TrainConfig, Trainer};

const USAGE: &str = "\
rbgp — Ramanujan Bipartite Graph Products for block sparse neural networks

USAGE: rbgp <command> [options]

COMMANDS
  gen-graph  --m 32 --n 32 --sp 0.75 [--seed 0]        sample + certify an RBG
  make-mask  [--config-json FILE | --sp-o .5 --sp-i .5] [--out mask.json]
  spectral   --theorem1 [--sp 0.75] [--seed 0]          Thm-1 ratio vs size
  memory     [--network vgg19|wrn40-4] [--fig3]         Table-1 Mem column
  explain    [--sp-o .5 --sp-i .5]                      Fig-1 tiling walkthrough
  table1                                                Table 1 (mem + time model)
  table2     [--measure-n 1024] [--seed 0] [--tune quick|full]  Table 2 (+tuned col)
  table3     [--measure-n 1024] [--seed 0] [--tune quick|full]  Table 3 (+tuned col)
  train      [--artifacts DIR] [--steps 300] [--lr 0.1] [--seed 0] [--distill]
             [--save ckpt.json] [--load ckpt.json]
             [--gradual] [--milestones 0.25,0.6] [--sp 0.75]
             [--tune off|quick|full] [--tune-cache FILE]       (native only)
  serve      [--requests 512] [--clients 4] [--workers 2] [--queue-cap 1024]
             [--deadline-ms 0] [--max-starvation-ms 1000] [--model-quota Q]
             [--model name=ckpt.json[@Q]]...
             [--alias name=model]... [--canary alias=model@pct]
             [--shadow alias=model] [--promote alias=model]
             [--tune off|quick|full] [--tune-cache FILE]
             [--retune-threshold 0.7]                          (native only)
             [--listen ADDR] [--tenant key=quota]...
             [--artifacts DIR] [--checkpoint ckpt.json]        (xla only)
  analyze    [PATHS]... [--json] [--out FILE] [--deny RULE]... [--verbose]
             lint the crate sources against the serving-core invariants

With the `xla` feature, train/serve execute AOT artifacts on PJRT (run
`make artifacts` first). Without it, they run the native plan-cached
backends: `train` fits the masked MLP on the synthetic task (add
--gradual to start dense and tighten toward the RBGP4 mask at the
--milestones fractions, re-keying the plan cache at each; --save/--load
round-trip JSON checkpoints), `serve` serves the RBGP4 demo model from
the kernel plan cache — or, with one `--model name=ckpt.json` per model,
serves several trained checkpoints concurrently from one worker pool
sharing one plan cache (per-model plan namespaces). --tune picks how
hard plan warm-up searches kernel schedules (off = fixed heuristic,
quick = small measured search, full = wider search; the winning
schedule is cached per plan key, so the search runs once, and every
candidate is bit-identical to the heuristic). --tune-cache persists the
winners to a JSON file keyed by structure, shape, batch class, threads
and a machine fingerprint: a later run (train or serve) pointed at the
same file rebuilds its plans with zero measurement reps. While serving,
workers track achieved GFLOP/s per layer; if a model drifts below
--retune-threshold of its tuned throughput (0 disables), an idle worker
re-runs the search and swaps plans without blocking traffic. A quota Q
bounds how
many requests a model may have queued at once (admission control): an
integer is an absolute cap, a fraction in (0,1) is a share of
--queue-cap, 0 means unlimited; --model-quota sets the default for every
model and `--model name=ckpt.json@Q` overrides it per model, so one hot
model cannot exhaust the queue the other models share. Rollout ops:
--alias adds a client-facing name over a concrete model (clients submit
under the alias; the round-robin demo traffic does), --canary routes
pct% of an alias's traffic to a second model by a deterministic
per-request hash, --shadow mirrors every alias request to a second model
on spare capacity and records max-abs logit divergence (the client is
always answered by the primary), and --promote runs a full zero-downtime
rollout after the traffic phase: atomically flip the alias to the named
model, drain the old primary and retire it, printing exact eviction
counters. --listen ADDR additionally binds the non-blocking TCP
front-end on ADDR (port 0 picks a free port) and routes the demo
traffic through it as real network clients speaking the length-prefixed
binary protocol; each --tenant key=quota (same Q grammar as model
quotas) caps that tenant key's in-flight requests, rejected with a
typed TenantQuotaExceeded status before they touch the shared queue.

`analyze` runs the built-in static-analysis pass (lock-discipline,
lock-order, panic-freedom, atomic-ordering, unsafe-inventory) over
src/benches/tests (or the given PATHS), exits non-zero on any finding
not waived by an inline `// analyze: allow(rule, reason=\"...\")`, and
with --json also writes the machine-readable report (findings, unsafe
inventory, lock graph) to --out (default analysis_report.json).
--deny RULE ignores that rule's waivers; --verbose lists waived
findings in text mode.";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

/// `--tune` for the table commands: default off (heuristic-only measured
/// column); quick/full add a tuned column next to it.
fn table_tune(args: &Args) -> anyhow::Result<Option<TuneMode>> {
    Ok(match TuneMode::parse(&args.get_str("tune", "off"))? {
        TuneMode::Off => None,
        mode => Some(mode),
    })
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command() {
        Some("gen-graph") => gen_graph(args),
        Some("make-mask") => make_mask(args),
        Some("spectral") => spectral_cmd(args),
        Some("memory") => memory_cmd(args),
        Some("explain") => explain_cmd(args),
        Some("table1") => {
            for t in table1::run() {
                println!("{}", t.render());
            }
            Ok(())
        }
        Some("table2") => {
            let n = args.get_usize("measure-n", 1024)?;
            let tune = table_tune(args)?;
            println!("{}", table2::run_tuned(n, args.get_u64("seed", 0)?, tune).render());
            Ok(())
        }
        Some("table3") => {
            let n = args.get_usize("measure-n", 1024)?;
            let tune = table_tune(args)?;
            println!("{}", table3::run_tuned(n, args.get_u64("seed", 0)?, tune).render());
            Ok(())
        }
        Some("train") => train_cmd(args),
        Some("serve") => serve_cmd(args),
        Some("analyze") => analyze_cmd(args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// `rbgp analyze [PATHS]... [--json] [--out FILE] [--deny RULE]...` — the
/// static-analysis pass over the crate's own sources. Exits non-zero when
/// any finding is not covered by an `analyze: allow` waiver (or when its
/// rule is denied, which ignores waivers).
fn analyze_cmd(args: &Args) -> anyhow::Result<()> {
    let roots: Vec<PathBuf> = if args.positional().len() > 1 {
        args.positional()[1..].iter().map(PathBuf::from).collect()
    } else {
        rbgp::analysis::default_roots()
    };
    let deny: Vec<String> = args.get_all("deny").into_iter().map(str::to_string).collect();
    for d in &deny {
        anyhow::ensure!(
            d == "all" || rbgp::analysis::RULES.contains(&d.as_str()),
            "--deny {d}: unknown rule (known: {}, all)",
            rbgp::analysis::RULES.join(", ")
        );
    }
    let opts = rbgp::analysis::AnalysisOptions { roots, deny };
    let report = rbgp::analysis::analyze_tree(&opts)?;
    if args.flag("json") {
        let text = report.to_json(&opts.deny).to_string_pretty();
        let out = args.get_str("out", "analysis_report.json");
        std::fs::write(&out, &text)?;
        println!("{text}");
    } else {
        print!("{}", report.render_text(&opts.deny, args.flag("verbose")));
    }
    let denied = report.denied(&opts.deny).count();
    anyhow::ensure!(denied == 0, "analyze: {denied} denied finding(s)");
    Ok(())
}

fn gen_graph(args: &Args) -> anyhow::Result<()> {
    let m = args.get_usize("m", 32)?;
    let n = args.get_usize("n", 32)?;
    let sp = args.get_f64("sp", 0.75)?;
    let mut rng = Rng::new(args.get_u64("seed", 0)?);
    let t0 = std::time::Instant::now();
    let gen = ramanujan::generate(m, n, sp, &mut rng, 500)?;
    let c = gen.cert;
    println!("Ramanujan bipartite graph {m}x{n} @ sparsity {sp}");
    println!("  degrees      (d_l, d_r) = ({}, {})", c.dl, c.dr);
    println!("  λ1 = {:.4}   λ2 = {:.4}   bound = {:.4}", c.lambda1, c.lambda2, c.bound);
    println!("  spectral gap = {:.4}", c.lambda1 - c.lambda2);
    println!("  Ramanujan: {}  (attempt {} of sampling loop)", c.is_ramanujan, gen.attempts);
    println!("  connected: {}", gen.graph.is_connected());
    println!("  generated in {:.3}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn parse_config(args: &Args) -> anyhow::Result<Rbgp4Config> {
    if let Some(path) = args.get("config-json") {
        let text = std::fs::read_to_string(path)?;
        return Rbgp4Config::from_json(&rbgp::util::json::Json::parse(&text)?);
    }
    let sp_o = args.get_f64("sp-o", 0.5)?;
    let sp_i = args.get_f64("sp-i", 0.5)?;
    Ok(Rbgp4Config::paper_default(sp_o, sp_i))
}

fn make_mask(args: &Args) -> anyhow::Result<()> {
    let config = parse_config(args)?;
    let mut rng = Rng::new(args.get_u64("seed", 0)?);
    let mask = Rbgp4Mask::sample(config, &mut rng)?;
    let out = args.get_str("out", "mask.json");
    std::fs::write(&out, mask.to_json().to_string_pretty())?;
    println!(
        "wrote {out}: {}x{} sparsity {:.4}, row_nnz {}, succinct index {} elems ({}x smaller than adjacency)",
        mask.rows(),
        mask.cols(),
        config.sparsity(),
        config.row_nnz(),
        mask.succinct_index_elems(),
        mask.generic_index_elems() / mask.succinct_index_elems().max(1)
    );
    Ok(())
}

fn spectral_cmd(args: &Args) -> anyhow::Result<()> {
    let sp = args.get_f64("sp", 0.75)?;
    let seed = args.get_u64("seed", 0)?;
    let mut rng = Rng::new(seed);
    println!("Theorem 1 — spectral gap of G = G1 ⊗ G2 vs the ideal d²-regular gap");
    println!("(ratio → 1 as n grows; both base graphs n x n @ sparsity {sp})\n");
    println!("{:>6} {:>6} {:>12} {:>12} {:>10}", "n", "d", "gap(G)", "ideal gap", "ratio");
    for n in [8usize, 16, 32, 64] {
        let d = ((1.0 - sp) * n as f64).round() as usize;
        if d < 4 {
            // Ramanujan bound is vacuous at d ≤ 2 (λ2 ≤ 2 = λ1); skip.
            continue;
        }
        let g1 = ramanujan::generate_best_effort(n, n, sp, &mut rng, 64)?.0.graph;
        let g2 = ramanujan::generate_best_effort(n, n, sp, &mut rng, 64)?.0.graph;
        let p = product_many(&[&g1, &g2])?;
        let s = spectral::spectrum(&p, rng.next_u64());
        let d2 = (d * d) as f64;
        let ideal = d2 - 2.0 * (d2 - 1.0).sqrt();
        let gap = s.gap();
        println!(
            "{n:>6} {d:>6} {gap:>12.4} {ideal:>12.4} {:>10.4}",
            ideal / gap.max(1e-12)
        );
    }
    println!("\n(λ2 of the product is the product of base λ's — see graph::product tests)");
    Ok(())
}

fn memory_cmd(args: &Args) -> anyhow::Result<()> {
    if args.flag("fig3") {
        // Figure-3 succinctness example: 4 base graphs, 512 edges vs 22.
        let mut rng = Rng::new(1);
        let g1 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng)?;
        let g2 = BipartiteGraph::identity(2);
        let g3 = BipartiteGraph::random_biregular(4, 4, 2, &mut rng)?;
        let g4 = BipartiteGraph::complete(2, 2);
        let p = product_many(&[&g1, &g2, &g3, &g4])?;
        let base_edges = g1.num_edges() + g2.num_edges() + g3.num_edges() + g4.num_edges();
        println!("Figure 3 — succinct connectivity storage");
        println!("  product graph: {}x{} with {} edges", p.nu, p.nv, p.num_edges());
        println!("  base-graph edges stored: {base_edges}");
        println!("  reduction: {:.1}x", p.num_edges() as f64 / base_edges as f64);
        return Ok(());
    }
    let which = args.get_str("network", "vgg19");
    let net = match which.as_str() {
        "vgg19" => vgg19(10),
        "wrn40-4" | "wideresnet" => wrn40_4(10),
        other => anyhow::bail!("unknown network '{other}' (vgg19|wrn40-4)"),
    };
    println!("{} — memory by pattern (MB), Table 1 Mem column", net.name);
    let layers = net.memory_layers();
    println!(
        "{:>10} {:>10} {:>14} {:>12} {:>10}",
        "Sparsity%", "Dense", "Unstructured", "Block(4,4)", "RBGP4"
    );
    for sp in [0.5, 0.75, 0.875, 0.9375] {
        println!(
            "{:>10.2} {:>10} {:>14} {:>12} {:>10}",
            sp * 100.0,
            fmt_mb(network_bytes(&layers, sp, Pattern::Dense)),
            fmt_mb(network_bytes(&layers, sp, Pattern::Unstructured)),
            fmt_mb(network_bytes(&layers, sp, Pattern::Block(4, 4))),
            fmt_mb(network_bytes(&layers, sp, Pattern::Rbgp4)),
        );
    }
    Ok(())
}

fn explain_cmd(args: &Args) -> anyhow::Result<()> {
    let config = parse_config(args)?;
    let e = explain_fig1(&config);
    println!("Figure 1 — RBGP4 tiled SDMM decomposition");
    println!("  W_s: {}x{}  sparsity {:.4}", config.rows(), config.cols(), config.sparsity());
    println!("  tile (TM, TK) = ({}, {})", e.tile_m, e.tile_k);
    println!(
        "  steps per output tile: {} of {} (G_o skips {:.0}% of tiles)",
        e.steps_skipped,
        e.steps_dense,
        100.0 * (1.0 - e.steps_skipped as f64 / e.steps_dense as f64)
    );
    println!("  row repetition (|G_r.U|·|G_b.U|): {}", e.row_repetition);
    println!("  RegW reuse: {}x   RegI reuse: {}x", e.regw_reuse, e.regi_reuse);
    Ok(())
}

#[cfg(feature = "xla")]
fn train_cmd(args: &Args) -> anyhow::Result<()> {
    for flag in ["gradual", "milestones"] {
        anyhow::ensure!(
            !args.flag(flag),
            "--{flag} runs on the native trainer (the AOT artifact's mask is \
             baked in at lowering time); rebuild without `--features xla`"
        );
    }
    let dir = artifacts_dir(args);
    let config = TrainConfig {
        steps: args.get_usize("steps", 300)?,
        lr0: args.get_f64("lr", 0.1)? as f32,
        seed: args.get_u64("seed", 0)?,
        distill: args.flag("distill"),
        eval_every: args.get_usize("eval-every", 50)?,
        ..TrainConfig::default()
    };
    println!("loading artifacts from {} …", dir.display());
    let mut trainer = Trainer::new(&dir, config)?;
    if let Some(load) = args.get("load") {
        trainer.load_checkpoint(std::path::Path::new(load))?;
        println!("loaded checkpoint {load}");
    }
    println!("batch {}, starting training", trainer.batch_size());
    trainer.run()?;
    if let Some(save) = args.get("save") {
        trainer.save_checkpoint(std::path::Path::new(save))?;
        println!("saved checkpoint {save}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn train_cmd(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.flag("distill"),
        "--distill requires the `xla` feature (the KD artifact runs on PJRT); \
         rebuild with `--features xla`"
    );
    let config = NativeTrainConfig {
        steps: args.get_usize("steps", 300)?,
        batch: args.get_usize("batch", 64)?,
        lr: args.get_f64("lr", 0.05)? as f32,
        seed: args.get_u64("seed", 0)?,
        tune: TuneMode::parse(&args.get_str("tune", "quick"))?,
        tune_cache: args.get("tune-cache").map(PathBuf::from),
        ..NativeTrainConfig::default()
    };
    let in_dim = args.get_usize("in-dim", 256)?;
    let hidden = args.get_usize("hidden", 256)?;
    let classes = args.get_usize("classes", 16)?;
    let sp = args.get_f64("sp", 0.75)?;
    if args.flag("gradual") {
        anyhow::ensure!(
            args.get("load").is_none(),
            "--load conflicts with --gradual (a restored mask need not nest \
             in the gradual chain); start the schedule fresh"
        );
        let schedule = match args.get("milestones") {
            Some(text) => rbgp::train_native::GradualSchedule::parse(text)?,
            None => rbgp::train_native::GradualSchedule::default(),
        };
        println!(
            "xla feature disabled — native gradual-induction trainer \
             (MLP {in_dim}->{hidden}->{classes}, dense start → RBGP4 @ {:.1}% \
             sparsity, milestones {:?})",
            sp * 100.0,
            schedule.fractions
        );
        let mut trainer =
            NativeTrainer::new_gradual(in_dim, hidden, classes, sp, &schedule, config)?;
        // run_gradual prints each milestone (loss/sparsity/structure
        // hash/eviction/rebuild) as it fires; only the totals remain here.
        let report = trainer.run_gradual()?;
        let rebuild_ms: f64 = report.milestones.iter().map(|r| r.plan_rebuild_s * 1e3).sum();
        println!("total plan-rebuild time across milestones: {rebuild_ms:.3} ms");
        let (hits, misses) = trainer.cache().stats();
        let (invalidations, evicted) = trainer.cache().eviction_stats();
        println!(
            "plan cache: {hits} hits, {misses} builds, {invalidations} re-keys, \
             {evicted} plans evicted, {} structures live",
            trainer.cache().structures().len()
        );
        save_native_checkpoint(args, &trainer)?;
        return Ok(());
    }
    anyhow::ensure!(
        args.get("milestones").is_none(),
        "--milestones only applies with --gradual"
    );
    println!(
        "xla feature disabled — native plan-cached trainer \
         (MLP {in_dim}->{hidden}->{classes}, RBGP4 mask @ {:.1}% sparsity)",
        sp * 100.0
    );
    let mut trainer = NativeTrainer::new(in_dim, hidden, classes, Pattern::Rbgp4, sp, config)?;
    if let Some(load) = args.get("load") {
        trainer.load_checkpoint(std::path::Path::new(load))?;
        println!("loaded checkpoint {load}");
    }
    trainer.run()?;
    let (hits, misses) = trainer.cache().stats();
    println!("plan cache: {hits} hits, {misses} builds");
    save_native_checkpoint(args, &trainer)?;
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn save_native_checkpoint(args: &Args, trainer: &NativeTrainer) -> anyhow::Result<()> {
    if let Some(save) = args.get("save") {
        trainer.save_checkpoint(std::path::Path::new(save))?;
        println!(
            "saved checkpoint {save} (structure {:016x}; serve it with \
             `rbgp serve --model name={save}`)",
            trainer.structure_hash()
        );
    }
    Ok(())
}

/// Parse a quota value: `0` = unlimited, a fraction in `(0, 1)` = fair
/// share of the queue capacity, an integer ≥ 1 = absolute cap.
fn parse_quota(text: &str, flag: &str) -> anyhow::Result<rbgp::coordinator::ModelQuota> {
    use rbgp::coordinator::ModelQuota;
    let v: f64 = text
        .parse()
        .map_err(|_| anyhow::anyhow!("{flag} expects a count or a fraction, got '{text}'"))?;
    anyhow::ensure!(
        v.is_finite() && v >= 0.0,
        "{flag} expects a non-negative number, got '{text}'"
    );
    if v == 0.0 {
        Ok(ModelQuota::Unlimited)
    } else if v < 1.0 {
        Ok(ModelQuota::FairShare(v))
    } else {
        anyhow::ensure!(
            v.fract() == 0.0,
            "{flag}: a quota above 1 must be a whole request count, got '{text}'"
        );
        Ok(ModelQuota::Absolute(v as usize))
    }
}

/// Parse `--max-starvation-ms`. `0` used to *silently disable* aging
/// promotion while reading like "promote immediately" — and worse, some
/// period math divided by it. It is now rejected at parse time; pass a
/// period ≥ 1 ms (or a very large one to approximate strict priority
/// with no promotion). The queue itself treats a literal
/// `Duration::ZERO` as promote-immediately, so embedders that want pure
/// arrival order can opt in programmatically.
fn parse_max_starvation_ms(ms: u64) -> anyhow::Result<Option<Duration>> {
    anyhow::ensure!(
        ms > 0,
        "--max-starvation-ms 0 is ambiguous (it used to silently disable aging \
         promotion): pass a period ≥ 1 ms, or a very large period to approximate \
         strict priority"
    );
    Ok(Some(Duration::from_millis(ms)))
}

/// Split a `--tenant` spec `key=quota` into the tenant key and its quota
/// class (same grammar as `--model-quota`).
fn parse_tenant_spec(spec: &str) -> anyhow::Result<(String, rbgp::coordinator::ModelQuota)> {
    let (key, quota) = split_assign("tenant", spec)?;
    Ok((key.to_string(), parse_quota(quota, "--tenant quota")?))
}

/// Split a `--model` spec `name=path[@quota]`. A trailing `@Q` is a quota
/// override only when `Q` parses as a quota; otherwise the `@` belongs to
/// the path.
#[cfg(not(feature = "xla"))]
fn parse_model_spec(
    spec: &str,
) -> anyhow::Result<(String, String, Option<rbgp::coordinator::ModelQuota>)> {
    let (name, rest) = spec
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("--model expects name=checkpoint.json[@quota], got '{spec}'"))?;
    if let Some((path, q)) = rest.rsplit_once('@') {
        if let Ok(quota) = parse_quota(q, "--model quota") {
            return Ok((name.to_string(), path.to_string(), Some(quota)));
        }
    }
    Ok((name.to_string(), rest.to_string(), None))
}

fn serve_cmd(args: &Args) -> anyhow::Result<()> {
    let total = args.get_usize("requests", 512)?;
    let clients = args.get_usize("clients", 4)?.max(1);
    let workers = args.get_usize("workers", 2)?.max(1);
    let queue_cap = args.get_usize("queue-cap", 1024)?;
    let deadline = match args.get_u64("deadline-ms", 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    let max_starvation = parse_max_starvation_ms(args.get_u64("max-starvation-ms", 1000)?)?;
    let model_quota = match args.get("model-quota") {
        Some(text) => parse_quota(text, "--model-quota")?,
        None => rbgp::coordinator::ModelQuota::Unlimited,
    };
    let tune_cache_path = args.get("tune-cache").map(PathBuf::from);
    let retune_threshold = match args.get_f64("retune-threshold", 0.7)? {
        t if t <= 0.0 => None,
        t => Some(t),
    };
    let base_config = ServerConfig {
        workers,
        queue_cap,
        default_deadline: deadline,
        max_starvation,
        model_quota,
        tune_cache: tune_cache_path.clone(),
        retune_threshold,
        ..ServerConfig::default()
    };
    let model_flags = args.get_all("model");
    // One route per served model: the id clients submit under (None = the
    // default model) plus that model's input width and class count.
    let mut routes: Vec<(Option<String>, usize, usize)> = Vec::new();
    #[cfg(feature = "xla")]
    let server = {
        anyhow::ensure!(
            model_flags.is_empty(),
            "--model requires the native backend (the xla path serves one AOT \
             artifact); rebuild without `--features xla`"
        );
        let dir = artifacts_dir(args);
        println!("starting inference server from {} …", dir.display());
        InferenceServer::start(
            dir,
            ServerConfig {
                checkpoint: args.get("checkpoint").map(std::path::PathBuf::from),
                ..base_config
            },
        )?
    };
    #[cfg(not(feature = "xla"))]
    let server = {
        let _ = artifacts_dir(args); // artifacts unused without PJRT
        anyhow::ensure!(
            args.get("checkpoint").is_none(),
            "--checkpoint requires the `xla` feature (checkpoints target the AOT artifact); \
             the native backend serves trained models via --model name=ckpt.json"
        );
        let batch = args.get_usize("batch", 16)?;
        let tune = TuneMode::parse(&args.get_str("tune", "quick"))?;
        // Divide the cores across the pool: N workers each running an
        // all-cores kernel would oversubscribe the CPU N-fold (and carry
        // N× the per-thread pack arenas in their detached plans).
        let threads = (rbgp::util::threadpool::default_threads() / workers).max(1);
        // One plan cache for the whole pool and every registered model:
        // plan builds scale with distinct structures, not models × workers.
        let cache = std::sync::Arc::new(rbgp::kernels::PlanCache::new());
        // Attach the persistent tuning cache *before* any factory warms:
        // even the first worker's schedule search then warm-starts from
        // the file (zero measurement reps on a warm cache) and newly
        // searched winners are recorded for the next process.
        if let Some(path) = &tune_cache_path {
            let tc = rbgp::kernels::TuneCache::open(path);
            println!(
                "tune cache {}: {} entries loaded ({} rejected)",
                path.display(),
                tc.len(),
                tc.rejected_entries()
            );
            cache.attach_tune_cache(tc);
        }
        if model_flags.is_empty() {
            println!(
                "xla feature disabled — serving the native RBGP4 demo model from the plan cache"
            );
            let seed = args.get_u64("seed", 0)?;
            let model_cache = std::sync::Arc::clone(&cache);
            InferenceServer::start_model(
                move || {
                    let mut model = NativeSparseModel::rbgp4_demo(
                        16,
                        batch,
                        threads,
                        seed,
                        std::sync::Arc::clone(&model_cache),
                    )?
                    .with_tune(tune);
                    model.warm()?;
                    Ok(Box::new(model) as Box<dyn BatchModel>)
                },
                base_config,
            )?
        } else {
            // Multi-model registry path: every `--model name=ckpt.json[@Q]`
            // joins the same pool; the first named model doubles as the
            // default route. A per-model `@Q` quota overrides the
            // server-wide --model-quota for that model.
            let mut checkpoints = Vec::new();
            for spec in &model_flags {
                let (name, path, quota) = parse_model_spec(spec)?;
                let ckpt = rbgp::coordinator::NativeCheckpoint::load(std::path::Path::new(&path))?;
                println!(
                    "model '{name}': {}→{}→{} from {path} (structure {:016x}{})",
                    ckpt.in_dim,
                    ckpt.hidden,
                    ckpt.classes,
                    ckpt.structure_hash(),
                    match quota {
                        Some(q) => format!(", quota {q:?}"),
                        None => String::new(),
                    }
                );
                checkpoints.push((name, ckpt, quota));
            }
            let (first_name, first, first_quota) = &checkpoints[0];
            let server = InferenceServer::start_model_as(
                first_name,
                first.serving_factory_tuned(batch, threads, std::sync::Arc::clone(&cache), tune),
                ServerConfig {
                    // The initial model registers through the config-level
                    // quota; apply its per-model override there.
                    model_quota: first_quota.unwrap_or(base_config.model_quota),
                    ..base_config.clone()
                },
            )?;
            for (name, ckpt, quota) in &checkpoints[1..] {
                let factory =
                    ckpt.serving_factory_tuned(batch, threads, std::sync::Arc::clone(&cache), tune);
                // Always pass an explicit quota: the server-level default
                // was overridden to the *first* model's `@Q` above, and a
                // later model without its own override must get the
                // --model-quota default, not that first override.
                server.register_model_with_quota(
                    name,
                    quota.unwrap_or(base_config.model_quota),
                    factory,
                )?;
            }
            for (name, ckpt, _) in &checkpoints {
                routes.push((Some(name.clone()), ckpt.in_dim, ckpt.classes));
            }
            let (hits, misses) = cache.stats();
            println!(
                "registered {} models on one pool: {} structures live, \
                 {misses} plan builds, {hits} cache hits",
                checkpoints.len(),
                cache.structures().len()
            );
            server
        }
    };
    if routes.is_empty() {
        routes.push((None, server.in_dim, server.classes));
    }
    // Rollout staging. Aliases join the round-robin routes so the demo
    // traffic exercises them alongside direct submits; canary/shadow stage
    // a second model behind an alias before the traffic phase starts.
    for spec in args.get_all("alias") {
        let (name, target) = split_assign("alias", spec)?;
        server.set_alias(name, target)?;
        let (in_dim, classes) = routes
            .iter()
            .find(|(m, _, _)| m.as_deref() == Some(target))
            .map(|(_, i, c)| (*i, *c))
            .unwrap_or((server.in_dim, server.classes));
        routes.push((Some(name.to_string()), in_dim, classes));
        println!("alias '{name}' → '{target}'");
    }
    for spec in args.get_all("canary") {
        let (alias, leg) = split_assign("canary", spec)?;
        let (target, pct) = leg
            .rsplit_once('@')
            .ok_or_else(|| anyhow::anyhow!("--canary expects alias=model@pct, got '{spec}'"))?;
        let pct: u8 = pct
            .parse()
            .map_err(|_| anyhow::anyhow!("--canary percent must be 1..=100, got '{pct}'"))?;
        server.set_canary(alias, target, pct)?;
        println!("canary '{alias}': {pct}% → '{target}'");
    }
    for spec in args.get_all("shadow") {
        let (alias, target) = split_assign("shadow", spec)?;
        server.set_shadow(alias, target)?;
        println!("shadow '{alias}' → '{target}'");
    }
    // Network front-end: with --listen the demo clients become real TCP
    // connections speaking the binary protocol; without it they submit
    // in-process exactly as before.
    let tenants = args
        .get_all("tenant")
        .iter()
        .map(|s| parse_tenant_spec(s))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let listen = args.get("listen");
    anyhow::ensure!(
        tenants.is_empty() || listen.is_some(),
        "--tenant quotas apply to the network front-end; add --listen ADDR"
    );
    let frontend = match listen {
        Some(addr) => {
            let fe = Frontend::start(
                server.clone(),
                FrontendConfig {
                    listen: addr.to_string(),
                    tenants: tenants.clone(),
                    ..FrontendConfig::default()
                },
            )?;
            println!(
                "front-end listening on {} ({} tenant quota classes)",
                fe.local_addr(),
                tenants.len()
            );
            Some(fe)
        }
        None => None,
    };
    let fe_addr = frontend.as_ref().map(|f| f.local_addr());
    // Each client thread submits under one tenant key, cycling through the
    // configured classes so quota admission actually gets exercised.
    let tenant_keys: Vec<String> = if tenants.is_empty() {
        vec!["demo".to_string()]
    } else {
        tenants.iter().map(|(k, _)| k.clone()).collect()
    };
    let deadline_ms_wire = deadline.map(|d| d.as_millis() as u32).unwrap_or(0);
    println!(
        "default model: in_dim {}, classes {}, max batch {} × {} workers, queue cap {}",
        server.in_dim,
        server.classes,
        server.batch,
        server.workers(),
        server.queue_capacity()
    );
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = server.clone();
            let routes = &routes;
            let tenant = tenant_keys[c % tenant_keys.len()].clone();
            scope.spawn(move || {
                let mut net =
                    fe_addr.map(|addr| FrontendClient::connect(addr).expect("connect front-end"));
                let mut data: Vec<CifarLike> = routes
                    .iter()
                    .map(|(_, in_dim, classes)| CifarLike::new(*in_dim, *classes, c as u64))
                    .collect();
                let per = total / clients;
                for r in 0..per {
                    // Round-robin across the served models, offset per
                    // client so mixed-model traffic hits every worker.
                    let route = (c + r) % routes.len();
                    let (model, _, classes) = &routes[route];
                    let b = data[route].test_batch(1);
                    if let Some(net) = net.as_mut() {
                        let resp = net
                            .infer(
                                b.x,
                                model.as_deref(),
                                rbgp::coordinator::Priority::Normal,
                                &tenant,
                                deadline_ms_wire,
                            )
                            .expect("front-end io");
                        match resp.status {
                            Status::Ok => assert_eq!(resp.payload.len(), *classes),
                            // Backpressure statuses mirror the in-process
                            // arm's tolerated rejections, plus the
                            // front-end-only tenant class.
                            Status::QueueFull
                            | Status::DeadlineExceeded
                            | Status::ModelQuotaExceeded
                            | Status::TenantQuotaExceeded => {}
                            s => panic!("front-end infer failed: {s}: {}", resp.detail),
                        }
                        continue;
                    }
                    let opts = match model {
                        Some(m) => SubmitOptions::default().with_model(m.clone()),
                        None => SubmitOptions::default(),
                    };
                    match server.infer_with(b.x, opts) {
                        Ok(logits) => assert_eq!(logits.len(), *classes),
                        // Under a --deadline-ms budget or a --model-quota,
                        // expiry and admission rejections are expected
                        // load-shedding, not failures; rejected() /
                        // rejected_quota() report them.
                        Err(ServeError::DeadlineExceeded { .. }) => {}
                        Err(ServeError::ModelQuotaExceeded { .. }) => {}
                        Err(e) => panic!("infer failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let (reqs, batches) = server.counters();
    println!("served {reqs} requests in {batches} batches over {wall:.2}s");
    println!("  throughput: {:.1} req/s", reqs as f64 / wall);
    // All-rejected runs (tight --deadline-ms) have no latency samples.
    if let Some(stats) = server.latency_stats() {
        println!(
            "  latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}",
            stats.p50 * 1e3,
            stats.p95 * 1e3,
            stats.p99 * 1e3,
            stats.max * 1e3
        );
        println!(
            "  batch occupancy: {:.1}%  peak queue depth: {}",
            stats.occupancy * 100.0,
            server.peak_queue_depth()
        );
    }
    let (rej_full, rej_late) = server.rejected();
    let rej_quota = server.rejected_quota();
    if rej_full + rej_late + rej_quota > 0 {
        println!(
            "  rejected: {rej_full} backpressure, {rej_late} deadline-expired, \
             {rej_quota} over model quota"
        );
    }
    if server.steals() > 0 {
        println!("  work steals: {} straggler windows cut for other models", server.steals());
    }
    for w in server.worker_stats() {
        println!(
            "    worker {}: {} reqs in {} batches (occupancy {:.1}%, {} steals)",
            w.worker,
            w.requests,
            w.batches,
            w.occupancy() * 100.0,
            w.steals
        );
    }
    if routes.len() > 1 {
        for m in server.model_stats() {
            println!(
                "    model '{}': {} reqs in {} batches (occupancy {:.1}%, \
                 {} deadline-rejected, {} quota-rejected, {} errors)",
                m.model,
                m.requests,
                m.batches,
                m.occupancy() * 100.0,
                m.rejected_deadline,
                m.rejected_quota,
                m.errors
            );
        }
    }
    for a in server.alias_stats() {
        let lat = match &a.latency {
            Some(l) => format!(", p50 {:.2} ms, p99 {:.2} ms", l.p50 * 1e3, l.p99 * 1e3),
            None => String::new(),
        };
        println!(
            "    alias '{}': {} reqs, {:.1}% canary{lat}",
            a.alias,
            a.requests,
            a.canary_fraction() * 100.0
        );
        if a.shadow_samples + a.shadow_dropped > 0 {
            println!(
                "      shadow divergence: {} samples, mean {:.3e}, max {:.3e}, {} dropped; \
                 hist(≤1e-6,1e-4,1e-3,1e-2,1e-1,∞) {:?}",
                a.shadow_samples, a.shadow_mean, a.shadow_max, a.shadow_dropped, a.shadow_hist
            );
        }
    }
    // Per-structure tuned-schedule summaries: what the search picked, how
    // close to the roofline it landed, and how achieved throughput tracked
    // it over the run (the drift re-tune trigger's inputs).
    for m in server.model_stats() {
        for t in &m.tuned {
            let drift = match (t.ewma_gflops, t.drift()) {
                (Some(e), Some(d)) => {
                    format!(", achieved {e:.2} GFLOP/s = {:.0}% of tuned", d * 100.0)
                }
                (Some(e), None) => format!(", achieved {e:.2} GFLOP/s (warming)"),
                _ => String::new(),
            };
            println!(
                "    tuned '{}' {} [{:016x}]: {} — {:.2} GFLOP/s, {:.0}% of roofline{}",
                m.model,
                t.layer,
                t.structure,
                t.params,
                t.tuned_gflops,
                t.roofline_fraction * 100.0,
                drift
            );
        }
        if m.retunes > 0 {
            println!("      model '{}': {} drift re-tunes", m.model, m.retunes);
        }
    }
    // Post-traffic rollout demo: atomically flip the alias, then drain and
    // retire the old primary — the full zero-downtime sequence.
    for spec in args.get_all("promote") {
        let (alias, target) = split_assign("promote", spec)?;
        let t0 = std::time::Instant::now();
        let report = server.rollout(alias, target)?;
        println!(
            "rollout '{alias}' → '{target}' in {:.1} ms: retired '{}' \
             ({} drained in-flight, {} structures evicted / {} retained, {} plans evicted)",
            t0.elapsed().as_secs_f64() * 1e3,
            report.model,
            report.drained_requests,
            report.evicted_structures.len(),
            report.retained_structures.len(),
            report.evicted_plans
        );
    }
    // Drain the front-end before the server: open connections finish
    // their in-flight responses while workers are still alive to answer.
    if let Some(fe) = frontend {
        let (accepted, rejected, shed) = server.frontend_totals();
        println!("  front-end: {accepted} accepted, {rejected} rejected, {shed} shed");
        fe.shutdown();
    }
    server.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use rbgp::coordinator::ModelQuota;

    #[test]
    fn zero_starvation_period_is_rejected_at_parse_time() {
        let err = parse_max_starvation_ms(0).expect_err("0 must be rejected");
        assert!(
            err.to_string().contains("ambiguous"),
            "rejection should explain the former silent-disable: {err}"
        );
        assert_eq!(
            parse_max_starvation_ms(250).expect("valid period"),
            Some(Duration::from_millis(250))
        );
    }

    #[test]
    fn tenant_spec_parses_every_quota_class() {
        let (key, quota) = parse_tenant_spec("team-a=0.5").expect("fair share");
        assert_eq!(key, "team-a");
        assert_eq!(quota, ModelQuota::FairShare(0.5));
        let (key, quota) = parse_tenant_spec("team-b=16").expect("absolute");
        assert_eq!(key, "team-b");
        assert_eq!(quota, ModelQuota::Absolute(16));
        let (key, quota) = parse_tenant_spec("team-c=0").expect("unlimited");
        assert_eq!(key, "team-c");
        assert_eq!(quota, ModelQuota::Unlimited);
        assert!(parse_tenant_spec("no-quota").is_err(), "missing '=' must be rejected");
        assert!(parse_tenant_spec("team-d=1.5").is_err(), "fractional >1 must be rejected");
    }
}
