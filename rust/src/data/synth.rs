//! CIFAR-like synthetic classification data.
//!
//! Construction: each class `c` has a latent prototype `z_c ∈ R^L`; a sample
//! is `tanh(P·(z_c + σ·ε))` with a fixed random projection `P ∈ R^{D×L}`
//! and Gaussian noise `ε`. With σ below the prototype separation the task
//! is learnable to high accuracy but requires mixing many input dimensions
//! — exactly what distinguishes well-connected masks from badly-connected
//! ones.

use crate::util::rng::Rng;

/// One batch: `x` is (batch × dim) row-major, `y` one-hot (batch × classes).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub labels: Vec<usize>,
    pub batch: usize,
    pub dim: usize,
    pub classes: usize,
}

/// Deterministic synthetic dataset generator.
pub struct CifarLike {
    pub dim: usize,
    pub classes: usize,
    latent: usize,
    noise: f32,
    /// (classes × latent) prototypes.
    prototypes: Vec<f32>,
    /// (dim × latent) fixed projection.
    proj: Vec<f32>,
    train_rng: Rng,
    test_rng: Rng,
}

impl CifarLike {
    /// `dim` input features (e.g. 1024 ≈ a 32×32 grayscale image), `classes`
    /// labels. The structure (prototypes, projection) depends only on
    /// `seed`; train and test sample streams are disjoint forks.
    pub fn new(dim: usize, classes: usize, seed: u64) -> CifarLike {
        let latent = (dim / 16).clamp(8, 64);
        let mut rng = Rng::new(seed);
        let prototypes = rng.normal_vec_f32(classes * latent, 1.0);
        let scale = (1.0 / latent as f64).sqrt() as f32;
        let proj = rng.normal_vec_f32(dim * latent, scale);
        let train_rng = rng.fork();
        let test_rng = rng.fork();
        CifarLike {
            dim,
            classes,
            latent,
            noise: 0.35,
            prototypes,
            proj,
            train_rng,
            test_rng,
        }
    }

    fn sample_into(&self, rng: &mut Rng, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = vec![0.0f32; batch * self.classes];
        let mut labels = Vec::with_capacity(batch);
        let mut z = vec![0.0f32; self.latent];
        for b in 0..batch {
            let c = rng.below_usize(self.classes);
            labels.push(c);
            y[b * self.classes + c] = 1.0;
            let proto = &self.prototypes[c * self.latent..(c + 1) * self.latent];
            for (zi, &p) in z.iter_mut().zip(proto) {
                *zi = p + self.noise * rng.normal_f32();
            }
            let xrow = &mut x[b * self.dim..(b + 1) * self.dim];
            for (d, xv) in xrow.iter_mut().enumerate() {
                let prow = &self.proj[d * self.latent..(d + 1) * self.latent];
                let mut s = 0.0f32;
                for (p, zv) in prow.iter().zip(&z) {
                    s += p * zv;
                }
                *xv = s.tanh();
            }
        }
        Batch {
            x,
            y,
            labels,
            batch,
            dim: self.dim,
            classes: self.classes,
        }
    }

    /// Override the within-class noise level (default 0.35). Higher noise
    /// makes the task harder — used by the accuracy-parity experiment to
    /// keep patterns below ceiling.
    pub fn with_noise(mut self, noise: f32) -> CifarLike {
        self.noise = noise;
        self
    }

    /// Next training batch (advances the train stream).
    pub fn train_batch(&mut self, batch: usize) -> Batch {
        let mut rng = self.train_rng.clone();
        let b = self.sample_into(&mut rng, batch);
        self.train_rng = rng;
        b
    }

    /// Next held-out batch (advances the test stream).
    pub fn test_batch(&mut self, batch: usize) -> Batch {
        let mut rng = self.test_rng.clone();
        let b = self.sample_into(&mut rng, batch);
        self.test_rng = rng;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_one_hot() {
        let mut ds = CifarLike::new(64, 10, 7);
        let b = ds.train_batch(16);
        assert_eq!(b.x.len(), 16 * 64);
        assert_eq!(b.y.len(), 16 * 10);
        for i in 0..16 {
            let row = &b.y[i * 10..(i + 1) * 10];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row[b.labels[i]], 1.0);
        }
        assert!(b.x.iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = CifarLike::new(32, 4, 9);
        let mut b = CifarLike::new(32, 4, 9);
        assert_eq!(a.train_batch(8).x, b.train_batch(8).x);
        let mut c = CifarLike::new(32, 4, 10);
        assert_ne!(a.train_batch(8).x, c.train_batch(8).x);
    }

    #[test]
    fn train_and_test_streams_differ() {
        let mut ds = CifarLike::new(32, 4, 11);
        let tr = ds.train_batch(8);
        let te = ds.test_batch(8);
        assert_ne!(tr.x, te.x);
    }

    #[test]
    fn consecutive_batches_differ() {
        let mut ds = CifarLike::new(32, 4, 12);
        let b1 = ds.train_batch(8);
        let b2 = ds.train_batch(8);
        assert_ne!(b1.x, b2.x);
    }

    #[test]
    fn task_linearly_separable_from_prototypes() {
        // Nearest-prototype-in-latent classification via the projection
        // pseudo-structure should beat chance by a wide margin: verify the
        // task carries signal (not noise) by checking same-class samples
        // are closer than cross-class on average.
        let mut ds = CifarLike::new(128, 4, 13);
        let b = ds.train_batch(64);
        let dist = |i: usize, j: usize| -> f32 {
            let (xi, xj) = (&b.x[i * 128..(i + 1) * 128], &b.x[j * 128..(j + 1) * 128]);
            xi.iter().zip(xj).map(|(a, c)| (a - c) * (a - c)).sum()
        };
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0f64, 0usize, 0.0f64, 0usize);
        for i in 0..64 {
            for j in (i + 1)..64 {
                if b.labels[i] == b.labels[j] {
                    same += dist(i, j) as f64;
                    same_n += 1;
                } else {
                    diff += dist(i, j) as f64;
                    diff_n += 1;
                }
            }
        }
        assert!(same / same_n as f64 * 1.5 < diff / diff_n as f64);
    }
}
