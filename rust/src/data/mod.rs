//! Synthetic datasets (DESIGN.md §Substitutions: CIFAR → CIFAR-like).
//!
//! The accuracy claim of Table 1 is about *mask connectivity*, which is
//! scale-free; we exercise it with a separable-but-not-trivial synthetic
//! task: class-conditional Gaussian clusters pushed through a fixed random
//! nonlinear projection, normalized like image data. The generator is
//! deterministic per seed, with disjoint train/test streams.

pub mod synth;

pub use synth::{Batch, CifarLike};
