//! Lightweight metrics: loss history, latency percentiles, throughput.

use std::time::Duration;

/// Rolling metrics store shared by the trainer and server.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub losses: Vec<(usize, f32)>,
    pub latencies: Vec<f64>,
    pub requests: usize,
    pub batches: usize,
}

impl Metrics {
    pub fn record_loss(&mut self, step: usize, loss: f32) {
        self.losses.push((step, loss));
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies.push(d.as_secs_f64());
        self.requests += 1;
    }

    pub fn record_batch(&mut self) {
        self.batches += 1;
    }

    pub fn latency_stats(&self) -> Option<LatencyStats> {
        LatencyStats::from_samples(&self.latencies)
    }

    /// Smoothed final loss: mean of the last `k` recorded losses.
    pub fn final_loss(&self, k: usize) -> Option<f32> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(k)..];
        Some(tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32)
    }
}

/// Latency percentile summary (seconds).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> Option<LatencyStats> {
        if samples.is_empty() {
            return None;
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let rank = (p / 100.0 * (s.len() - 1) as f64).round() as usize;
            s[rank.min(s.len() - 1)]
        };
        Some(LatencyStats {
            count: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: *s.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_history_and_smoothing() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_loss(i, 10.0 - i as f32);
        }
        assert_eq!(m.losses.len(), 10);
        // last 2: 2.0, 1.0 -> mean 1.5
        assert_eq!(m.final_loss(2), Some(1.5));
        assert_eq!(Metrics::default().final_loss(3), None);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let s = LatencyStats::from_samples(&samples).unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 0.050).abs() < 0.002);
        assert_eq!(s.max, 0.1);
    }

    #[test]
    fn empty_latency_is_none() {
        assert!(LatencyStats::from_samples(&[]).is_none());
    }
}
