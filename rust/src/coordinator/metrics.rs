//! Lightweight metrics: loss history, latency percentiles, throughput, and
//! the lock-free serving counters.
//!
//! Two stores live here:
//!
//! * [`Metrics`] — the single-owner store the trainers mutate directly
//!   (`&mut self` methods; loss history, eval latencies).
//! * [`ServingMetrics`] — the shared store the multi-worker inference
//!   server records into. All counters are atomics; latency samples live
//!   in one *bounded* ring per worker (no pool-wide lock on the request
//!   hot path, O(1) memory for a long-lived server), and every lock goes
//!   through [`lock_recover`], so a worker that dies mid-record degrades
//!   the metrics instead of poisoning them and panicking every client
//!   that later asks for stats.

use crate::util::lock_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Single-owner metrics store used by the trainers.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub losses: Vec<(usize, f32)>,
    pub latencies: Vec<f64>,
    pub requests: usize,
    pub batches: usize,
    /// Real samples in recorded batches (see [`Metrics::record_batch_occupancy`]).
    pub occupied_slots: usize,
    /// Total slots in recorded batches; 0 when the recorder never pads.
    pub batch_slots: usize,
}

impl Metrics {
    pub fn record_loss(&mut self, step: usize, loss: f32) {
        self.losses.push((step, loss));
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies.push(d.as_secs_f64());
        self.requests += 1;
    }

    /// Count one executed batch with no padding accounting (training steps,
    /// which always run full batches).
    pub fn record_batch(&mut self) {
        self.batches += 1;
    }

    /// Count one executed batch of `slots` capacity carrying `occupied`
    /// real samples — the padded remainder is what a dynamic batcher
    /// silently wastes, so it must be recorded, not counted as throughput.
    pub fn record_batch_occupancy(&mut self, occupied: usize, slots: usize) {
        self.batches += 1;
        self.occupied_slots += occupied.min(slots);
        self.batch_slots += slots;
    }

    /// Mean fraction of batch slots holding real samples (1.0 when the
    /// recorder never tracked occupancy).
    pub fn occupancy(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.occupied_slots as f64 / self.batch_slots as f64
        }
    }

    pub fn latency_stats(&self) -> Option<LatencyStats> {
        LatencyStats::from_samples(&self.latencies).map(|s| s.with_occupancy(self.occupancy()))
    }

    /// Smoothed final loss: mean of the last `k` recorded losses.
    pub fn final_loss(&self, k: usize) -> Option<f32> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(k)..];
        Some(tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32)
    }
}

/// Latency percentile summary (seconds) plus the batch-occupancy gauge of
/// the path that produced the samples.
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    /// Mean fraction of executed batch slots that carried real samples —
    /// 1.0 means every flush was full; a padded partial flush pulls it
    /// below 1.0. Paths that never pad report 1.0.
    pub occupancy: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> Option<LatencyStats> {
        if samples.is_empty() {
            return None;
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let rank = (p / 100.0 * (s.len() - 1) as f64).round() as usize;
            s[rank.min(s.len() - 1)]
        };
        Some(LatencyStats {
            count: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: *s.last().unwrap(),
            occupancy: 1.0,
        })
    }

    pub fn with_occupancy(mut self, occupancy: f64) -> LatencyStats {
        self.occupancy = occupancy;
        self
    }
}

/// Per-worker atomic counters (one slot per worker thread, no sharing).
#[derive(Default)]
struct WorkerCounters {
    requests: AtomicUsize,
    batches: AtomicUsize,
    occupied_slots: AtomicUsize,
    batch_slots: AtomicUsize,
    errors: AtomicUsize,
    steals: AtomicUsize,
}

/// Snapshot of one worker's counters.
#[derive(Clone, Copy, Debug)]
pub struct WorkerStats {
    pub worker: usize,
    /// Requests this worker answered successfully.
    pub requests: usize,
    /// Batches this worker executed.
    pub batches: usize,
    /// Real samples across those batches.
    pub occupied_slots: usize,
    /// Total slots across those batches (occupied + padding).
    pub batch_slots: usize,
    /// Batch executions that failed.
    pub errors: usize,
    /// Straggler windows this worker cut short to serve another model's
    /// backlog instead of idling (work steals).
    pub steals: usize,
}

impl WorkerStats {
    /// Mean fraction of this worker's batch slots holding real samples.
    pub fn occupancy(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.occupied_slots as f64 / self.batch_slots as f64
        }
    }
}

/// Cap on retained latency samples *per worker*: percentiles are computed
/// over a sliding window so a long-lived server's stats cost stays O(1)
/// in memory and sort time instead of growing with every request ever
/// served.
const LATENCY_WINDOW: usize = 4096;

/// Fixed-capacity ring of the most recent latency samples.
#[derive(Default)]
struct LatencyRing {
    samples: Vec<f64>,
    next: usize,
}

impl LatencyRing {
    fn push(&mut self, v: f64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }
}

/// Per-structure tuning snapshot a plan-cached backend reports: the
/// persisted/searched winner next to the EWMA of GFLOP/s achieved on real
/// flushes — what an operator reads to see whether a tuned schedule has
/// gone stale (and what the drift re-tuner acts on).
#[derive(Clone, Debug)]
pub struct TunedStatus {
    /// Which layer/weight of the model this plan serves (backend-defined
    /// label, e.g. `"w1"`).
    pub layer: String,
    /// Structure hash of the sparse matrix the plan was built for.
    pub structure: u64,
    /// The winning schedule's candidate label.
    pub params: String,
    /// GFLOP/s the schedule search recorded for the winner.
    pub tuned_gflops: f64,
    /// Winner throughput as a fraction of the machine's roofline.
    pub roofline_fraction: f64,
    /// EWMA of GFLOP/s achieved on real serving flushes (None until the
    /// first flush lands).
    pub ewma_gflops: Option<f64>,
    /// Flushes folded into the EWMA.
    pub samples: usize,
}

/// A drift ratio below this many samples is noise, not a trend: the EWMA
/// must see at least this many flushes before [`TunedStatus::drift`]
/// reports anything.
pub const DRIFT_MIN_SAMPLES: usize = 8;

impl TunedStatus {
    /// Achieved/recorded throughput ratio (1.0 = the plan still delivers
    /// what the search measured; below the server's `retune_threshold`
    /// triggers a background re-tune). `None` until the EWMA has
    /// [`DRIFT_MIN_SAMPLES`] flushes or when the recorded figure is
    /// degenerate.
    pub fn drift(&self) -> Option<f64> {
        let ewma = self.ewma_gflops?;
        if self.samples < DRIFT_MIN_SAMPLES || !(self.tuned_gflops > 0.0) {
            return None;
        }
        Some(ewma / self.tuned_gflops)
    }
}

/// Upper edges of the shadow-divergence histogram buckets: per-request
/// max-abs logit divergence between an alias's primary and shadow legs.
/// Log-spaced, because the regimes that matter are qualitative —
/// bit-identical-ish (≤1e-6), rounding-level noise, and genuinely
/// different predictions; the final bucket catches everything above 0.1.
pub const DIVERGENCE_BUCKETS: [f64; 6] = [1e-6, 1e-4, 1e-3, 1e-2, 1e-1, f64::INFINITY];

/// Running tallies for one alias: SLO latency window, canary split, and
/// the shadow-divergence accumulators. Unlike [`ModelTally`], the latency
/// ring *is* fed per request — per-alias p50/p99 is the point — so alias
/// traffic pays one short map-lock per answered request; direct
/// (alias-less) submits never touch this map.
#[derive(Default)]
struct AliasTally {
    /// Client requests answered through this alias (mirrors excluded).
    requests: usize,
    /// Of those, how many the deterministic key routed to the canary leg.
    canary: usize,
    latencies: LatencyRing,
    shadow_samples: usize,
    shadow_sum: f64,
    shadow_max: f64,
    shadow_hist: [usize; DIVERGENCE_BUCKETS.len()],
    /// Mirrors never executed: push rejected (queue/quota pressure) or
    /// deadline lapsed before the mirror's Low-priority turn came up.
    shadow_dropped: usize,
}

/// Snapshot of one alias's rollout telemetry (see
/// [`ServingMetrics::alias_stats`]).
#[derive(Clone, Debug)]
pub struct AliasStats {
    pub alias: String,
    /// Client requests answered through this alias (shadow mirrors are
    /// not client requests and are excluded).
    pub requests: usize,
    /// Of those, requests served by the canary leg.
    pub canary: usize,
    /// Queue→response percentiles over this alias's recent window; `None`
    /// before the first answered request.
    pub latency: Option<LatencyStats>,
    /// Completed shadow comparisons (both legs flushed).
    pub shadow_samples: usize,
    /// Mean max-abs logit divergence over those samples.
    pub shadow_mean: f64,
    /// Largest max-abs logit divergence observed.
    pub shadow_max: f64,
    /// Divergence histogram; bucket `i` counts samples ≤
    /// [`DIVERGENCE_BUCKETS`]`[i]` (and above the previous edge).
    pub shadow_hist: Vec<usize>,
    /// Mirrors dropped under load instead of executed (never client-facing).
    pub shadow_dropped: usize,
}

impl AliasStats {
    /// Fraction of this alias's answered requests the canary leg served.
    pub fn canary_fraction(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.canary as f64 / self.requests as f64
        }
    }
}

/// Running tallies for one served model (registry id). Plain counters
/// behind the store's model-map mutex: they are bumped once per *flush*
/// (and per rejection), not per request, so the map lock is off the
/// per-request hot path.
#[derive(Clone, Debug, Default)]
struct ModelTally {
    requests: usize,
    batches: usize,
    occupied_slots: usize,
    batch_slots: usize,
    rejected_deadline: usize,
    rejected_quota: usize,
    errors: usize,
    retunes: usize,
    tuned: Vec<TunedStatus>,
}

/// Snapshot of one model's serving counters (multi-model registry view —
/// the per-*model* axis next to [`WorkerStats`]' per-*worker* axis).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub model: String,
    /// Requests answered successfully for this model.
    pub requests: usize,
    /// Batches flushed for this model (never mixing models).
    pub batches: usize,
    /// Real samples across those batches.
    pub occupied_slots: usize,
    /// Total slots across those batches (occupied + padding).
    pub batch_slots: usize,
    /// Requests for this model rejected because their deadline expired.
    pub rejected_deadline: usize,
    /// Submits for this model rejected at admission because its queue
    /// quota was already saturated.
    pub rejected_quota: usize,
    /// Batch executions for this model that failed.
    pub errors: usize,
    /// Drift-triggered background re-tunes completed for this model.
    pub retunes: usize,
    /// Latest per-structure tuning snapshots (winning schedule, roofline
    /// fraction, achieved-GFLOP/s EWMA) mirrored from a worker's model
    /// instance after flushes; empty for backends without tuned plans.
    pub tuned: Vec<TunedStatus>,
}

impl ModelStats {
    /// Mean fraction of this model's batch slots holding real samples.
    pub fn occupancy(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.occupied_slots as f64 / self.batch_slots as f64
        }
    }
}

/// Shared metrics store for the multi-worker inference server: per-worker
/// atomic counters, per-model tallies, queue gauges, rejection counters,
/// and one bounded latency ring *per worker* (so the request hot path
/// never contends on a pool-wide lock), each locked through the
/// recovering guard.
pub struct ServingMetrics {
    workers: Vec<WorkerCounters>,
    latencies: Vec<Mutex<LatencyRing>>,
    models: Mutex<HashMap<String, ModelTally>>,
    aliases: Mutex<HashMap<String, AliasTally>>,
    rejected_full: AtomicUsize,
    rejected_deadline: AtomicUsize,
    rejected_quota: AtomicUsize,
    peak_queue_depth: AtomicUsize,
    /// Shadow pairs created but not yet settled (gauge). A pair settles
    /// when its last leg's request drops, on any path; a steady-state
    /// nonzero floor here means pairs are leaking.
    shadow_pending: AtomicUsize,
    /// Requests the network front-end admitted into the queue.
    frontend_accepted: AtomicUsize,
    /// Typed-error responses the front-end sent instead of admitting
    /// (submit rejections, tenant quota, malformed frames).
    frontend_rejected: AtomicUsize,
    /// Responses dropped because a slow reader's bounded write buffer was
    /// full (shed-on-overflow: the connection survives, the reply does
    /// not).
    frontend_shed: AtomicUsize,
}

impl ServingMetrics {
    pub fn new(workers: usize) -> ServingMetrics {
        let workers = workers.max(1);
        ServingMetrics {
            workers: (0..workers).map(|_| WorkerCounters::default()).collect(),
            latencies: (0..workers).map(|_| Mutex::new(LatencyRing::default())).collect(),
            models: Mutex::new(HashMap::new()),
            aliases: Mutex::new(HashMap::new()),
            rejected_full: AtomicUsize::new(0),
            rejected_deadline: AtomicUsize::new(0),
            rejected_quota: AtomicUsize::new(0),
            peak_queue_depth: AtomicUsize::new(0),
            shadow_pending: AtomicUsize::new(0),
            frontend_accepted: AtomicUsize::new(0),
            frontend_rejected: AtomicUsize::new(0),
            frontend_shed: AtomicUsize::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// One executed batch on `worker`: `occupied` real samples in `slots`
    /// total slots (padding = `slots - occupied`).
    pub(crate) fn record_flush(&self, worker: usize, occupied: usize, slots: usize) {
        let w = &self.workers[worker];
        w.batches.fetch_add(1, Ordering::Relaxed);
        w.occupied_slots.fetch_add(occupied.min(slots), Ordering::Relaxed);
        w.batch_slots.fetch_add(slots, Ordering::Relaxed);
    }

    /// One answered request on `worker` with its queue→response latency.
    /// Only this worker's ring is locked — workers never contend here.
    pub(crate) fn record_latency(&self, worker: usize, d: Duration) {
        self.workers[worker].requests.fetch_add(1, Ordering::Relaxed);
        lock_recover(&self.latencies[worker]).push(d.as_secs_f64());
    }

    pub(crate) fn record_error(&self, worker: usize) {
        self.workers[worker].errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One straggler window `worker` cut short to serve another model's
    /// backlog (a work steal).
    pub(crate) fn record_steal(&self, worker: usize) {
        self.workers[worker].steals.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_rejected_full(&self) {
        self.rejected_full.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_rejected_deadline(&self) {
        self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_rejected_quota(&self) {
        self.rejected_quota.fetch_add(1, Ordering::Relaxed);
    }

    /// One executed batch attributed to `model`: `occupied` answered
    /// requests in `slots` total slots.
    pub(crate) fn record_model_flush(&self, model: &str, occupied: usize, slots: usize) {
        let mut map = lock_recover(&self.models);
        let t = map.entry(model.to_string()).or_default();
        t.requests += occupied.min(slots);
        t.batches += 1;
        t.occupied_slots += occupied.min(slots);
        t.batch_slots += slots;
    }

    pub(crate) fn record_model_rejected_deadline(&self, model: &str) {
        lock_recover(&self.models)
            .entry(model.to_string())
            .or_default()
            .rejected_deadline += 1;
    }

    pub(crate) fn record_model_rejected_quota(&self, model: &str) {
        lock_recover(&self.models)
            .entry(model.to_string())
            .or_default()
            .rejected_quota += 1;
    }

    pub(crate) fn record_model_error(&self, model: &str) {
        lock_recover(&self.models)
            .entry(model.to_string())
            .or_default()
            .errors += 1;
    }

    /// One completed drift-triggered background re-tune for `model`.
    pub(crate) fn record_model_retune(&self, model: &str) {
        lock_recover(&self.models)
            .entry(model.to_string())
            .or_default()
            .retunes += 1;
    }

    /// Mirror the latest tuning snapshots for `model` (overwrites the
    /// previous mirror — this is a gauge, not a counter).
    pub(crate) fn set_model_tuned(&self, model: &str, tuned: Vec<TunedStatus>) {
        lock_recover(&self.models)
            .entry(model.to_string())
            .or_default()
            .tuned = tuned;
    }

    /// Drift-triggered re-tunes completed, all models.
    pub fn retunes(&self) -> usize {
        lock_recover(&self.models).values().map(|t| t.retunes).sum()
    }

    /// One client request answered through `alias` with its queue→response
    /// latency; `canary` marks the requests the deterministic key routed
    /// to the canary leg.
    pub(crate) fn record_alias_latency(&self, alias: &str, canary: bool, d: Duration) {
        let mut map = lock_recover(&self.aliases);
        let t = map.entry(alias.to_string()).or_default();
        t.requests += 1;
        if canary {
            t.canary += 1;
        }
        t.latencies.push(d.as_secs_f64());
    }

    /// One completed shadow comparison for `alias`: the max-abs logit
    /// divergence between the primary and mirror legs of one request.
    pub(crate) fn record_shadow_divergence(&self, alias: &str, d: f64) {
        let mut map = lock_recover(&self.aliases);
        let t = map.entry(alias.to_string()).or_default();
        t.shadow_samples += 1;
        t.shadow_sum += d;
        if d > t.shadow_max {
            t.shadow_max = d;
        }
        let bucket = DIVERGENCE_BUCKETS
            .iter()
            .position(|&edge| d <= edge)
            .unwrap_or(DIVERGENCE_BUCKETS.len() - 1);
        t.shadow_hist[bucket] += 1;
    }

    /// One shadow mirror dropped under load (push rejected, or deadline
    /// lapsed before its Low-priority turn) — lost divergence coverage,
    /// never a client-facing rejection.
    pub(crate) fn record_shadow_dropped(&self, alias: &str) {
        lock_recover(&self.aliases)
            .entry(alias.to_string())
            .or_default()
            .shadow_dropped += 1;
    }

    /// One shadow pair created (raises the pending gauge).
    pub(crate) fn record_shadow_begun(&self) {
        self.shadow_pending.fetch_add(1, Ordering::Relaxed);
    }

    /// One shadow pair settled — completed or abandoned — on its last
    /// leg's drop (lowers the pending gauge).
    pub(crate) fn record_shadow_settled(&self) {
        self.shadow_pending.fetch_sub(1, Ordering::Relaxed);
    }

    /// Shadow pairs currently awaiting at least one leg. Returns to zero
    /// whenever shadow traffic drains — including when mirror legs die
    /// with backend errors (the complete-or-expire contract).
    pub fn shadow_pending(&self) -> usize {
        self.shadow_pending.load(Ordering::Relaxed)
    }

    /// One socket request admitted into the queue by the front-end.
    pub(crate) fn record_frontend_accepted(&self) {
        self.frontend_accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// One socket request answered with a typed error status instead of
    /// being admitted.
    pub(crate) fn record_frontend_rejected(&self) {
        self.frontend_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One response shed because the connection's bounded write buffer
    /// was full (slow reader).
    pub(crate) fn record_frontend_shed(&self) {
        self.frontend_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// `(accepted, rejected, shed)` totals for the network front-end.
    pub fn frontend_totals(&self) -> (usize, usize, usize) {
        (
            self.frontend_accepted.load(Ordering::Relaxed),
            self.frontend_rejected.load(Ordering::Relaxed),
            self.frontend_shed.load(Ordering::Relaxed),
        )
    }

    /// Per-alias rollout telemetry snapshots, sorted by alias. Tallies
    /// survive `remove_alias` — a finished rollout's history stays
    /// reportable.
    pub fn alias_stats(&self) -> Vec<AliasStats> {
        let map = lock_recover(&self.aliases);
        let mut stats: Vec<AliasStats> = map
            .iter()
            .map(|(alias, t)| AliasStats {
                alias: alias.clone(),
                requests: t.requests,
                canary: t.canary,
                latency: LatencyStats::from_samples(&t.latencies.samples),
                shadow_samples: t.shadow_samples,
                shadow_mean: if t.shadow_samples == 0 {
                    0.0
                } else {
                    t.shadow_sum / t.shadow_samples as f64
                },
                shadow_max: t.shadow_max,
                shadow_hist: t.shadow_hist.to_vec(),
                shadow_dropped: t.shadow_dropped,
            })
            .collect();
        stats.sort_by(|a, b| a.alias.cmp(&b.alias));
        stats
    }

    /// Track the deepest queue observed at submit time.
    pub(crate) fn observe_queue_depth(&self, depth: usize) {
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// `(answered requests, executed batches)` summed over workers.
    pub fn totals(&self) -> (usize, usize) {
        let mut requests = 0;
        let mut batches = 0;
        for w in &self.workers {
            requests += w.requests.load(Ordering::Relaxed);
            batches += w.batches.load(Ordering::Relaxed);
        }
        (requests, batches)
    }

    /// `(queue-full rejects, deadline-expired rejects)`.
    pub fn rejected(&self) -> (usize, usize) {
        (
            self.rejected_full.load(Ordering::Relaxed),
            self.rejected_deadline.load(Ordering::Relaxed),
        )
    }

    /// Submits rejected at admission because the target model's queue
    /// quota was saturated, all models.
    pub fn rejected_quota(&self) -> usize {
        self.rejected_quota.load(Ordering::Relaxed)
    }

    /// Straggler windows cut short to serve another model's backlog,
    /// summed over workers.
    pub fn steals(&self) -> usize {
        self.workers
            .iter()
            .map(|w| w.steals.load(Ordering::Relaxed))
            .sum()
    }

    pub fn peak_queue_depth(&self) -> usize {
        self.peak_queue_depth.load(Ordering::Relaxed)
    }

    /// Mean batch occupancy over every executed batch, all workers.
    pub fn occupancy(&self) -> f64 {
        let mut occupied = 0;
        let mut slots = 0;
        for w in &self.workers {
            occupied += w.occupied_slots.load(Ordering::Relaxed);
            slots += w.batch_slots.load(Ordering::Relaxed);
        }
        if slots == 0 {
            1.0
        } else {
            occupied as f64 / slots as f64
        }
    }

    /// Latency percentiles over the merged per-worker sample windows, with
    /// the occupancy gauge; never panics, even if a worker died while
    /// recording.
    pub fn latency_stats(&self) -> Option<LatencyStats> {
        let mut samples = Vec::new();
        for ring in &self.latencies {
            samples.extend_from_slice(&lock_recover(ring).samples);
        }
        LatencyStats::from_samples(&samples).map(|s| s.with_occupancy(self.occupancy()))
    }

    /// Per-model counter snapshots, sorted by model id. Counters survive
    /// `unregister_model` (a retired model's history stays reportable).
    pub fn model_stats(&self) -> Vec<ModelStats> {
        let map = lock_recover(&self.models);
        let mut stats: Vec<ModelStats> = map
            .iter()
            .map(|(model, t)| ModelStats {
                model: model.clone(),
                requests: t.requests,
                batches: t.batches,
                occupied_slots: t.occupied_slots,
                batch_slots: t.batch_slots,
                rejected_deadline: t.rejected_deadline,
                rejected_quota: t.rejected_quota,
                errors: t.errors,
                retunes: t.retunes,
                tuned: t.tuned.clone(),
            })
            .collect();
        stats.sort_by(|a, b| a.model.cmp(&b.model));
        stats
    }

    /// Per-worker counter snapshots, worker order.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        self.workers
            .iter()
            .enumerate()
            .map(|(worker, w)| WorkerStats {
                worker,
                requests: w.requests.load(Ordering::Relaxed),
                batches: w.batches.load(Ordering::Relaxed),
                occupied_slots: w.occupied_slots.load(Ordering::Relaxed),
                batch_slots: w.batch_slots.load(Ordering::Relaxed),
                errors: w.errors.load(Ordering::Relaxed),
                steals: w.steals.load(Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn loss_history_and_smoothing() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_loss(i, 10.0 - i as f32);
        }
        assert_eq!(m.losses.len(), 10);
        // last 2: 2.0, 1.0 -> mean 1.5
        assert_eq!(m.final_loss(2), Some(1.5));
        assert_eq!(Metrics::default().final_loss(3), None);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let s = LatencyStats::from_samples(&samples).unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 0.050).abs() < 0.002);
        assert_eq!(s.max, 0.1);
        assert_eq!(s.occupancy, 1.0, "from_samples defaults to full batches");
    }

    #[test]
    fn empty_latency_is_none() {
        assert!(LatencyStats::from_samples(&[]).is_none());
    }

    #[test]
    fn occupancy_tracks_padding() {
        let mut m = Metrics::default();
        m.record_batch(); // occupancy-less batch: neutral
        assert_eq!(m.occupancy(), 1.0);
        m.record_batch_occupancy(2, 8);
        m.record_batch_occupancy(8, 8);
        assert_eq!(m.batches, 3);
        assert!((m.occupancy() - 10.0 / 16.0).abs() < 1e-12);
        m.record_latency(Duration::from_millis(1));
        let s = m.latency_stats().unwrap();
        assert!((s.occupancy - 10.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn serving_metrics_aggregate_per_worker() {
        let m = ServingMetrics::new(2);
        m.record_flush(0, 3, 8);
        m.record_flush(1, 8, 8);
        for _ in 0..3 {
            m.record_latency(0, Duration::from_millis(2));
        }
        for _ in 0..8 {
            m.record_latency(1, Duration::from_millis(4));
        }
        m.record_rejected_full();
        m.record_rejected_deadline();
        m.record_rejected_deadline();
        m.record_rejected_quota();
        m.record_steal(0);
        m.record_steal(1);
        m.record_steal(1);
        m.observe_queue_depth(5);
        m.observe_queue_depth(3);

        assert_eq!(m.totals(), (11, 2));
        assert_eq!(m.rejected(), (1, 2));
        assert_eq!(m.rejected_quota(), 1);
        assert_eq!(m.steals(), 3);
        assert_eq!(m.peak_queue_depth(), 5);
        assert!((m.occupancy() - 11.0 / 16.0).abs() < 1e-12);

        let ws = m.worker_stats();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].requests, 3);
        assert_eq!(ws[0].batches, 1);
        assert_eq!(ws[0].steals, 1);
        assert_eq!(ws[1].steals, 2);
        assert!((ws[0].occupancy() - 3.0 / 8.0).abs() < 1e-12);
        assert_eq!(ws[1].errors, 0);
        assert!((ws[1].occupancy() - 1.0).abs() < 1e-12);

        let s = m.latency_stats().unwrap();
        assert_eq!(s.count, 11);
        assert!((s.occupancy - 11.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn model_stats_track_per_model_axis() {
        let m = ServingMetrics::new(2);
        m.record_model_flush("a", 3, 8);
        m.record_model_flush("a", 8, 8);
        m.record_model_flush("b", 2, 4);
        m.record_model_rejected_deadline("b");
        m.record_model_rejected_quota("b");
        m.record_model_rejected_quota("b");
        m.record_model_error("a");
        let stats = m.model_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].model, "a");
        assert_eq!(stats[0].requests, 11);
        assert_eq!(stats[0].batches, 2);
        assert!((stats[0].occupancy() - 11.0 / 16.0).abs() < 1e-12);
        assert_eq!(stats[0].errors, 1);
        assert_eq!(stats[0].rejected_quota, 0);
        assert_eq!(stats[1].model, "b");
        assert_eq!(stats[1].rejected_deadline, 1);
        assert_eq!(stats[1].rejected_quota, 2);
        assert!((stats[1].occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tuned_status_drift_gates_on_samples_and_retunes_tally() {
        let mut s = TunedStatus {
            layer: "w1".to_string(),
            structure: 0xabc,
            params: "stride=64".to_string(),
            tuned_gflops: 10.0,
            roofline_fraction: 0.5,
            ewma_gflops: None,
            samples: 0,
        };
        assert_eq!(s.drift(), None, "no flushes yet");
        s.ewma_gflops = Some(6.0);
        s.samples = DRIFT_MIN_SAMPLES - 1;
        assert_eq!(s.drift(), None, "below the sample floor");
        s.samples = DRIFT_MIN_SAMPLES;
        assert!((s.drift().unwrap() - 0.6).abs() < 1e-12);
        s.tuned_gflops = 0.0;
        assert_eq!(s.drift(), None, "degenerate recorded figure");

        let m = ServingMetrics::new(1);
        m.record_model_retune("a");
        m.record_model_retune("a");
        s.tuned_gflops = 10.0;
        m.set_model_tuned("a", vec![s.clone()]);
        m.set_model_tuned("a", vec![s]); // gauge: overwrite, not append
        assert_eq!(m.retunes(), 2);
        let stats = m.model_stats();
        assert_eq!(stats[0].retunes, 2);
        assert_eq!(stats[0].tuned.len(), 1);
        assert!((stats[0].tuned[0].drift().unwrap() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn alias_stats_track_canary_split_and_divergence_histogram() {
        let m = ServingMetrics::new(1);
        assert!(m.alias_stats().is_empty());
        for i in 0..10 {
            m.record_alias_latency("prod", i < 3, Duration::from_millis(i as u64 + 1));
        }
        m.record_shadow_divergence("prod", 5e-7); // bucket 0: ≤1e-6
        m.record_shadow_divergence("prod", 2e-3); // bucket 3: ≤1e-2
        m.record_shadow_divergence("prod", 7.5); // overflow bucket
        m.record_shadow_dropped("prod");
        m.record_alias_latency("staging", false, Duration::from_millis(1));

        let stats = m.alias_stats();
        assert_eq!(stats.len(), 2, "sorted by alias");
        let p = &stats[0];
        assert_eq!(p.alias, "prod");
        assert_eq!((p.requests, p.canary), (10, 3));
        assert!((p.canary_fraction() - 0.3).abs() < 1e-12);
        let lat = p.latency.expect("requests recorded");
        assert_eq!(lat.count, 10);
        assert!(lat.p50 <= lat.p99);
        assert_eq!(p.shadow_samples, 3);
        assert!((p.shadow_max - 7.5).abs() < 1e-12);
        assert!((p.shadow_mean - (5e-7 + 2e-3 + 7.5) / 3.0).abs() < 1e-12);
        assert_eq!(p.shadow_hist, vec![1, 0, 0, 1, 0, 1]);
        assert_eq!(p.shadow_dropped, 1);
        let s = &stats[1];
        assert_eq!(s.alias, "staging");
        assert_eq!(s.canary_fraction(), 0.0);
        assert_eq!(s.shadow_samples, 0);
        assert_eq!(s.shadow_mean, 0.0, "no samples: mean is zero, not NaN");
    }

    #[test]
    fn latency_window_is_bounded() {
        let m = ServingMetrics::new(1);
        for i in 0..LATENCY_WINDOW + 10 {
            m.record_latency(0, Duration::from_micros(i as u64 + 1));
        }
        let s = m.latency_stats().unwrap();
        assert_eq!(s.count, LATENCY_WINDOW, "ring retains a bounded window");
        // The oldest samples were overwritten: the window minimum is the
        // 11th sample, not the 1st.
        assert!(s.p50 > 10e-6, "old samples evicted from the window");
        assert_eq!(m.totals().0, LATENCY_WINDOW + 10, "counters still exact");
    }

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = lock_recover(&m2);
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_recover(&m), 7, "recovered guard still reads");
    }
}
