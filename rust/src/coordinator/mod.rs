//! L3 coordinator: the drivers that own the process — training loops and a
//! batched inference server. The native paths execute through the
//! plan-cached [`crate::kernels`] layer; the `xla` feature adds the
//! PJRT-backed trainer and serving backend that execute AOT artifacts
//! through [`crate::runtime`] (Python never runs at request time).

pub mod config;
pub mod metrics;
pub mod server;
pub mod trainer;

pub use config::TrainConfig;
pub use metrics::{LatencyStats, Metrics};
pub use server::{BatchModel, InferenceServer, NativeSparseModel, ServerConfig};
pub use trainer::NativeTrainer;
#[cfg(feature = "xla")]
pub use trainer::Trainer;
