//! L3 coordinator: the drivers that own the process — a training loop and a
//! batched inference server — both executing AOT artifacts through
//! [`crate::runtime`] with no Python anywhere near the request path.

pub mod config;
pub mod metrics;
pub mod server;
pub mod trainer;

pub use config::TrainConfig;
pub use metrics::{LatencyStats, Metrics};
pub use server::{InferenceServer, ServerConfig};
pub use trainer::Trainer;
