//! L3 coordinator: the drivers that own the process — training loops and a
//! multi-worker batched inference server. The native paths execute through
//! the plan-cached [`crate::kernels`] layer; the `xla` feature adds the
//! PJRT-backed trainer and serving backend that execute AOT artifacts
//! through [`crate::runtime`] (Python never runs at request time).
//!
//! Serving lives in [`serving`]: a pool of worker threads (one
//! [`BatchModel`] each) behind a bounded priority queue, all resolving
//! plans from one shared [`PlanCache`](crate::kernels::plan::PlanCache).

pub mod config;
pub mod frontend;
pub mod metrics;
pub mod serving;
pub mod trainer;

pub use config::TrainConfig;
pub use frontend::{Frontend, FrontendClient, FrontendConfig, Request, Response, Status};
pub use metrics::{
    AliasStats, LatencyStats, Metrics, ModelStats, ServingMetrics, TunedStatus, WorkerStats,
};
pub use serving::{
    AliasInfo, BatchModel, InferenceServer, ModelQuota, NativeSparseModel, Priority, ServeError,
    ServerConfig, SubmitOptions, UnregisterReport, DEFAULT_MODEL,
};
pub use trainer::{GradualReport, MilestoneRecord, NativeCheckpoint, NativeTrainer};
#[cfg(feature = "xla")]
pub use trainer::Trainer;
