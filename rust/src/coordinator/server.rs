//! Batched inference server: the L3 request path.
//!
//! One worker thread owns the compiled `forward` executable (PJRT handles
//! are not `Send`-safe to share); client handles submit single samples over
//! an mpsc channel. The worker *dynamically batches*: it drains up to the
//! artifact's batch size, waiting at most `max_wait` for stragglers, pads
//! the final partial batch, executes once, and scatters per-sample logits
//! back through per-request channels. Latency/throughput metrics accumulate
//! in a shared store.

use crate::coordinator::metrics::{LatencyStats, Metrics};
use crate::runtime::executor::{Executor, HostTensor};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
    /// Optional trained checkpoint to serve (JSON, `Trainer::save_checkpoint`
    /// schema); defaults to the exported init parameters.
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
            checkpoint: None,
        }
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<anyhow::Result<Vec<f32>>>,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    pub in_dim: usize,
    pub classes: usize,
    pub batch: usize,
    metrics: Arc<Mutex<Metrics>>,
}

impl InferenceServer {
    /// Start the worker thread. PJRT handles are not `Send`, so the worker
    /// compiles the artifact itself and reports readiness (or the compile
    /// error) back over a oneshot channel before the constructor returns.
    pub fn start(artifacts_dir: PathBuf, config: ServerConfig) -> anyhow::Result<InferenceServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<(usize, usize, usize)>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let worker_metrics = Arc::clone(&metrics);
        thread::Builder::new()
            .name("rbgp-serve".into())
            .spawn(move || {
                let init = || -> anyhow::Result<(Executor, Vec<HostTensor>, usize, usize, usize)> {
                    let exe = Executor::compile(&artifacts_dir, "forward")?;
                    let meta = &exe.artifact.meta;
                    let batch = meta
                        .batch()
                        .ok_or_else(|| anyhow::anyhow!("forward metadata missing batch"))?;
                    let in_dim = meta.raw.req_usize("in_dim")?;
                    let classes = meta.raw.req_usize("classes")?;
                    // Parameters served: a trained checkpoint when given,
                    // else the exported init values.
                    let params_path = config
                        .checkpoint
                        .clone()
                        .unwrap_or_else(|| artifacts_dir.join("init_params.json"));
                    let init_text = std::fs::read_to_string(&params_path)?;
                    let init = crate::util::json::Json::parse(&init_text)?;
                    let mut params = Vec::new();
                    for (idx, name) in meta.param_order.iter().enumerate() {
                        let sig = &meta.inputs[idx];
                        let vals: Vec<f32> = init
                            .req_arr(name)?
                            .iter()
                            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                            .collect();
                        params.push(HostTensor::new(vals, &sig.shape));
                    }
                    Ok((exe, params, batch, in_dim, classes))
                };
                match init() {
                    Ok((exe, params, batch, in_dim, classes)) => {
                        let _ = ready_tx.send(Ok((batch, in_dim, classes)));
                        worker_loop(exe, params, batch, in_dim, classes, config, rx, worker_metrics);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        let (batch, in_dim, classes) = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(InferenceServer {
            tx,
            in_dim,
            classes,
            batch,
            metrics,
        })
    }

    /// Submit one sample; returns a receiver that yields the logits.
    pub fn submit(&self, x: Vec<f32>) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<f32>>>> {
        anyhow::ensure!(
            x.len() == self.in_dim,
            "sample has {} features, model wants {}",
            x.len(),
            self.in_dim
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request {
                x,
                enqueued: Instant::now(),
                respond: rtx,
            })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx)
    }

    /// Blocking convenience: submit and wait for logits.
    pub fn infer(&self, x: Vec<f32>) -> anyhow::Result<Vec<f32>> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    pub fn latency_stats(&self) -> Option<LatencyStats> {
        self.metrics.lock().unwrap().latency_stats()
    }

    pub fn counters(&self) -> (usize, usize) {
        let m = self.metrics.lock().unwrap();
        (m.requests, m.batches)
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    exe: Executor,
    params: Vec<HostTensor>,
    batch: usize,
    in_dim: usize,
    classes: usize,
    config: ServerConfig,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<Mutex<Metrics>>,
) {
    loop {
        // Block for the first request; then drain greedily with deadline.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped: shut down
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad to the artifact batch and execute.
        let mut x = vec![0.0f32; batch * in_dim];
        for (s, req) in pending.iter().enumerate() {
            x[s * in_dim..(s + 1) * in_dim].copy_from_slice(&req.x);
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::new(x, &[batch, in_dim]));
        let result = exe.run(&inputs);

        match result {
            Ok(out) => {
                let logits = &out[0];
                let mut m = metrics.lock().unwrap();
                m.record_batch();
                for (s, req) in pending.into_iter().enumerate() {
                    let row = logits.data[s * classes..(s + 1) * classes].to_vec();
                    m.record_latency(req.enqueued.elapsed());
                    let _ = req.respond.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for req in pending {
                    let _ = req.respond.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}
