//! Batched inference server: the L3 request path.
//!
//! One worker thread owns the model; client handles submit single samples
//! over an mpsc channel. The worker *dynamically batches*: it drains up to
//! the model's batch size, waiting at most `max_wait` for stragglers, pads
//! the final partial batch, executes once, and scatters per-sample logits
//! back through per-request channels. Latency/throughput metrics accumulate
//! in a shared store.
//!
//! The batcher is generic over [`BatchModel`]. Two backends exist:
//!
//! * [`NativeSparseModel`] — the default build's backend: a sparse MLP
//!   executed through the [`SparseKernel`](crate::kernels::registry::SparseKernel)
//!   plan layer. Plans come from a shared [`PlanCache`], so every flush —
//!   full or padded — reuses the structure derived once at warm-up instead
//!   of rebuilding `local_cols`/scratch per batch.
//! * the XLA backend (feature `xla`) — compiles an AOT artifact on a PJRT
//!   client (handles are not `Send`, so the worker compiles it itself).

use crate::coordinator::metrics::{LatencyStats, Metrics};
use crate::kernels::plan::{KernelPlan, PlanCache, PlanRequest, SparseMatrix};
use crate::kernels::registry::KernelRegistry;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
    /// Optional trained checkpoint to serve (JSON, `Trainer::save_checkpoint`
    /// schema); defaults to the exported init parameters. XLA backend only.
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
            checkpoint: None,
        }
    }
}

/// What the batcher needs from a model: fixed batch geometry plus a
/// full-batch forward. `x` is `(batch × in_dim)` row-major; the result is
/// `(batch × classes)` row-major.
pub trait BatchModel: Send {
    fn batch(&self) -> usize;
    fn in_dim(&self) -> usize;
    fn classes(&self) -> usize;
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>>;
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<anyhow::Result<Vec<f32>>>,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    pub in_dim: usize,
    pub classes: usize,
    pub batch: usize,
    metrics: Arc<Mutex<Metrics>>,
}

impl InferenceServer {
    /// Start the worker thread around any [`BatchModel`]. The factory runs
    /// *on* the worker thread (some backends — PJRT — own handles that are
    /// not `Send`); its result (or error) is reported back before this
    /// constructor returns.
    pub fn start_model<F>(factory: F, config: ServerConfig) -> anyhow::Result<InferenceServer>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn BatchModel>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<(usize, usize, usize)>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let worker_metrics = Arc::clone(&metrics);
        thread::Builder::new()
            .name("rbgp-serve".into())
            .spawn(move || match factory() {
                Ok(mut model) => {
                    let dims = (model.batch(), model.in_dim(), model.classes());
                    let _ = ready_tx.send(Ok(dims));
                    worker_loop(model.as_mut(), config, rx, worker_metrics);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        let (batch, in_dim, classes) = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(InferenceServer {
            tx,
            in_dim,
            classes,
            batch,
            metrics,
        })
    }

    /// Start serving a compiled AOT artifact on the PJRT client (feature
    /// `xla`). The worker compiles the artifact itself and reports
    /// readiness (or the compile error) back before the constructor returns.
    #[cfg(feature = "xla")]
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        config: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        let checkpoint = config.checkpoint.clone();
        InferenceServer::start_model(
            move || {
                let model = xla_backend::XlaModel::load(&artifacts_dir, checkpoint)?;
                Ok(Box::new(model) as Box<dyn BatchModel>)
            },
            config,
        )
    }

    /// Submit one sample; returns a receiver that yields the logits.
    pub fn submit(&self, x: Vec<f32>) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<f32>>>> {
        anyhow::ensure!(
            x.len() == self.in_dim,
            "sample has {} features, model wants {}",
            x.len(),
            self.in_dim
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request {
                x,
                enqueued: Instant::now(),
                respond: rtx,
            })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx)
    }

    /// Blocking convenience: submit and wait for logits.
    pub fn infer(&self, x: Vec<f32>) -> anyhow::Result<Vec<f32>> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    pub fn latency_stats(&self) -> Option<LatencyStats> {
        self.metrics.lock().unwrap().latency_stats()
    }

    pub fn counters(&self) -> (usize, usize) {
        let m = self.metrics.lock().unwrap();
        (m.requests, m.batches)
    }
}

fn worker_loop(
    model: &mut dyn BatchModel,
    config: ServerConfig,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let (batch, in_dim, classes) = (model.batch(), model.in_dim(), model.classes());
    // One padded batch buffer reused across flushes (the model executes
    // from cached plans; the batcher should not allocate per flush either).
    let mut x = vec![0.0f32; batch * in_dim];
    loop {
        // Block for the first request; then drain greedily with deadline.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped: shut down
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad to the model batch and execute.
        x.fill(0.0);
        for (s, req) in pending.iter().enumerate() {
            x[s * in_dim..(s + 1) * in_dim].copy_from_slice(&req.x);
        }
        match model.forward(&x) {
            Ok(logits) => {
                let mut m = metrics.lock().unwrap();
                m.record_batch();
                for (s, req) in pending.into_iter().enumerate() {
                    let row = logits[s * classes..(s + 1) * classes].to_vec();
                    m.record_latency(req.enqueued.elapsed());
                    let _ = req.respond.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for req in pending {
                    let _ = req.respond.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

/// The native serving backend: a two-layer sparse MLP
/// (`x → W1 (sparse) → ReLU → W2 → logits`) executed through the
/// [`SparseKernel`](crate::kernels::registry::SparseKernel) plan layer.
/// All scratch is preallocated; both layers execute from the shared
/// [`PlanCache`], so a warmed model's forward performs no allocation and no
/// structure derivation regardless of how the batcher flushes.
pub struct NativeSparseModel {
    w1: SparseMatrix,
    b1: Vec<f32>,
    w2: SparseMatrix,
    b2: Vec<f32>,
    batch: usize,
    threads: usize,
    registry: KernelRegistry,
    cache: Arc<PlanCache>,
    // Plan handles resolved once (lazily, or eagerly via `warm`) so the
    // per-flush forward neither re-hashes the matrix structure nor takes
    // the cache map lock — it goes straight to the plans.
    plan1: Option<Arc<Mutex<KernelPlan>>>,
    plan2: Option<Arc<Mutex<KernelPlan>>>,
    // Preallocated scratch: transposed input, hidden, logits.
    xt: Vec<f32>,
    hid: Vec<f32>,
    logits: Vec<f32>,
}

impl NativeSparseModel {
    /// Build from explicit weights. `w1` is (hidden × in_dim), `w2` is
    /// (classes × hidden); biases match the row counts.
    pub fn new(
        w1: SparseMatrix,
        b1: Vec<f32>,
        w2: SparseMatrix,
        b2: Vec<f32>,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
    ) -> anyhow::Result<NativeSparseModel> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        anyhow::ensure!(
            w2.cols() == w1.rows(),
            "layer shapes disagree: W2 cols {} != W1 rows {}",
            w2.cols(),
            w1.rows()
        );
        anyhow::ensure!(b1.len() == w1.rows(), "b1 length mismatch");
        anyhow::ensure!(b2.len() == w2.rows(), "b2 length mismatch");
        let (h, d, c) = (w1.rows(), w1.cols(), w2.rows());
        Ok(NativeSparseModel {
            w1,
            b1,
            w2,
            b2,
            batch,
            threads: threads.max(1),
            registry: KernelRegistry::builtin(),
            cache,
            plan1: None,
            plan2: None,
            xt: vec![0.0; d * batch],
            hid: vec![0.0; h * batch],
            logits: vec![0.0; c * batch],
        })
    }

    /// A self-contained demo model on a small RBGP4 hidden layer (256→256
    /// at 75 % sparsity) — the featureless `rbgp serve` backend and the
    /// test fixture. Deterministic in `seed`.
    pub fn rbgp4_demo(
        classes: usize,
        batch: usize,
        threads: usize,
        seed: u64,
        cache: Arc<PlanCache>,
    ) -> anyhow::Result<NativeSparseModel> {
        use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
        use crate::util::rng::Rng;
        let cfg = Rbgp4Config {
            go: GraphSpec::new(8, 16, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(16, 16, 0.5),
            gb: (1, 1),
        };
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(cfg, &mut rng)?;
        let w1 = Rbgp4Matrix::random(mask, &mut rng);
        let h = w1.mask.rows();
        let w2scale = (1.0 / h as f64).sqrt() as f32;
        let w2 = rng.normal_vec_f32(classes * h, w2scale);
        NativeSparseModel::new(
            SparseMatrix::Rbgp4(w1),
            vec![0.0; h],
            SparseMatrix::dense(w2, classes, h),
            vec![0.0; classes],
            batch,
            threads,
            cache,
        )
    }

    /// Pre-build both layers' plans for this model's batch class so the
    /// first request pays no plan-construction latency.
    pub fn warm(&mut self) -> anyhow::Result<()> {
        self.resolve_plans()
    }

    /// Resolve (and retain) the two layer-plan handles from the shared
    /// cache. Idempotent; called lazily by `forward` if `warm` wasn't.
    fn resolve_plans(&mut self) -> anyhow::Result<()> {
        let req = PlanRequest {
            n: self.batch,
            threads: self.threads,
        };
        if self.plan1.is_none() {
            self.plan1 = Some(self.cache.plan_for(&self.registry, &self.w1, &req)?);
        }
        if self.plan2.is_none() {
            self.plan2 = Some(self.cache.plan_for(&self.registry, &self.w2, &req)?);
        }
        Ok(())
    }

    /// The plan cache this model executes from (shared; inspect for stats).
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }
}

impl BatchModel for NativeSparseModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.w1.cols()
    }

    fn classes(&self) -> usize {
        self.w2.rows()
    }

    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let (b, d) = (self.batch, self.w1.cols());
        let (h, c) = (self.w1.rows(), self.w2.rows());
        anyhow::ensure!(x.len() == b * d, "batch input length mismatch");
        self.resolve_plans()?;
        // (batch × d) → (d × batch): kernels consume column-major batches.
        for r in 0..b {
            for col in 0..d {
                self.xt[col * b + r] = x[r * d + col];
            }
        }
        // Execute straight from the retained plan handles: no structure
        // re-hash, no cache-map lock on the flush path.
        let plan1 = Arc::clone(self.plan1.as_ref().expect("resolved above"));
        let plan2 = Arc::clone(self.plan2.as_ref().expect("resolved above"));
        self.registry.for_matrix(&self.w1)?.execute(
            &self.w1,
            &mut plan1.lock().unwrap(),
            &self.xt,
            &mut self.hid,
            b,
        )?;
        for r in 0..h {
            let bias = self.b1[r];
            for j in 0..b {
                let v = self.hid[r * b + j] + bias;
                self.hid[r * b + j] = if v > 0.0 { v } else { 0.0 };
            }
        }
        self.registry.for_matrix(&self.w2)?.execute(
            &self.w2,
            &mut plan2.lock().unwrap(),
            &self.hid,
            &mut self.logits,
            b,
        )?;
        // (c × batch) + bias → (batch × c) row-major for the batcher.
        let mut out = vec![0.0f32; b * c];
        for j in 0..b {
            for r in 0..c {
                out[j * c + r] = self.logits[r * b + j] + self.b2[r];
            }
        }
        Ok(out)
    }
}

#[cfg(feature = "xla")]
mod xla_backend {
    use super::BatchModel;
    use crate::runtime::executor::{Executor, HostTensor};
    use std::path::{Path, PathBuf};

    /// The PJRT-backed model: a compiled `forward` artifact plus its served
    /// parameters.
    pub struct XlaModel {
        exe: Executor,
        params: Vec<HostTensor>,
        batch: usize,
        in_dim: usize,
        classes: usize,
    }

    impl XlaModel {
        pub fn load(artifacts_dir: &Path, checkpoint: Option<PathBuf>) -> anyhow::Result<XlaModel> {
            let exe = Executor::compile(artifacts_dir, "forward")?;
            let meta = &exe.artifact.meta;
            let batch = meta
                .batch()
                .ok_or_else(|| anyhow::anyhow!("forward metadata missing batch"))?;
            let in_dim = meta.raw.req_usize("in_dim")?;
            let classes = meta.raw.req_usize("classes")?;
            // Parameters served: a trained checkpoint when given, else the
            // exported init values.
            let params_path =
                checkpoint.unwrap_or_else(|| artifacts_dir.join("init_params.json"));
            let init_text = std::fs::read_to_string(&params_path)?;
            let init = crate::util::json::Json::parse(&init_text)?;
            let mut params = Vec::new();
            for (idx, name) in meta.param_order.iter().enumerate() {
                let sig = &meta.inputs[idx];
                let vals: Vec<f32> = init
                    .req_arr(name)?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                params.push(HostTensor::new(vals, &sig.shape));
            }
            Ok(XlaModel {
                exe,
                params,
                batch,
                in_dim,
                classes,
            })
        }
    }

    impl BatchModel for XlaModel {
        fn batch(&self) -> usize {
            self.batch
        }

        fn in_dim(&self) -> usize {
            self.in_dim
        }

        fn classes(&self) -> usize {
            self.classes
        }

        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::new(x.to_vec(), &[self.batch, self.in_dim]));
            let out = self.exe.run(&inputs)?;
            Ok(out[0].data.clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo(seed: u64, cache: Arc<PlanCache>) -> NativeSparseModel {
        NativeSparseModel::rbgp4_demo(10, 8, 2, seed, cache).unwrap()
    }

    #[test]
    fn native_model_shapes_and_determinism() {
        let cache = Arc::new(PlanCache::new());
        let mut m = demo(42, Arc::clone(&cache));
        assert_eq!(m.in_dim(), 256);
        assert_eq!(m.classes(), 10);
        assert_eq!(m.batch(), 8);
        m.warm().unwrap();
        let (_, misses) = cache.stats();
        assert_eq!(misses, 2, "warm builds one plan per layer");
        let x: Vec<f32> = (0..8 * 256).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let a = m.forward(&x).unwrap();
        let b = m.forward(&x).unwrap();
        assert_eq!(a, b, "same input, same plan → same logits");
        assert_eq!(a.len(), 8 * 10);
        assert!(a.iter().all(|v| v.is_finite()));
        // The flush path holds the plan handles: after warm-up, forward
        // generates no cache traffic at all (no re-hash, no map lock).
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "forward never rebuilds plans");
        assert_eq!(hits, 0, "forward bypasses the cache map entirely");
        // A second model on the same cache shares the warmed plans.
        let mut m2 = demo(42, Arc::clone(&cache));
        m2.warm().unwrap();
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "same structure → no new plan builds");
        assert_eq!(hits, 2, "second model resolves both plans from cache");
    }

    #[test]
    fn native_server_serves_and_batches() {
        let cache = Arc::new(PlanCache::new());
        let mut reference = demo(7, Arc::new(PlanCache::new()));
        let model = demo(7, Arc::clone(&cache));
        let server = InferenceServer::start_model(
            move || {
                let mut m = model;
                m.warm()?;
                Ok(Box::new(m) as Box<dyn BatchModel>)
            },
            ServerConfig {
                max_wait: std::time::Duration::from_millis(2),
                checkpoint: None,
            },
        )
        .unwrap();
        assert_eq!(server.in_dim, 256);

        // Single request: result equals a padded direct forward.
        let x: Vec<f32> = (0..256).map(|i| (i as f32 / 256.0) - 0.5).collect();
        let got = server.infer(x.clone()).unwrap();
        let mut xb = vec![0.0f32; 8 * 256];
        xb[..256].copy_from_slice(&x);
        let want = reference.forward(&xb).unwrap();
        for (a, b) in got.iter().zip(&want[..10]) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }

        // A burst from several clients all gets answered; the batcher
        // groups them into ≤ ceil(32/1) and ≥ ceil(32/8) flushes.
        std::thread::scope(|scope| {
            for t in 0..4 {
                let server = server.clone();
                let x = x.clone();
                scope.spawn(move || {
                    for _ in 0..8 {
                        let out = server.infer(x.clone()).unwrap();
                        assert_eq!(out.len(), 10);
                        let _ = t;
                    }
                });
            }
        });
        let (requests, batches) = server.counters();
        assert_eq!(requests, 33);
        assert!(batches >= 5, "at least ceil(33/8) flushes, got {batches}");
        assert!(server.latency_stats().is_some());

        // Every flush of the burst reused cached plans: exactly the two
        // warm-time builds, never more.
        let (_, misses) = cache.stats();
        assert_eq!(misses, 2, "batcher must execute from cached plans");
    }

    #[test]
    fn submit_rejects_wrong_width() {
        let cache = Arc::new(PlanCache::new());
        let model = demo(3, cache);
        let server = InferenceServer::start_model(
            move || Ok(Box::new(model) as Box<dyn BatchModel>),
            ServerConfig::default(),
        )
        .unwrap();
        assert!(server.submit(vec![0.0; 3]).is_err());
    }
}
