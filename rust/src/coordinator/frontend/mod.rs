//! Network front-end: a hand-rolled non-blocking TCP reactor that puts
//! the serving core's admission machinery — bounded priority queue,
//! deadlines, per-model quotas, typed backpressure — on a socket.
//!
//! One reactor thread owns every connection (no locks on the data path):
//! it accepts, reads and decodes length-prefixed request frames
//! ([`protocol`]), admits each request against its tenant's quota class,
//! submits into the existing [`InferenceServer`] queue, then sweeps the
//! per-request completion channels and writes responses back **out of
//! order** as workers finish them. Every typed [`ServeError`] surfaces
//! as a distinct protocol [`Status`] code instead of a dropped
//! connection, and a slow reader gets a bounded write buffer whose
//! overflow sheds responses (counted in `ServingMetrics`) rather than
//! ballooning memory.
//!
//! Shutdown is drain-clean: stop accepting, finish every in-flight
//! request, flush every write buffer, then close.

pub mod conn;
pub mod protocol;

pub use protocol::{FrontendClient, Request, Response, Status};

#[cfg(doc)]
use crate::coordinator::serving::ServeError;

use self::conn::{Conn, InFlight};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::serving::{InferenceServer, ModelQuota, SubmitOptions};
use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long a drain-clean shutdown waits on peers that stop reading;
/// past this, remaining buffered responses are abandoned so `shutdown`
/// cannot hang on a dead-but-open socket.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// Front-end tuning knobs.
#[derive(Clone, Debug)]
pub struct FrontendConfig {
    /// Bind address, e.g. `127.0.0.1:7777`; port 0 picks a free port
    /// (see [`Frontend::local_addr`]).
    pub listen: String,
    /// Tenant quota classes: each key resolves to a max-in-flight cap
    /// against the server's queue capacity, exactly like a model quota
    /// ([`ModelQuota::limit`]). Unlisted tenants are unlimited.
    pub tenants: Vec<(String, ModelQuota)>,
    /// Per-connection write-buffer bound; responses that would grow a
    /// slow reader's backlog past this are shed (dropped + counted).
    pub write_buf_cap: usize,
    /// Largest accepted request frame body; an oversize length prefix is
    /// unrecoverable framing and closes the connection.
    pub max_frame: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            listen: "127.0.0.1:0".to_string(),
            tenants: Vec::new(),
            write_buf_cap: 256 * 1024,
            max_frame: 1 << 20,
        }
    }
}

/// Handle to a running front-end; [`Frontend::shutdown`] (or drop)
/// drains and joins the reactor.
pub struct Frontend {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    reactor: Option<std::thread::JoinHandle<()>>,
}

impl Frontend {
    /// Bind `config.listen` and start the reactor thread over `server`'s
    /// queue. The server handle is cloned in; shutting the front-end
    /// down does not stop the server (or vice versa — a stopped server
    /// turns every submit into a typed `Stopped` response).
    pub fn start(server: InferenceServer, config: FrontendConfig) -> anyhow::Result<Frontend> {
        let listener = TcpListener::bind(&config.listen)
            .map_err(|e| anyhow::anyhow!("frontend bind {}: {e}", config.listen))?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let reactor = {
            let stop = Arc::clone(&stop);
            // Tenant quotas resolve once against the queue capacity —
            // tenants are config, not registry members, so there is no
            // membership to track.
            let tenant_caps: HashMap<String, usize> = config
                .tenants
                .iter()
                .filter_map(|(k, q)| q.limit(server.queue_capacity()).map(|l| (k.clone(), l)))
                .collect();
            let metrics = Arc::clone(server.metrics());
            let cfg = config.clone();
            std::thread::Builder::new()
                .name("rbgp-frontend".to_string())
                .spawn(move || reactor_loop(listener, server, metrics, tenant_caps, cfg, stop))?
        };
        Ok(Frontend { local_addr, stop, reactor: Some(reactor) })
    }

    /// The bound address (the actual port when `listen` used port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Drain-clean shutdown: stop accepting, answer everything in
    /// flight, flush every connection, join the reactor. Idempotent via
    /// drop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// The reactor: single-threaded owner of every connection. Runs until
/// `stop` is raised *and* all in-flight work has drained.
fn reactor_loop(
    listener: TcpListener,
    server: InferenceServer,
    metrics: Arc<ServingMetrics>,
    tenant_caps: HashMap<String, usize>,
    cfg: FrontendConfig,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    // Requests in flight per tenant key, reactor-private (one thread).
    let mut tenant_inflight: HashMap<String, usize> = HashMap::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        let stopping = stop.load(Ordering::Acquire);
        if stopping && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + DRAIN_TIMEOUT);
        }
        let mut progressed = false;

        if !stopping {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nodelay(true);
                        if stream.set_nonblocking(true).is_ok() {
                            conns.push(Conn::new(stream));
                            progressed = true;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        }

        for conn in &mut conns {
            // Read + decode new requests. During a drain we stop reading:
            // anything the peer sent after shutdown began is dropped with
            // the connection rather than admitted to a stopping server.
            if !stopping && conn.read_ready() {
                progressed = true;
            }
            if !stopping {
                progressed |= pump_requests(
                    conn,
                    &server,
                    &metrics,
                    &tenant_caps,
                    &mut tenant_inflight,
                    &cfg,
                );
            }
            progressed |= sweep_completions(conn, &metrics, &mut tenant_inflight, &cfg);
            if conn.flush_ready() {
                progressed = true;
            }
        }

        // Reap finished and dead connections, refunding the tenant
        // accounting for any work a dead peer abandoned in flight.
        conns.retain_mut(|c| {
            if c.dead || c.drained() {
                for inflight in c.inflight.drain(..) {
                    release_tenant(&mut tenant_inflight, &inflight.tenant);
                }
                false
            } else {
                true
            }
        });

        if stopping {
            // Drained: every admitted request answered and every response
            // byte handed to the kernel. Peers may keep their connections
            // open — we do not wait for their EOF, and a peer that stops
            // reading only holds shutdown until the drain timeout.
            let drained =
                conns.iter().all(|c| c.inflight.is_empty() && c.pending_write() == 0);
            let expired = drain_deadline.map(|d| Instant::now() >= d).unwrap_or(false);
            if drained || expired {
                return;
            }
        }
        if !progressed {
            // Nothing moved anywhere: sleep a beat instead of spinning.
            std::thread::sleep(Duration::from_micros(300));
        }
    }
}

/// Decode every complete frame buffered on `conn` and submit it.
fn pump_requests(
    conn: &mut Conn,
    server: &InferenceServer,
    metrics: &ServingMetrics,
    tenant_caps: &HashMap<String, usize>,
    tenant_inflight: &mut HashMap<String, usize>,
    cfg: &FrontendConfig,
) -> bool {
    let mut progressed = false;
    loop {
        let body = match conn.take_frame(cfg.max_frame) {
            Ok(Some(body)) => body,
            Ok(None) => return progressed,
            Err(oversize) => {
                // Framing is lost; tell the peer why before closing.
                metrics.record_frontend_rejected();
                let frame = protocol::encode_response_err(
                    0,
                    Status::BadFrame,
                    &format!("frame body {oversize} exceeds max {}", cfg.max_frame),
                );
                let _ = conn.enqueue_write(&frame, cfg.write_buf_cap);
                let _ = conn.flush_ready();
                return true;
            }
        };
        progressed = true;
        let req = match protocol::decode_request(&body) {
            Ok(req) => req,
            Err(detail) => {
                metrics.record_frontend_rejected();
                respond_err(conn, metrics, cfg, 0, Status::BadFrame, &detail);
                continue;
            }
        };

        // Tenant admission: a saturated quota class is back-pressured
        // here, before the request can occupy shared queue capacity.
        let in_use = tenant_inflight.get(&req.tenant).copied().unwrap_or(0);
        if let Some(cap) = tenant_caps.get(&req.tenant) {
            if in_use >= *cap {
                metrics.record_frontend_rejected();
                respond_err(
                    conn,
                    metrics,
                    cfg,
                    req.req_id,
                    Status::TenantQuotaExceeded,
                    &format!("tenant '{}' at quota ({cap} in flight)", req.tenant),
                );
                continue;
            }
        }

        let mut opts = SubmitOptions::default().with_priority(req.priority);
        if req.deadline_ms > 0 {
            opts = opts.with_deadline(Duration::from_millis(req.deadline_ms as u64));
        }
        if let Some(model) = &req.model {
            opts = opts.with_model(model.clone());
        }
        match server.submit_with(req.payload, opts) {
            Ok(rx) => {
                metrics.record_frontend_accepted();
                *tenant_inflight.entry(req.tenant.clone()).or_insert(0) += 1;
                conn.inflight.push(InFlight { req_id: req.req_id, tenant: req.tenant, rx });
            }
            Err(e) => {
                metrics.record_frontend_rejected();
                respond_err(
                    conn,
                    metrics,
                    cfg,
                    req.req_id,
                    Status::from_error(&e),
                    &e.to_string(),
                );
            }
        }
    }
}

/// Poll every in-flight completion channel on `conn`, encoding finished
/// responses in completion order (out of request order by design).
fn sweep_completions(
    conn: &mut Conn,
    metrics: &ServingMetrics,
    tenant_inflight: &mut HashMap<String, usize>,
    cfg: &FrontendConfig,
) -> bool {
    if conn.inflight.is_empty() {
        return false;
    }
    let mut progressed = false;
    // Taking the vec lets us write into `conn` while polling; pending
    // entries are pushed straight back.
    let inflight = std::mem::take(&mut conn.inflight);
    for entry in inflight {
        let frame = match entry.rx.try_recv() {
            Err(TryRecvError::Empty) => {
                conn.inflight.push(entry);
                continue;
            }
            Ok(Ok(logits)) => protocol::encode_response_ok(entry.req_id, &logits),
            Ok(Err(e)) => {
                protocol::encode_response_err(entry.req_id, Status::from_error(&e), &e.to_string())
            }
            // A dropped sender without a value is a worker pool that died
            // mid-request: same contract as a stopped server.
            Err(TryRecvError::Disconnected) => {
                protocol::encode_response_err(entry.req_id, Status::Stopped, "server stopped")
            }
        };
        progressed = true;
        release_tenant(tenant_inflight, &entry.tenant);
        if !conn.enqueue_write(&frame, cfg.write_buf_cap) {
            metrics.record_frontend_shed();
        }
    }
    progressed
}

/// Encode an error response into the connection, shedding (with
/// accounting) if the write buffer is full.
fn respond_err(
    conn: &mut Conn,
    metrics: &ServingMetrics,
    cfg: &FrontendConfig,
    req_id: u64,
    status: Status,
    detail: &str,
) {
    let frame = protocol::encode_response_err(req_id, status, detail);
    if !conn.enqueue_write(&frame, cfg.write_buf_cap) {
        metrics.record_frontend_shed();
    }
}

fn release_tenant(tenant_inflight: &mut HashMap<String, usize>, tenant: &str) {
    if let Some(n) = tenant_inflight.get_mut(tenant) {
        *n = n.saturating_sub(1);
        if *n == 0 {
            tenant_inflight.remove(tenant);
        }
    }
}
