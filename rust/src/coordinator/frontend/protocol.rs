//! Wire protocol for the network front-end: length-prefixed binary
//! frames over TCP, little-endian throughout.
//!
//! A **frame** is a `u32` body length followed by the body. Request body:
//!
//! ```text
//! req_id      u64   client-chosen correlation id (echoed verbatim)
//! priority    u8    0 = High, 1 = Normal, 2 = Low
//! deadline_ms u32   0 = server default deadline
//! tenant_len  u16   then that many UTF-8 bytes (quota-class key)
//! model_len   u16   then that many UTF-8 bytes (empty = default model)
//! payload     rest  f32 LE samples (len must be a multiple of 4)
//! ```
//!
//! Response body:
//!
//! ```text
//! req_id      u64
//! status      u8    Status code; 0 = Ok
//! Ok:   payload     f32 LE logits
//! Err:  detail_len  u16, then that many UTF-8 bytes of human detail
//! ```
//!
//! Responses complete **out of order**: the server answers each request
//! as its worker finishes it, and the client correlates by `req_id`.

use crate::coordinator::serving::{Priority, ServeError};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

/// Protocol status codes — one per reachable [`ServeError`] variant plus
/// the front-end's own admission/framing outcomes. Codes are wire ABI:
/// append, never renumber.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Request served; body carries the logits.
    Ok,
    /// Shared queue at capacity ([`ServeError::QueueFull`]).
    QueueFull,
    /// Target model at its admission quota
    /// ([`ServeError::ModelQuotaExceeded`]).
    ModelQuotaExceeded,
    /// Deadline lapsed before a worker served it
    /// ([`ServeError::DeadlineExceeded`]).
    DeadlineExceeded,
    /// No such model or alias ([`ServeError::UnknownModel`]).
    UnknownModel,
    /// Registration probe still pending ([`ServeError::ModelNotReady`]).
    ModelNotReady,
    /// Payload width does not match the target model
    /// ([`ServeError::WrongInputWidth`]).
    WrongInputWidth,
    /// Server shut down ([`ServeError::Stopped`]).
    Stopped,
    /// Model execution failed ([`ServeError::Backend`]).
    Backend,
    /// The tenant key's in-flight quota is saturated (front-end
    /// admission, before the request reaches the queue).
    TenantQuotaExceeded,
    /// The frame could not be decoded; detail says why.
    BadFrame,
}

impl Status {
    pub fn code(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::QueueFull => 1,
            Status::ModelQuotaExceeded => 2,
            Status::DeadlineExceeded => 3,
            Status::UnknownModel => 4,
            Status::ModelNotReady => 5,
            Status::WrongInputWidth => 6,
            Status::Stopped => 7,
            Status::Backend => 8,
            Status::TenantQuotaExceeded => 9,
            Status::BadFrame => 10,
        }
    }

    pub fn from_code(code: u8) -> Option<Status> {
        Some(match code {
            0 => Status::Ok,
            1 => Status::QueueFull,
            2 => Status::ModelQuotaExceeded,
            3 => Status::DeadlineExceeded,
            4 => Status::UnknownModel,
            5 => Status::ModelNotReady,
            6 => Status::WrongInputWidth,
            7 => Status::Stopped,
            8 => Status::Backend,
            9 => Status::TenantQuotaExceeded,
            10 => Status::BadFrame,
            _ => return None,
        })
    }

    /// The protocol code for a typed serving error — total over
    /// [`ServeError`], so no error can reach the socket without a
    /// distinct status.
    pub fn from_error(e: &ServeError) -> Status {
        match e {
            ServeError::QueueFull { .. } => Status::QueueFull,
            ServeError::ModelQuotaExceeded { .. } => Status::ModelQuotaExceeded,
            ServeError::DeadlineExceeded { .. } => Status::DeadlineExceeded,
            ServeError::UnknownModel { .. } => Status::UnknownModel,
            ServeError::ModelNotReady { .. } => Status::ModelNotReady,
            ServeError::WrongInputWidth { .. } => Status::WrongInputWidth,
            ServeError::Stopped => Status::Stopped,
            ServeError::Backend(_) => Status::Backend,
        }
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

pub(crate) fn priority_code(p: Priority) -> u8 {
    match p {
        Priority::High => 0,
        Priority::Normal => 1,
        Priority::Low => 2,
    }
}

pub(crate) fn priority_from_code(code: u8) -> Option<Priority> {
    Some(match code {
        0 => Priority::High,
        1 => Priority::Normal,
        2 => Priority::Low,
        _ => return None,
    })
}

/// One decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub req_id: u64,
    pub priority: Priority,
    /// Per-request deadline in milliseconds; `0` defers to the server's
    /// configured default.
    pub deadline_ms: u32,
    /// Tenant quota-class key; empty = anonymous (unlimited).
    pub tenant: String,
    /// Target model or alias; `None` = the server's default model.
    pub model: Option<String>,
    pub payload: Vec<f32>,
}

/// Byte-cursor over a frame body; every `take` is bounds-checked so a
/// truncated or hostile frame decodes to an error, never a panic.
struct Cursor<'a> {
    buf: &'a [u8],
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() < n {
            return Err(format!(
                "truncated frame: wanted {n} more bytes, have {}",
                self.buf.len()
            ));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    /// Little-endian unsigned int of `n` bytes (n ≤ 8).
    fn le(&mut self, n: usize) -> Result<u64, String> {
        let bytes = self.take(n)?;
        let mut v = 0u64;
        for (i, b) in bytes.iter().enumerate() {
            v |= (*b as u64) << (8 * i);
        }
        Ok(v)
    }

    fn utf8(&mut self, n: usize) -> Result<String, String> {
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| "non-UTF-8 string field".to_string())
    }
}

fn put_le(out: &mut Vec<u8>, v: u64, n: usize) {
    for i in 0..n {
        out.push((v >> (8 * i)) as u8);
    }
}

/// Encode a full request frame (length prefix included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let model = req.model.as_deref().unwrap_or("");
    let body_len = 8 + 1 + 4 + 2 + req.tenant.len() + 2 + model.len() + 4 * req.payload.len();
    let mut out = Vec::with_capacity(4 + body_len);
    put_le(&mut out, body_len as u64, 4);
    put_le(&mut out, req.req_id, 8);
    out.push(priority_code(req.priority));
    put_le(&mut out, req.deadline_ms as u64, 4);
    put_le(&mut out, req.tenant.len() as u64, 2);
    out.extend_from_slice(req.tenant.as_bytes());
    put_le(&mut out, model.len() as u64, 2);
    out.extend_from_slice(model.as_bytes());
    for x in &req.payload {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode one request frame body (the length prefix already consumed).
/// Errors are human-readable details for a [`Status::BadFrame`] response.
pub fn decode_request(body: &[u8]) -> Result<Request, String> {
    let mut c = Cursor { buf: body };
    let req_id = c.le(8)?;
    let pcode = c.le(1)? as u8;
    let priority =
        priority_from_code(pcode).ok_or_else(|| format!("bad priority code {pcode} (0|1|2)"))?;
    let deadline_ms = c.le(4)? as u32;
    let tenant_len = c.le(2)? as usize;
    let tenant = c.utf8(tenant_len)?;
    let model_len = c.le(2)? as usize;
    let model = c.utf8(model_len)?;
    if c.buf.len() % 4 != 0 {
        return Err(format!("payload length {} is not a multiple of 4", c.buf.len()));
    }
    let payload = c
        .buf
        .chunks_exact(4)
        .map(|ch| {
            // LE f32: fold the 4 bytes most-significant-first into the bits.
            f32::from_bits(ch.iter().rev().fold(0u32, |acc, b| (acc << 8) | *b as u32))
        })
        .collect();
    Ok(Request {
        req_id,
        priority,
        deadline_ms,
        tenant,
        model: if model.is_empty() { None } else { Some(model) },
        payload,
    })
}

/// Encode a full `Ok` response frame (length prefix included).
pub fn encode_response_ok(req_id: u64, logits: &[f32]) -> Vec<u8> {
    let body_len = 8 + 1 + 4 * logits.len();
    let mut out = Vec::with_capacity(4 + body_len);
    put_le(&mut out, body_len as u64, 4);
    put_le(&mut out, req_id, 8);
    out.push(Status::Ok.code());
    for x in logits {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Encode a full error response frame (length prefix included). The
/// detail is truncated to fit its u16 length field.
pub fn encode_response_err(req_id: u64, status: Status, detail: &str) -> Vec<u8> {
    let detail = detail.as_bytes();
    let detail = detail.get(..detail.len().min(u16::MAX as usize)).unwrap_or(detail);
    let body_len = 8 + 1 + 2 + detail.len();
    let mut out = Vec::with_capacity(4 + body_len);
    put_le(&mut out, body_len as u64, 4);
    put_le(&mut out, req_id, 8);
    out.push(status.code());
    put_le(&mut out, detail.len() as u64, 2);
    out.extend_from_slice(detail);
    out
}

/// One decoded response frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub req_id: u64,
    pub status: Status,
    /// Logits when `status == Ok`, empty otherwise.
    pub payload: Vec<f32>,
    /// Human-readable error detail, empty on `Ok`.
    pub detail: String,
}

/// Decode one response frame body (length prefix already consumed).
pub fn decode_response(body: &[u8]) -> Result<Response, String> {
    let mut c = Cursor { buf: body };
    let req_id = c.le(8)?;
    let code = c.le(1)? as u8;
    let status = Status::from_code(code).ok_or_else(|| format!("bad status code {code}"))?;
    if status == Status::Ok {
        if c.buf.len() % 4 != 0 {
            return Err(format!("logit bytes {} not a multiple of 4", c.buf.len()));
        }
        let payload = c
            .buf
            .chunks_exact(4)
            .map(|ch| {
                f32::from_bits(ch.iter().rev().fold(0u32, |acc, b| (acc << 8) | *b as u32))
            })
            .collect();
        return Ok(Response { req_id, status, payload, detail: String::new() });
    }
    let detail_len = c.le(2)? as usize;
    let detail = c.utf8(detail_len)?;
    Ok(Response { req_id, status, payload: Vec::new(), detail })
}

/// Blocking client for tests, benches and the CLI demo: one TCP
/// connection, synchronous `send`/`recv` (responses may interleave out of
/// request order — correlate by [`Response::req_id`]).
pub struct FrontendClient {
    stream: TcpStream,
    next_id: u64,
}

impl FrontendClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<FrontendClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(FrontendClient { stream, next_id: 1 })
    }

    /// Send one request frame; returns the request id used.
    pub fn send(&mut self, req: &Request) -> std::io::Result<u64> {
        self.stream.write_all(&encode_request(req))?;
        Ok(req.req_id)
    }

    /// Read exactly one response frame (blocking).
    pub fn recv(&mut self) -> anyhow::Result<Response> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        let mut body = vec![0u8; n];
        self.stream.read_exact(&mut body)?;
        decode_response(&body).map_err(|e| anyhow::anyhow!("bad response frame: {e}"))
    }

    /// Round-trip convenience: send one request with an auto-assigned id
    /// and block for its response (valid on a connection with no other
    /// requests outstanding, where no interleaving is possible).
    pub fn infer(
        &mut self,
        payload: Vec<f32>,
        model: Option<&str>,
        priority: Priority,
        tenant: &str,
        deadline_ms: u32,
    ) -> anyhow::Result<Response> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send(&Request {
            req_id,
            priority,
            deadline_ms,
            tenant: tenant.to_string(),
            model: model.map(str::to_string),
            payload,
        })?;
        let resp = self.recv()?;
        anyhow::ensure!(
            resp.req_id == req_id,
            "response id {} for request {req_id} on a serial connection",
            resp.req_id
        );
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_preserves_every_field() {
        let req = Request {
            req_id: 0xDEAD_BEEF_CAFE,
            priority: Priority::Low,
            deadline_ms: 250,
            tenant: "team-a".to_string(),
            model: Some("prod".to_string()),
            payload: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
        };
        let frame = encode_request(&req);
        let (len, body) = frame.split_at(4);
        assert_eq!(u32::from_le_bytes(len.try_into().unwrap()) as usize, body.len());
        assert_eq!(decode_request(body).unwrap(), req);

        // Empty model field decodes to the default route.
        let anon = Request { model: None, tenant: String::new(), ..req };
        let frame = encode_request(&anon);
        assert_eq!(decode_request(&frame[4..]).unwrap(), anon);
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let frame = encode_response_ok(42, &[0.5, -0.5]);
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!((got.req_id, got.status), (42, Status::Ok));
        assert_eq!(got.payload, vec![0.5, -0.5]);

        let frame = encode_response_err(7, Status::QueueFull, "queue full (cap 8)");
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!((got.req_id, got.status), (7, Status::QueueFull));
        assert_eq!(got.detail, "queue full (cap 8)");
    }

    #[test]
    fn truncated_and_malformed_frames_decode_to_errors_not_panics() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[1, 2, 3]).is_err());
        // Bad priority code.
        let mut frame = encode_request(&Request {
            req_id: 1,
            priority: Priority::Normal,
            deadline_ms: 0,
            tenant: String::new(),
            model: None,
            payload: vec![],
        });
        frame[4 + 8] = 9; // priority byte
        assert!(decode_request(&frame[4..]).unwrap_err().contains("priority"));
        // Payload not a multiple of 4.
        let good = encode_request(&Request {
            req_id: 1,
            priority: Priority::Normal,
            deadline_ms: 0,
            tenant: String::new(),
            model: None,
            payload: vec![1.0],
        });
        assert!(decode_request(&good[4..good.len() - 1]).is_err());
        assert!(decode_response(&[0; 8]).is_err());
    }

    #[test]
    fn every_serve_error_maps_to_a_distinct_status_code() {
        use std::time::Duration;
        let errors = [
            ServeError::QueueFull { cap: 1 },
            ServeError::ModelQuotaExceeded { model: "m".into(), quota: 1 },
            ServeError::DeadlineExceeded { waited: Duration::ZERO },
            ServeError::UnknownModel { model: "m".into() },
            ServeError::ModelNotReady { model: "m".into() },
            ServeError::WrongInputWidth { got: 1, want: 2 },
            ServeError::Stopped,
            ServeError::Backend("boom".into()),
        ];
        let mut codes: Vec<u8> = errors.iter().map(|e| Status::from_error(e).code()).collect();
        // Front-end-originated codes share the same namespace.
        codes.push(Status::Ok.code());
        codes.push(Status::TenantQuotaExceeded.code());
        codes.push(Status::BadFrame.code());
        let mut dedup = codes.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), codes.len(), "status codes must be pairwise distinct");
        // And every code survives the wire roundtrip.
        for c in codes {
            assert_eq!(Status::from_code(c).unwrap().code(), c);
        }
    }
}
