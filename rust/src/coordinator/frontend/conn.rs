//! Per-connection state for the reactor: a non-blocking socket, an
//! accumulating read buffer the framer slices complete frames out of, a
//! **bounded** write buffer (slow readers shed responses instead of
//! growing it without bound), and the connection's in-flight requests.

use crate::coordinator::serving::ServeError;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;

/// One request this connection has submitted into the queue and not yet
/// answered on the wire.
pub(crate) struct InFlight {
    pub req_id: u64,
    /// Tenant key charged for this request; the reactor decrements the
    /// tenant's in-flight count when the request settles (or the
    /// connection dies with it outstanding).
    pub tenant: String,
    pub rx: mpsc::Receiver<Result<Vec<f32>, ServeError>>,
}

pub(crate) struct Conn {
    pub stream: TcpStream,
    /// Unparsed request bytes; frames are drained from the front.
    read_buf: Vec<u8>,
    /// Encoded response bytes not yet accepted by the socket.
    write_buf: Vec<u8>,
    /// How much of `write_buf` the socket has taken (drained lazily so
    /// steady-state flushes never memmove).
    written: usize,
    pub inflight: Vec<InFlight>,
    /// Peer closed its write side (EOF on read): no more requests, but
    /// in-flight responses still drain.
    pub read_closed: bool,
    /// Fatal socket or framing error: reap the connection, dropping any
    /// in-flight work.
    pub dead: bool,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            written: 0,
            inflight: Vec::new(),
            read_closed: false,
            dead: false,
        }
    }

    /// Pull whatever the socket has ready into `read_buf`. Returns true
    /// if any bytes arrived. A would-block is "nothing ready"; EOF marks
    /// the read side closed; other errors kill the connection.
    pub fn read_ready(&mut self) -> bool {
        if self.read_closed || self.dead {
            return false;
        }
        let mut progressed = false;
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.read_closed = true;
                    return progressed;
                }
                Ok(n) => {
                    if let Some(head) = chunk.get(..n) {
                        self.read_buf.extend_from_slice(head);
                        progressed = true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return progressed,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return progressed;
                }
            }
        }
    }

    /// Slice one complete frame body out of the read buffer, if present.
    /// A frame longer than `max_frame` is unrecoverable (the framer can't
    /// resync) — the connection is marked dead and the oversize length
    /// returned as the error.
    pub fn take_frame(&mut self, max_frame: usize) -> Result<Option<Vec<u8>>, usize> {
        let Some(len_bytes) = self.read_buf.get(..4) else {
            return Ok(None);
        };
        let body_len = len_bytes
            .iter()
            .enumerate()
            .fold(0usize, |acc, (i, b)| acc | ((*b as usize) << (8 * i)));
        if body_len > max_frame {
            self.dead = true;
            return Err(body_len);
        }
        if self.read_buf.len() < 4 + body_len {
            return Ok(None);
        }
        let body = self.read_buf.get(4..4 + body_len).map(<[u8]>::to_vec);
        self.read_buf.drain(..4 + body_len);
        Ok(body)
    }

    /// Queue encoded response bytes, bounded by `cap`: a slow reader
    /// whose buffered backlog would exceed the cap has this response
    /// *shed* (dropped; the connection survives). Returns false on shed.
    pub fn enqueue_write(&mut self, bytes: &[u8], cap: usize) -> bool {
        if self.pending_write() + bytes.len() > cap {
            return false;
        }
        self.write_buf.extend_from_slice(bytes);
        true
    }

    pub fn pending_write(&self) -> usize {
        self.write_buf.len() - self.written
    }

    /// Push buffered response bytes into the socket without blocking.
    /// Returns true if any bytes moved.
    pub fn flush_ready(&mut self) -> bool {
        if self.dead {
            return false;
        }
        let mut progressed = false;
        while self.written < self.write_buf.len() {
            let pending = self.write_buf.get(self.written..).unwrap_or(&[]);
            match self.stream.write(pending) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.written += n;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.written == self.write_buf.len() && self.written > 0 {
            self.write_buf.clear();
            self.written = 0;
        }
        progressed
    }

    /// Nothing left to do: peer finished sending, all submitted work
    /// answered, all bytes on the wire.
    pub fn drained(&self) -> bool {
        self.read_closed && self.inflight.is_empty() && self.pending_write() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (server, client)
    }

    #[test]
    fn framer_reassembles_split_frames_and_rejects_oversize() {
        let (server, mut client) = pair();
        let mut conn = Conn::new(server);
        // A 6-byte body arriving in two halves.
        let frame = [6u8, 0, 0, 0, 1, 2, 3, 4, 5, 6];
        client.write_all(&frame[..5]).unwrap();
        while !conn.read_ready() {
            std::thread::yield_now();
        }
        assert_eq!(conn.take_frame(64).unwrap(), None, "half a frame is no frame");
        client.write_all(&frame[5..]).unwrap();
        while conn.take_frame(64).unwrap().is_none() {
            conn.read_ready();
            std::thread::yield_now();
        }
        // Oversize length prefix kills the connection.
        let mut conn2 = Conn::new(pair_stream());
        conn2.read_buf.extend_from_slice(&[255, 255, 255, 255]);
        assert!(conn2.take_frame(64).is_err());
        assert!(conn2.dead);
    }

    fn pair_stream() -> TcpStream {
        pair().0
    }

    #[test]
    fn bounded_write_buffer_sheds_on_overflow() {
        let (server, _client) = pair();
        let mut conn = Conn::new(server);
        assert!(conn.enqueue_write(&[0u8; 10], 16));
        assert!(!conn.enqueue_write(&[0u8; 10], 16), "over cap: shed");
        assert_eq!(conn.pending_write(), 10, "shed responses are not buffered");
    }
}
