//! Training configuration (the paper's §6 recipe, step-based).

/// Step-based training schedule mirroring the paper's epoch schedule
/// (initial LR 0.1, decayed ×`lr_decay` at the listed milestones).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr0: f32,
    pub lr_decay: f32,
    /// Fractions of `steps` at which LR decays (paper: 60/120/160 of 160
    /// epochs ≈ 0.375, 0.75, 1.0).
    pub milestones: Vec<f64>,
    pub seed: u64,
    /// Use the knowledge-distillation artifact when available.
    pub distill: bool,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    pub eval_batches: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr0: 0.1,
            lr_decay: 0.1,
            milestones: vec![0.375, 0.75],
            seed: 0,
            distill: false,
            eval_every: 50,
            eval_batches: 8,
        }
    }
}

impl TrainConfig {
    /// Learning rate at a given step.
    pub fn lr_at(&self, step: usize) -> f32 {
        let frac = step as f64 / self.steps.max(1) as f64;
        let decays = self.milestones.iter().filter(|&&m| frac >= m).count();
        self.lr0 * self.lr_decay.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_at_milestones() {
        let c = TrainConfig {
            steps: 100,
            lr0: 0.1,
            lr_decay: 0.1,
            milestones: vec![0.4, 0.8],
            ..TrainConfig::default()
        };
        assert_eq!(c.lr_at(0), 0.1);
        assert_eq!(c.lr_at(39), 0.1);
        assert!((c.lr_at(40) - 0.01).abs() < 1e-9);
        assert!((c.lr_at(80) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn default_is_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.lr0 > 0.0);
        assert!(c.lr_at(c.steps - 1) < c.lr0);
    }
}
