//! Trainers: the drivers that own model state between steps.
//!
//! Two backends share this module:
//!
//! * [`NativeTrainer`] (always available) — the pure-Rust masked MLP
//!   trained through the shared `kernels::dense` GEMMs, with evaluation and
//!   checkpoint-to-serving handoff running through the
//!   [`SparseKernel`](crate::kernels::registry::SparseKernel) plan layer: a
//!   [`PlanCache`] is threaded from the trainer into the
//!   [`NativeSparseModel`] it exports, so the plans built during evaluation
//!   are the very plans the inference server reuses.
//! * [`Trainer`] (feature `xla`) — drives the AOT `train_step` artifact
//!   over synthetic CIFAR-like batches; the entire compute graph (forward →
//!   loss → backward → SGD-momentum update) is one fused HLO executable and
//!   this loop only moves data and logs.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::serving::{BatchModel, InferenceServer, NativeSparseModel, ServerConfig};
use crate::data::synth::CifarLike;
use crate::kernels::autotune::TuneMode;
use crate::kernels::dense::transpose;
use crate::kernels::plan::{PlanCache, SparseMatrix};
use crate::sparsity::csr::CsrMatrix;
use crate::sparsity::memory::Pattern;
use crate::sparsity::rbgp4::Rbgp4Mask;
use crate::train_native::gradual::{is_nested, nested_masks_from, GradualSchedule};
use crate::train_native::masks::{pattern_mask, rbgp4_factorization};
use crate::train_native::mlp::{MaskedMlp, NativeTrainConfig};
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Telemetry of one gradual-induction milestone: the mask tightened, the
/// outgoing structure's plans were evicted from the shared cache, and the
/// incoming structure's plans were rebuilt (warmed) — the whole mutable
/// part of the structure lifecycle, measured.
#[derive(Clone, Debug)]
pub struct MilestoneRecord {
    /// 0-based milestone index (position in the schedule).
    pub milestone: usize,
    /// Training step after which the mask tightened.
    pub step: usize,
    /// That step's training loss.
    pub loss: f32,
    /// Mask sparsity after tightening.
    pub sparsity: f64,
    /// Structure hash of the hidden layer *after* tightening — the new
    /// plan-cache namespace.
    pub structure_hash: u64,
    /// Plans evicted for the outgoing structure at the re-key.
    pub evicted_plans: usize,
    /// Seconds to rebuild (warm) the incoming structure's plans.
    pub plan_rebuild_s: f64,
}

/// Result of a full gradual run: the milestone trace plus the usual
/// (loss, accuracy) outcome.
#[derive(Clone, Debug, Default)]
pub struct GradualReport {
    pub milestones: Vec<MilestoneRecord>,
    pub final_loss: f32,
    pub accuracy: f64,
}

/// Internal bookkeeping of a gradual run: the nested mask chain (one entry
/// per schedule fraction, ending at the exact RBGP4 mask) and the cursor
/// of the next mask to apply.
struct GradualState {
    fractions: Vec<f64>,
    chain: Vec<Vec<f32>>,
    final_mask: Rbgp4Mask,
    next: usize,
}

/// A serveable snapshot of a native model: geometry, hidden-layer mask and
/// all parameters. This is the unit the multi-model serving registry
/// consumes — two checkpoints of one gradual run (different masks, so
/// different plan-cache namespaces) can be registered side by side on one
/// pool. JSON round-trips are bit-exact for every `f32` (numbers are
/// printed in shortest-roundtrip form), so a checkpoint served from disk
/// produces logits identical to the trainer that saved it.
#[derive(Clone, Debug, PartialEq)]
pub struct NativeCheckpoint {
    pub in_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Hidden-layer mask (hidden × in_dim), 0/1.
    pub mask: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl NativeCheckpoint {
    /// The hidden layer in serving form — the same recipe as
    /// `NativeTrainer::export_w1` (pattern from the *mask*, explicit
    /// zeros kept), so the structure hash is a pure function of the mask.
    fn export_w1(&self) -> SparseMatrix {
        SparseMatrix::Csr(CsrMatrix::from_dense_with_pattern(
            &self.w1,
            &self.mask,
            self.hidden,
            self.in_dim,
        ))
    }

    /// Structure hash of the hidden layer as served — the plan-cache
    /// namespace this checkpoint's plans live under.
    pub fn structure_hash(&self) -> u64 {
        self.export_w1().structure_hash()
    }

    /// Build a plan-cached serving model for this checkpoint (default
    /// [`TuneMode::Quick`]; see [`NativeCheckpoint::serving_model_tuned`]).
    pub fn serving_model(
        &self,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
    ) -> anyhow::Result<NativeSparseModel> {
        self.serving_model_tuned(batch, threads, cache, TuneMode::default())
    }

    /// [`NativeCheckpoint::serving_model`] with an explicit autotune mode —
    /// how hard `warm()` will search for kernel schedules (once per plan
    /// key; subsequent models on the same cache hit the tuned plans).
    pub fn serving_model_tuned(
        &self,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
        tune: TuneMode,
    ) -> anyhow::Result<NativeSparseModel> {
        Ok(NativeSparseModel::new(
            self.export_w1(),
            self.b1.clone(),
            SparseMatrix::dense(self.w2.clone(), self.classes, self.hidden),
            self.b2.clone(),
            batch,
            threads,
            cache,
        )?
        .with_tune(tune))
    }

    /// A thread-safe factory producing identical warmed serving models on
    /// `cache` — the shape `InferenceServer::{start_model_as,
    /// register_model}` want. The hidden layer is compacted once here;
    /// workers clone the compact form. Default [`TuneMode::Quick`].
    pub fn serving_factory(
        &self,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
    ) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
        self.serving_factory_tuned(batch, threads, cache, TuneMode::default())
    }

    /// [`NativeCheckpoint::serving_factory`] with an explicit autotune
    /// mode. Only the first worker to warm a plan key pays the search; the
    /// rest hit the cached tuned plan.
    pub fn serving_factory_tuned(
        &self,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
        tune: TuneMode,
    ) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
        let w1 = self.export_w1();
        let b1 = self.b1.clone();
        let w2 = SparseMatrix::dense(self.w2.clone(), self.classes, self.hidden);
        let b2 = self.b2.clone();
        move || {
            let mut model = NativeSparseModel::new(
                w1.clone(),
                b1.clone(),
                w2.clone(),
                b2.clone(),
                batch,
                threads,
                Arc::clone(&cache),
            )?
            .with_tune(tune);
            model.warm()?;
            Ok(Box::new(model) as Box<dyn BatchModel>)
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let arr = |v: &[f32]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let mut j = Json::obj();
        j.set("in_dim", self.in_dim)
            .set("hidden", self.hidden)
            .set("classes", self.classes)
            .set("mask", arr(&self.mask))
            .set("w1", arr(&self.w1))
            .set("b1", arr(&self.b1))
            .set("w2", arr(&self.w2))
            .set("b2", arr(&self.b2));
        j
    }

    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<NativeCheckpoint> {
        // Strict parsing: a malformed element must fail the load, not
        // silently become a zero weight the server would then serve.
        let floats = |key: &str| -> anyhow::Result<Vec<f32>> {
            j.req_arr(key)?
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    v.as_f64().map(|x| x as f32).ok_or_else(|| {
                        anyhow::anyhow!("checkpoint field '{key}'[{i}] is not a number")
                    })
                })
                .collect()
        };
        let ckpt = NativeCheckpoint {
            in_dim: j.req_usize("in_dim")?,
            hidden: j.req_usize("hidden")?,
            classes: j.req_usize("classes")?,
            mask: floats("mask")?,
            w1: floats("w1")?,
            b1: floats("b1")?,
            w2: floats("w2")?,
            b2: floats("b2")?,
        };
        let (h, d, c) = (ckpt.hidden, ckpt.in_dim, ckpt.classes);
        anyhow::ensure!(ckpt.mask.len() == h * d, "checkpoint mask shape mismatch");
        anyhow::ensure!(ckpt.w1.len() == h * d, "checkpoint w1 shape mismatch");
        anyhow::ensure!(ckpt.b1.len() == h, "checkpoint b1 shape mismatch");
        anyhow::ensure!(ckpt.w2.len() == c * h, "checkpoint w2 shape mismatch");
        anyhow::ensure!(ckpt.b2.len() == c, "checkpoint b2 shape mismatch");
        Ok(ckpt)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<NativeCheckpoint> {
        let text = std::fs::read_to_string(path)?;
        NativeCheckpoint::from_json(&crate::util::json::Json::parse(&text)?)
    }
}

/// Fresh plan cache for a trainer, with the config's persistent tuning
/// cache attached (first-wins) when one is configured — schedule searches
/// then warm-start from the file and record their winners back to it.
fn plan_cache_for(config: &NativeTrainConfig) -> Arc<PlanCache> {
    let cache = Arc::new(PlanCache::new());
    if let Some(path) = &config.tune_cache {
        cache.attach_tune_cache(crate::kernels::TuneCache::open(path));
    }
    cache
}

/// Native trainer: masked-MLP SGD on the CIFAR-like task, plan-cached
/// evaluation/serving. The default build's training path.
pub struct NativeTrainer {
    pub mlp: MaskedMlp,
    pub config: NativeTrainConfig,
    pub metrics: Metrics,
    data: CifarLike,
    cache: Arc<PlanCache>,
    threads: usize,
    gradual: Option<GradualState>,
}

impl NativeTrainer {
    /// Build a `in_dim → hidden → classes` MLP whose hidden layer carries a
    /// fresh mask of `pattern` at `sparsity`, on the synthetic task seeded
    /// from `config.seed`.
    pub fn new(
        in_dim: usize,
        hidden: usize,
        classes: usize,
        pattern: Pattern,
        sparsity: f64,
        config: NativeTrainConfig,
    ) -> anyhow::Result<NativeTrainer> {
        let mut rng = Rng::new(config.seed);
        let mask = pattern_mask(pattern, hidden, in_dim, sparsity, &mut rng)?;
        let mlp = MaskedMlp::new(in_dim, hidden, classes, mask, &mut rng);
        let data = CifarLike::new(in_dim, classes, config.seed ^ 0x0005_ca1e);
        let cache = plan_cache_for(&config);
        Ok(NativeTrainer {
            mlp,
            config,
            metrics: Metrics::default(),
            data,
            cache,
            threads: crate::util::threadpool::default_threads(),
            gradual: None,
        })
    }

    /// Build a trainer for *gradual* structure induction (§7 future work):
    /// training starts on a fully dense hidden layer and, at each schedule
    /// fraction, tightens the mask along a nested superset chain that ends
    /// at an exact RBGP4 mask of the given total `sparsity`
    /// (factorized by [`rbgp4_factorization`], sampled once from
    /// `config.seed`). Every tightening re-keys the shared [`PlanCache`]:
    /// the outgoing structure's plans are evicted, the incoming structure's
    /// are rebuilt — see [`NativeTrainer::run_gradual`].
    pub fn new_gradual(
        in_dim: usize,
        hidden: usize,
        classes: usize,
        sparsity: f64,
        schedule: &GradualSchedule,
        config: NativeTrainConfig,
    ) -> anyhow::Result<NativeTrainer> {
        let schedule = GradualSchedule::from_fractions(schedule.fractions.clone())?;
        let rbgp = rbgp4_factorization(hidden, in_dim, sparsity)?;
        let mut rng = Rng::new(config.seed);
        let final_mask = Rbgp4Mask::sample(rbgp, &mut rng)?;
        // One mask per schedule fraction: fractions.len() - 1 intermediates
        // plus the final mask, so the *last* milestone lands on the exact
        // RBGP4 structure and trains there until the end of the run.
        let chain = nested_masks_from(&final_mask, schedule.fractions.len() - 1, &mut rng);
        debug_assert!(is_nested(&chain));
        debug_assert_eq!(chain.len(), schedule.fractions.len());
        let mlp = MaskedMlp::new(in_dim, hidden, classes, vec![1.0; hidden * in_dim], &mut rng);
        let data = CifarLike::new(in_dim, classes, config.seed ^ 0x0005_ca1e);
        let cache = plan_cache_for(&config);
        Ok(NativeTrainer {
            mlp,
            config,
            metrics: Metrics::default(),
            data,
            cache,
            threads: crate::util::threadpool::default_threads(),
            gradual: Some(GradualState {
                fractions: schedule.fractions,
                chain,
                final_mask,
                next: 0,
            }),
        })
    }

    /// Share an external plan cache (e.g. the serving process's) so plans
    /// built during evaluation are warm when the model is served.
    pub fn with_cache(mut self, cache: Arc<PlanCache>) -> NativeTrainer {
        self.cache = cache;
        self
    }

    /// Worker threads for the plan-based evaluation path.
    pub fn with_threads(mut self, threads: usize) -> NativeTrainer {
        self.threads = threads.max(1);
        self
    }

    /// One SGD step; returns the batch loss.
    pub fn step(&mut self, step_idx: usize) -> f32 {
        let b = self.data.train_batch(self.config.batch);
        let xt = transpose(&b.x, self.config.batch, self.mlp.d);
        let yt = transpose(&b.y, self.config.batch, self.mlp.c);
        let cfg = self.config.clone();
        let loss = self.mlp.train_step(&xt, &yt, cfg.batch, &cfg);
        self.metrics.record_loss(step_idx, loss);
        self.metrics.record_batch();
        loss
    }

    /// The hidden layer in serving form: CSR whose *pattern comes from the
    /// mask* (an on-mask weight that is transiently `0.0` is stored
    /// explicitly), so the structure hash — the plan-cache namespace — is a
    /// pure function of the mask: stable within a gradual milestone,
    /// changed exactly at one.
    fn export_w1(&self) -> SparseMatrix {
        SparseMatrix::Csr(CsrMatrix::from_dense_with_pattern(
            &self.mlp.w1,
            &self.mlp.mask,
            self.mlp.h,
            self.mlp.d,
        ))
    }

    /// Structure hash of the current hidden layer as it would be served —
    /// the namespace under which this trainer's plans live in the cache.
    pub fn structure_hash(&self) -> u64 {
        self.export_w1().structure_hash()
    }

    /// Snapshot the current weights in serving form: the masked hidden
    /// layer CSR-compacted on the mask pattern (see
    /// [`NativeTrainer::export_w1`]), the classifier dense. Single source
    /// of truth for the export recipe: `serving_model` (single-shot eval)
    /// and `serving_factory` (worker pool) must never diverge.
    fn export_weights(&self) -> (SparseMatrix, Vec<f32>, SparseMatrix, Vec<f32>) {
        let (h, c) = (self.mlp.h, self.mlp.c);
        (
            self.export_w1(),
            self.mlp.b1.clone(),
            SparseMatrix::dense(self.mlp.w2.clone(), c, h),
            self.mlp.b2.clone(),
        )
    }

    /// Export the current weights as a plan-cached serving model
    /// (see [`NativeTrainer::export_weights`] for the storage choices).
    pub fn serving_model(
        &self,
        batch: usize,
        threads: usize,
    ) -> anyhow::Result<NativeSparseModel> {
        let (w1, b1, w2, b2) = self.export_weights();
        Ok(
            NativeSparseModel::new(w1, b1, w2, b2, batch, threads, Arc::clone(&self.cache))?
                .with_tune(self.config.tune),
        )
    }

    /// A thread-safe factory producing identical serving models that all
    /// share this trainer's [`PlanCache`] — the shape
    /// [`InferenceServer::start_model`] wants for a multi-worker pool. Each
    /// worker builds (and warms) its own [`NativeSparseModel`] on its own
    /// thread; because every instance resolves plans from the one shared
    /// cache, the structure derivation happens once and the plans built
    /// during this trainer's evaluation are already warm.
    pub fn serving_factory(
        &self,
        batch: usize,
        threads: usize,
    ) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
        let (w1, b1, w2, b2) = self.export_weights();
        let cache = Arc::clone(&self.cache);
        let tune = self.config.tune;
        move || {
            let mut model = NativeSparseModel::new(
                w1.clone(),
                b1.clone(),
                w2.clone(),
                b2.clone(),
                batch,
                threads,
                Arc::clone(&cache),
            )?
            .with_tune(tune);
            model.warm()?;
            Ok(Box::new(model) as Box<dyn BatchModel>)
        }
    }

    /// Snapshot the current weights as a serveable [`NativeCheckpoint`] —
    /// the multi-model unit: snapshots taken at different gradual
    /// milestones carry different masks (different plan-cache namespaces)
    /// and can be registered side by side on one serving pool.
    pub fn checkpoint(&self) -> NativeCheckpoint {
        NativeCheckpoint {
            in_dim: self.mlp.d,
            hidden: self.mlp.h,
            classes: self.mlp.c,
            mask: self.mlp.mask.clone(),
            w1: self.mlp.w1.clone(),
            b1: self.mlp.b1.clone(),
            w2: self.mlp.w2.clone(),
            b2: self.mlp.b2.clone(),
        }
    }

    /// Save the current weights as a JSON checkpoint servable by
    /// `rbgp serve --model name=ckpt.json` (bit-exact round trip).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()> {
        self.checkpoint().save(path)
    }

    /// Restore weights and mask from a checkpoint (geometry validated
    /// against this trainer); momenta reset to zero.
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let ckpt = NativeCheckpoint::load(path)?;
        anyhow::ensure!(
            (ckpt.in_dim, ckpt.hidden, ckpt.classes) == (self.mlp.d, self.mlp.h, self.mlp.c),
            "checkpoint geometry {}→{}→{} does not match trainer {}→{}→{}",
            ckpt.in_dim,
            ckpt.hidden,
            ckpt.classes,
            self.mlp.d,
            self.mlp.h,
            self.mlp.c
        );
        self.mlp
            .load_params(ckpt.mask, ckpt.w1, ckpt.b1, ckpt.w2, ckpt.b2);
        Ok(())
    }

    /// The model-id/checkpoint variant of [`NativeTrainer::serving_factory`]:
    /// a factory for an arbitrary checkpoint (e.g. a gradual-run milestone
    /// snapshot) that shares **this trainer's** plan cache, so several
    /// checkpoints registered on one pool amortize their shared structures
    /// (the dense classifier, any common mask) and each adds only its own
    /// namespace.
    pub fn checkpoint_factory(
        &self,
        ckpt: &NativeCheckpoint,
        batch: usize,
        threads: usize,
    ) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + 'static {
        ckpt.serving_factory_tuned(batch, threads, Arc::clone(&self.cache), self.config.tune)
    }

    /// Spin up a multi-worker inference server on the current weights
    /// (`config.workers` workers, all sharing this trainer's plan cache).
    pub fn serve(
        &self,
        batch: usize,
        threads: usize,
        config: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        InferenceServer::start_model(self.serving_factory(batch, threads), config)
    }

    /// Held-out accuracy over `n_batches` test batches, computed through
    /// the plan-based serving path (the same kernels inference uses).
    pub fn evaluate(&mut self, n_batches: usize) -> anyhow::Result<f64> {
        let batch = self.config.batch;
        let mut model = self.serving_model(batch, self.threads)?;
        let classes = self.mlp.c;
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_batches.max(1) {
            let b = self.data.test_batch(batch);
            let logits = model.forward(&b.x)?;
            for (s, &label) in b.labels.iter().enumerate() {
                let row = &logits[s * classes..(s + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                correct += (pred == label) as usize;
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    // ---- gradual structure induction -------------------------------------

    /// The nested mask chain of a gradual trainer (one mask per schedule
    /// fraction, ending at the exact RBGP4 mask); `None` for fixed-mask
    /// trainers.
    pub fn gradual_chain(&self) -> Option<&[Vec<f32>]> {
        self.gradual.as_ref().map(|g| g.chain.as_slice())
    }

    /// The sampled final RBGP4 mask a gradual run converges to.
    pub fn gradual_final_mask(&self) -> Option<&Rbgp4Mask> {
        self.gradual.as_ref().map(|g| &g.final_mask)
    }

    /// Milestones applied so far (`Some(0)` before the first tightening).
    pub fn gradual_milestones_applied(&self) -> Option<usize> {
        self.gradual.as_ref().map(|g| g.next)
    }

    /// Apply the next mask in the chain and re-key the plan cache:
    /// 1. hash the *outgoing* structure,
    /// 2. tighten the mask (weights and momenta off the new mask zeroed),
    /// 3. evict the outgoing structure's plans ([`PlanCache::invalidate_structure`]),
    /// 4. rebuild (warm) the incoming structure's plans, timed.
    fn apply_next_milestone(&mut self, step: usize, loss: f32) -> anyhow::Result<MilestoneRecord> {
        let old_hash = self.structure_hash();
        let (milestone, mask) = {
            let g = self
                .gradual
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("trainer was not built with new_gradual"))?;
            anyhow::ensure!(g.next < g.chain.len(), "gradual chain exhausted");
            let m = g.next;
            g.next += 1;
            (m, g.chain[m].clone())
        };
        self.mlp.tighten_mask(mask);
        // The outgoing structure is dead: its plans must not linger for the
        // rest of a long run (zero stale-structure plans is asserted by the
        // integration suite via the eviction counters).
        let evicted_plans = self.cache.invalidate_structure(old_hash);
        // Warm the incoming structure — the per-milestone cost a gradual
        // run pays that a fixed-mask run does not; reported so the bench
        // can compare it against steady-state execution.
        let t0 = Instant::now();
        self.serving_model(self.config.batch, self.threads)?.warm()?;
        let plan_rebuild_s = t0.elapsed().as_secs_f64();
        Ok(MilestoneRecord {
            milestone,
            step,
            loss,
            sparsity: self.mlp.mask_sparsity(),
            structure_hash: self.structure_hash(),
            evicted_plans,
            plan_rebuild_s,
        })
    }

    /// One gradual training step: an SGD step, then any schedule milestones
    /// that came due at the completed-step fraction (each tightening the
    /// mask and re-keying the plan cache). Returns the step loss and the
    /// milestone records fired (usually zero or one).
    pub fn step_gradual(&mut self, step_idx: usize) -> anyhow::Result<(f32, Vec<MilestoneRecord>)> {
        anyhow::ensure!(
            self.gradual.is_some(),
            "trainer was not built with new_gradual"
        );
        let loss = self.step(step_idx);
        let frac = (step_idx + 1) as f64 / self.config.steps.max(1) as f64;
        let mut records = Vec::new();
        loop {
            let due = {
                let g = self.gradual.as_ref().expect("checked above");
                g.next < g.chain.len() && frac >= g.fractions[g.next]
            };
            if !due {
                break;
            }
            records.push(self.apply_next_milestone(step_idx, loss)?);
        }
        Ok((loss, records))
    }

    /// Full gradual run: dense start, schedule-driven tightening with plan
    /// re-keying at every milestone, final evaluation through the plan
    /// path. The starting structure's plans are warmed up front so the
    /// first milestone has real plans to evict and the serving path is
    /// live from step 0.
    pub fn run_gradual(&mut self) -> anyhow::Result<GradualReport> {
        anyhow::ensure!(
            self.gradual.is_some(),
            "trainer was not built with new_gradual"
        );
        let steps = self.config.steps;
        let t0 = Instant::now();
        self.serving_model(self.config.batch, self.threads)?.warm()?;
        let mut report = GradualReport::default();
        let mut loss = f32::NAN;
        for s in 0..steps {
            let (step_loss, records) = self.step_gradual(s)?;
            loss = step_loss;
            for r in &records {
                println!(
                    "milestone {} @ step {:>5}: loss {:>8.4}  sparsity {:.4}  \
                     structure {:016x}  evicted {}  rebuild {:.3} ms",
                    r.milestone,
                    r.step + 1,
                    r.loss,
                    r.sparsity,
                    r.structure_hash,
                    r.evicted_plans,
                    r.plan_rebuild_s * 1e3
                );
            }
            report.milestones.extend(records);
            if steps >= 10 && (s + 1) % (steps / 10).max(1) == 0 {
                println!(
                    "step {:>5}  loss {:>8.4}  {:>6.1}s",
                    s + 1,
                    loss,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        // Degenerate budgets (steps == 0) never reach frac 1.0; force the
        // chain to its end so the final structure always holds.
        while self.gradual.as_ref().expect("checked above").next
            < self.gradual.as_ref().expect("checked above").chain.len()
        {
            let r = self.apply_next_milestone(steps.saturating_sub(1), loss)?;
            report.milestones.push(r);
        }
        report.accuracy = self.evaluate(8)?;
        report.final_loss = self.metrics.final_loss(10).unwrap_or(loss);
        let (invalidations, evicted) = self.cache.eviction_stats();
        println!(
            "gradual done: {} steps in {:.1}s — final loss {:.4}, accuracy {:.2}%, \
             {} milestones, {} re-keys, {} plans evicted, {} structures live",
            steps,
            t0.elapsed().as_secs_f64(),
            report.final_loss,
            report.accuracy * 100.0,
            report.milestones.len(),
            invalidations,
            evicted,
            self.cache.structures().len()
        );
        Ok(report)
    }

    /// Full training run; returns (final loss, held-out accuracy). A
    /// trainer built with [`NativeTrainer::new_gradual`] runs the gradual
    /// schedule ([`NativeTrainer::run_gradual`]) — a fixed-mask `run` on it
    /// would silently never tighten.
    pub fn run(&mut self) -> anyhow::Result<(f32, f64)> {
        if self.gradual.is_some() {
            let report = self.run_gradual()?;
            return Ok((report.final_loss, report.accuracy));
        }
        let steps = self.config.steps;
        let t0 = std::time::Instant::now();
        let mut loss = f32::NAN;
        for s in 0..steps {
            loss = self.step(s);
            if steps >= 10 && (s + 1) % (steps / 10).max(1) == 0 {
                println!(
                    "step {:>5}  loss {:>8.4}  {:>6.1}s",
                    s + 1,
                    loss,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        let acc = self.evaluate(8)?;
        println!(
            "done: {} steps in {:.1}s — final loss {:.4}, accuracy {:.2}%",
            steps,
            t0.elapsed().as_secs_f64(),
            loss,
            acc * 100.0
        );
        Ok((loss, acc))
    }

    /// The plan cache the evaluation/serving path executes from.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }
}

#[cfg(feature = "xla")]
pub use xla_trainer::Trainer;

#[cfg(feature = "xla")]
mod xla_trainer {
    use crate::coordinator::config::TrainConfig;
    use crate::coordinator::metrics::Metrics;
    use crate::data::synth::CifarLike;
    use crate::runtime::executor::{Executor, HostTensor};
    use crate::util::json::Json;
    use crate::util::rng::Rng;
    use std::path::Path;

    /// Owns the compiled step/forward executables and the model state.
    pub struct Trainer {
        step_exe: Executor,
        forward_exe: Executor,
        /// Parameters in `param_order`.
        pub params: Vec<HostTensor>,
        /// Momentum buffers, same order.
        pub velocity: Vec<HostTensor>,
        pub config: TrainConfig,
        pub metrics: Metrics,
        data: CifarLike,
        batch: usize,
        in_dim: usize,
        classes: usize,
        n_params: usize,
        use_kd: bool,
    }

    impl Trainer {
        /// Load artifacts from `dir`; initial parameter values come from
        /// `init_params.json` (written by aot.py) so Rust and Python training
        /// are bit-identical at step 0.
        pub fn new(dir: &Path, config: TrainConfig) -> anyhow::Result<Trainer> {
            let use_kd = config.distill && dir.join("train_step_kd.hlo.txt").exists();
            let step_name = if use_kd { "train_step_kd" } else { "train_step" };
            let step_exe = Executor::compile(dir, step_name)?;
            let forward_exe = Executor::compile(dir, "forward")?;
            let meta = &step_exe.artifact.meta;
            let n_params = meta.param_order.len();
            anyhow::ensure!(n_params > 0, "train_step artifact lacks param_order");
            let batch = meta
                .batch()
                .ok_or_else(|| anyhow::anyhow!("train_step metadata missing batch"))?;
            let in_dim = meta.raw.req_usize("in_dim")?;
            let classes = meta.raw.req_usize("classes")?;

            // Initial parameter values.
            let init_text = std::fs::read_to_string(dir.join("init_params.json"))?;
            let init = Json::parse(&init_text)?;
            let mut params = Vec::with_capacity(n_params);
            let mut velocity = Vec::with_capacity(n_params);
            for (idx, name) in meta.param_order.iter().enumerate() {
                let sig = &meta.inputs[idx];
                anyhow::ensure!(&sig.name == name, "param order / signature mismatch");
                let vals = init
                    .req_arr(name)?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                    .collect::<Vec<f32>>();
                anyhow::ensure!(
                    vals.len() == sig.elements(),
                    "init {name}: {} values, signature wants {}",
                    vals.len(),
                    sig.elements()
                );
                params.push(HostTensor::new(vals, &sig.shape));
                velocity.push(HostTensor::zeros(&sig.shape));
            }

            let data = CifarLike::new(in_dim, classes, config.seed);
            Ok(Trainer {
                step_exe,
                forward_exe,
                params,
                velocity,
                config,
                metrics: Metrics::default(),
                data,
                batch,
                in_dim,
                classes,
                n_params,
                use_kd,
            })
        }

        pub fn batch_size(&self) -> usize {
            self.batch
        }

        /// One optimizer step; returns the loss.
        pub fn step(&mut self, step_idx: usize) -> anyhow::Result<f32> {
            let b = self.data.train_batch(self.batch);
            let lr = self.config.lr_at(step_idx);
            let mut inputs: Vec<HostTensor> =
                Vec::with_capacity(2 * self.n_params + if self.use_kd { 4 } else { 3 });
            inputs.extend(self.params.iter().cloned());
            inputs.extend(self.velocity.iter().cloned());
            inputs.push(HostTensor::new(b.x, &[self.batch, self.in_dim]));
            inputs.push(HostTensor::new(b.y.clone(), &[self.batch, self.classes]));
            if self.use_kd {
                // Teacher logits: sharpened one-hot targets stand in for a dense
                // teacher when none is provided (see DESIGN.md §Substitutions).
                let teacher: Vec<f32> = b.y.iter().map(|&v| v * 10.0).collect();
                inputs.push(HostTensor::new(teacher, &[self.batch, self.classes]));
            }
            inputs.push(HostTensor::scalar(lr));

            let mut outputs = self.step_exe.run(&inputs)?;
            let loss = outputs
                .pop()
                .ok_or_else(|| anyhow::anyhow!("no loss output"))?
                .data[0];
            let vel_new = outputs.split_off(self.n_params);
            self.params = outputs;
            self.velocity = vel_new;
            self.metrics.record_loss(step_idx, loss);
            self.metrics.record_batch();
            Ok(loss)
        }

        /// Held-out accuracy over `n_batches` test batches via the forward
        /// (Pallas-kernel) artifact.
        pub fn evaluate(&mut self, n_batches: usize) -> anyhow::Result<f64> {
            let mut correct = 0usize;
            let mut total = 0usize;
            for _ in 0..n_batches {
                let b = self.data.test_batch(self.batch);
                let mut inputs: Vec<HostTensor> = self.params.clone();
                inputs.push(HostTensor::new(b.x, &[self.batch, self.in_dim]));
                let out = self.forward_exe.run(&inputs)?;
                let logits = &out[0];
                for (s, &label) in b.labels.iter().enumerate() {
                    let row = &logits.data[s * self.classes..(s + 1) * self.classes];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    correct += (pred == label) as usize;
                    total += 1;
                }
            }
            Ok(correct as f64 / total.max(1) as f64)
        }

        /// Full training run; logs to stdout, returns (final smoothed loss,
        /// final accuracy).
        pub fn run(&mut self) -> anyhow::Result<(f32, f64)> {
            let steps = self.config.steps;
            let t0 = std::time::Instant::now();
            for s in 0..steps {
                let loss = self.step(s)?;
                let should_eval =
                    self.config.eval_every > 0 && (s + 1) % self.config.eval_every == 0;
                if should_eval || s == 0 {
                    let acc = self.evaluate(self.config.eval_batches)?;
                    println!(
                        "step {:>5}  loss {:>8.4}  acc {:>6.2}%  lr {:.4}  {:>6.1}s",
                        s + 1,
                        loss,
                        acc * 100.0,
                        self.config.lr_at(s),
                        t0.elapsed().as_secs_f64()
                    );
                }
            }
            let acc = self.evaluate(self.config.eval_batches)?;
            let loss = self.metrics.final_loss(10).unwrap_or(f32::NAN);
            println!(
                "done: {} steps in {:.1}s — final loss {:.4}, accuracy {:.2}%",
                steps,
                t0.elapsed().as_secs_f64(),
                loss,
                acc * 100.0
            );
            Ok((loss, acc))
        }

        /// A fresh RNG derived from the config seed (for callers needing
        /// auxiliary randomness that must not disturb the data streams).
        pub fn fork_rng(&self) -> Rng {
            Rng::new(self.config.seed ^ 0x7261_6E64)
        }

        /// Save trained parameters as a JSON checkpoint (same schema as
        /// `init_params.json`, so it can also be served — see
        /// `InferenceServer`/`rbgp serve --checkpoint`).
        pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<()> {
            let mut j = Json::obj();
            let order = &self.step_exe.artifact.meta.param_order;
            for (name, tensor) in order.iter().zip(&self.params) {
                j.set(
                    name,
                    Json::Arr(tensor.data.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
            }
            std::fs::write(path, j.to_string())?;
            Ok(())
        }

        /// Load parameters from a checkpoint (shapes validated against the
        /// artifact signature); momenta reset to zero.
        pub fn load_checkpoint(&mut self, path: &Path) -> anyhow::Result<()> {
            let text = std::fs::read_to_string(path)?;
            let j = Json::parse(&text)?;
            let meta = &self.step_exe.artifact.meta;
            for (idx, name) in meta.param_order.iter().enumerate() {
                let sig = &meta.inputs[idx];
                let vals: Vec<f32> = j
                    .req_arr(name)?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                anyhow::ensure!(
                    vals.len() == sig.elements(),
                    "checkpoint {name}: {} values, expected {}",
                    vals.len(),
                    sig.elements()
                );
                self.params[idx] = HostTensor::new(vals, &sig.shape);
                self.velocity[idx] = HostTensor::zeros(&sig.shape);
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config(steps: usize) -> NativeTrainConfig {
        NativeTrainConfig {
            steps,
            batch: 16,
            lr: 0.05,
            seed: 9,
            ..NativeTrainConfig::default()
        }
    }

    #[test]
    fn native_trainer_learns_and_evaluates_through_plans() {
        let mut t = NativeTrainer::new(64, 64, 4, Pattern::Rbgp4, 0.75, quick_config(80))
            .unwrap()
            .with_threads(2);
        let first = t.step(0);
        for s in 1..80 {
            t.step(s);
        }
        let last = t.metrics.final_loss(5).unwrap();
        assert!(last < first, "loss should fall: {first} → {last}");
        let acc = t.evaluate(4).unwrap();
        assert!(acc > 0.5, "accuracy {acc}");
        // Evaluation executed through the shared plan cache.
        let (_, misses) = t.cache().stats();
        assert!(misses >= 2, "both layers planned");
    }

    #[test]
    fn trainer_serves_multi_worker_from_shared_cache() {
        let mut t = NativeTrainer::new(64, 64, 4, Pattern::Rbgp4, 0.75, quick_config(10))
            .unwrap()
            .with_threads(1);
        for s in 0..10 {
            t.step(s);
        }
        let server = t
            .serve(
                8,
                1,
                ServerConfig {
                    workers: 2,
                    ..ServerConfig::default()
                },
            )
            .unwrap();
        let b = t.data.test_batch(1);
        let logits = server.infer(b.x).unwrap();
        assert_eq!(logits.len(), 4);
        // Both workers warmed their two layer plans from the trainer's one
        // cache: two structure builds ever, the other worker's resolves hit.
        let (hits, misses) = t.cache().stats();
        assert_eq!(misses, 2, "structure derived once across the pool");
        assert_eq!(hits, 2, "second worker warms from cache");
        server.shutdown();
    }

    #[test]
    fn gradual_trainer_rekeys_cache_per_milestone() {
        let schedule = GradualSchedule::from_fractions(vec![0.3, 0.6]).unwrap();
        let mut t = NativeTrainer::new_gradual(64, 64, 4, 0.75, &schedule, quick_config(60))
            .unwrap()
            .with_threads(1);
        let report = t.run_gradual().unwrap();
        assert_eq!(report.milestones.len(), 2);
        assert_eq!(t.gradual_milestones_applied(), Some(2));
        for r in &report.milestones {
            assert!(r.loss.is_finite(), "milestone {} loss", r.milestone);
            assert!(r.evicted_plans >= 1, "each re-key evicts the old plans");
        }
        // Sparsity tightens monotonically toward the config target.
        assert!(report.milestones[0].sparsity < report.milestones[1].sparsity);
        let cfg_sp = t.gradual_final_mask().unwrap().config.sparsity();
        assert!((report.milestones[1].sparsity - cfg_sp).abs() < 1e-9);
        // Final mask is the exact RBGP4 mask.
        assert_eq!(t.mlp.mask, t.gradual_final_mask().unwrap().dense());
        // One invalidation per milestone; only the final w1 structure and
        // the (stable) dense classifier structure remain cached.
        let (invalidations, evicted) = t.cache().eviction_stats();
        assert_eq!(invalidations, 2);
        assert_eq!(
            evicted,
            report.milestones.iter().map(|r| r.evicted_plans).sum::<usize>()
        );
        let structures = t.cache().structures();
        assert_eq!(structures.len(), 2, "final w1 + dense w2 only: {structures:?}");
        assert!(structures.contains(&t.structure_hash()));
        assert!(t.cache().structure_plan_count(t.structure_hash()) >= 1);
    }

    #[test]
    fn checkpoint_round_trips_bit_exact_and_serves_identically() {
        let mut t = NativeTrainer::new(64, 64, 4, Pattern::Rbgp4, 0.75, quick_config(10))
            .unwrap()
            .with_threads(1);
        for s in 0..10 {
            t.step(s);
        }
        let ckpt = t.checkpoint();
        assert_eq!(ckpt.structure_hash(), t.structure_hash());

        let path = std::env::temp_dir().join(format!("rbgp_ckpt_{}.json", std::process::id()));
        ckpt.save(&path).unwrap();
        let loaded = NativeCheckpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ckpt, loaded, "JSON round trip is bit-exact");

        // The loaded checkpoint's serving model computes bit-identical
        // logits to the trainer's own serving model.
        let batch = t.config.batch;
        let mut from_trainer = t.serving_model(batch, 1).unwrap();
        let mut from_ckpt = loaded
            .serving_model(batch, 1, Arc::new(PlanCache::new()))
            .unwrap();
        let b = t.data.test_batch(batch);
        assert_eq!(
            from_trainer.forward(&b.x).unwrap(),
            from_ckpt.forward(&b.x).unwrap()
        );

        // Restoring into a trainer reproduces the exact parameters.
        let params = t.mlp.flat_params();
        ckpt.save(&path).unwrap();
        t.load_checkpoint(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t.mlp.flat_params(), params);
        assert_eq!(t.structure_hash(), ckpt.structure_hash());
    }

    #[test]
    fn fixed_mask_trainer_rejects_gradual_stepping() {
        let mut t =
            NativeTrainer::new(64, 64, 4, Pattern::Rbgp4, 0.75, quick_config(5)).unwrap();
        assert!(t.step_gradual(0).is_err());
        assert!(t.run_gradual().is_err());
        assert!(t.gradual_chain().is_none());
        assert!(t.gradual_final_mask().is_none());
    }

    #[test]
    fn run_delegates_to_gradual_schedule() {
        let schedule = GradualSchedule::from_fractions(vec![0.5]).unwrap();
        let mut t = NativeTrainer::new_gradual(64, 64, 4, 0.75, &schedule, quick_config(20))
            .unwrap()
            .with_threads(1);
        let (loss, acc) = t.run().unwrap();
        assert!(loss.is_finite());
        assert!(acc > 0.0);
        // The schedule actually fired: the final structure is in place.
        assert_eq!(t.mlp.mask, t.gradual_final_mask().unwrap().dense());
        assert_eq!(t.cache().eviction_stats().0, 1);
    }

    #[test]
    fn serving_model_matches_training_forward() {
        let mut t = NativeTrainer::new(64, 64, 4, Pattern::Unstructured, 0.75, quick_config(30))
            .unwrap()
            .with_threads(1);
        for s in 0..30 {
            t.step(s);
        }
        let batch = t.config.batch;
        let mut model = t.serving_model(batch, 1).unwrap();
        let b = t.data.test_batch(batch);
        // Plan-path logits → argmax must equal the training-path softmax
        // argmax (softmax is monotone).
        let logits = model.forward(&b.x).unwrap();
        let xt = transpose(&b.x, batch, t.mlp.d);
        let direct_acc = t.mlp.accuracy(&xt, &b.labels, batch);
        let mut correct = 0usize;
        for (s, &label) in b.labels.iter().enumerate() {
            let row = &logits[s * t.mlp.c..(s + 1) * t.mlp.c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            correct += (pred == label) as usize;
        }
        let plan_acc = correct as f64 / batch as f64;
        assert!(
            (plan_acc - direct_acc).abs() < 1e-12,
            "plan path {plan_acc} vs direct {direct_acc}"
        );
    }
}
