//! The bounded priority request queue between client handles and workers.
//!
//! * **Bounded** — `push` never blocks and never grows the queue past its
//!   capacity; an over-capacity submit is rejected with
//!   [`ServeError::QueueFull`] so overload surfaces as backpressure at the
//!   caller instead of unbounded memory growth and latency collapse.
//! * **Priority** — entries pop in `(priority, arrival)` order: all
//!   [`Priority::High`] before [`Priority::Normal`] before
//!   [`Priority::Low`], FIFO within a class (a sequence number breaks ties
//!   so equal-priority requests cannot starve each other).
//! * **Deadlines** — a request may carry an absolute expiry [`Instant`].
//!   The queue stores it; *workers* check it at pop time (see
//!   `worker::next_live`), so an expired request is answered with a typed
//!   error and never occupies a batch slot.
//!
//! Closing the queue ([`RequestQueue::close`]) rejects new pushes with
//! [`ServeError::Stopped`] but keeps handing out already-queued entries —
//! that is what lets shutdown drain in-flight requests before joining.

use super::ServeError;
use crate::coordinator::metrics::lock_recover;
use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

/// Scheduling class of a request; classes pop strictly in this order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Served before everything else (health probes, latency-critical).
    High,
    /// The default class.
    #[default]
    Normal,
    /// Served only when no higher class is waiting (batch/offline traffic).
    Low,
}

impl Priority {
    fn rank(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Per-request submit options (see `InferenceServer::submit_with`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// Time budget from submit; once exceeded the request is rejected with
    /// [`ServeError::DeadlineExceeded`] instead of being executed. `None`
    /// falls back to the server's `default_deadline` (which may be `None`:
    /// wait forever).
    pub deadline: Option<std::time::Duration>,
}

impl SubmitOptions {
    pub fn with_priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: std::time::Duration) -> SubmitOptions {
        self.deadline = Some(deadline);
        self
    }
}

/// One queued sample plus its response channel.
pub(crate) struct QueuedRequest {
    pub x: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute expiry; `None` waits indefinitely.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Result<Vec<f32>, ServeError>>,
}

struct Entry {
    rank: u8,
    seq: u64,
    req: QueuedRequest,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.rank == other.rank && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    // BinaryHeap is a max-heap; invert so the smallest `(rank, seq)` —
    // most urgent class, earliest arrival — pops first.
    fn cmp(&self, other: &Entry) -> CmpOrdering {
        (other.rank, other.seq).cmp(&(self.rank, self.seq))
    }
}

struct QueueState {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
    closed: bool,
}

/// Bounded, closable priority queue shared by every client handle and every
/// worker. All locking goes through [`lock_recover`]: a worker that panics
/// elsewhere must not wedge the queue for the rest of the fleet.
pub(crate) struct RequestQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    cap: usize,
}

impl RequestQueue {
    pub fn new(cap: usize) -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState {
                heap: BinaryHeap::new(),
                next_seq: 0,
                closed: false,
            }),
            available: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).heap.len()
    }

    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// Enqueue `req`; returns the queue depth after the push. Fails with
    /// [`ServeError::Stopped`] once closed and [`ServeError::QueueFull`] at
    /// capacity — never blocks, never grows past `cap`.
    pub fn push(&self, req: QueuedRequest, priority: Priority) -> Result<usize, ServeError> {
        let depth = {
            let mut s = lock_recover(&self.state);
            if s.closed {
                return Err(ServeError::Stopped);
            }
            if s.heap.len() >= self.cap {
                return Err(ServeError::QueueFull { cap: self.cap });
            }
            let seq = s.next_seq;
            s.next_seq += 1;
            s.heap.push(Entry {
                rank: priority.rank(),
                seq,
                req,
            });
            s.heap.len()
        };
        self.available.notify_one();
        Ok(depth)
    }

    /// Block until an entry is available. Returns `None` only once the
    /// queue is closed *and* drained (the shutdown exit condition).
    pub fn pop_blocking(&self) -> Option<QueuedRequest> {
        let mut s = lock_recover(&self.state);
        loop {
            if let Some(e) = s.heap.pop() {
                return Some(e.req);
            }
            if s.closed {
                return None;
            }
            s = self
                .available
                .wait(s)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Pop, waiting at most until `until`; `None` on timeout or on
    /// closed-and-drained. Used by workers to fill a batch with stragglers.
    pub fn pop_until(&self, until: Instant) -> Option<QueuedRequest> {
        let mut s = lock_recover(&self.state);
        loop {
            if let Some(e) = s.heap.pop() {
                return Some(e.req);
            }
            if s.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (guard, _timeout) = self
                .available
                .wait_timeout(s, until - now)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            s = guard;
        }
    }

    /// Reject future pushes; wake every waiter. Queued entries remain
    /// poppable so workers can drain before exiting.
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.available.notify_all();
    }

    /// Close *and* answer every still-queued request with
    /// [`ServeError::Stopped`] — the last live worker's exit path. Without
    /// this, a pool whose every worker died would leave queued clients
    /// blocked on receivers nobody will ever serve.
    pub fn close_and_fail_pending(&self) {
        let drained: Vec<Entry> = {
            let mut s = lock_recover(&self.state);
            s.closed = true;
            s.heap.drain().collect()
        };
        self.available.notify_all();
        for e in drained {
            let _ = e.req.respond.send(Err(ServeError::Stopped));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    fn req(id: f32) -> (QueuedRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            QueuedRequest {
                x: vec![id],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = RequestQueue::new(16);
        for (id, p) in [
            (1.0, Priority::Normal),
            (2.0, Priority::Low),
            (3.0, Priority::High),
            (4.0, Priority::Normal),
            (5.0, Priority::High),
        ] {
            let (r, _rx) = req(id);
            q.push(r, p).unwrap();
        }
        let order: Vec<f32> = (0..5).map(|_| q.pop_blocking().unwrap().x[0]).collect();
        assert_eq!(order, vec![3.0, 5.0, 1.0, 4.0, 2.0]);
    }

    #[test]
    fn bounded_push_rejects_when_full() {
        let q = RequestQueue::new(2);
        let (r1, _x1) = req(1.0);
        let (r2, _x2) = req(2.0);
        assert_eq!(q.push(r1, Priority::Normal).unwrap(), 1);
        assert_eq!(q.push(r2, Priority::Normal).unwrap(), 2);
        let (r3, _x3) = req(3.0);
        match q.push(r3, Priority::High) {
            Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 2),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Popping frees capacity again.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        let (r4, _x4) = req(4.0);
        assert!(q.push(r4, Priority::Normal).is_ok());
    }

    #[test]
    fn close_rejects_pushes_but_drains_pops() {
        let q = RequestQueue::new(4);
        let (r1, _x1) = req(1.0);
        q.push(r1, Priority::Normal).unwrap();
        q.close();
        assert!(q.is_closed());
        let (r2, _x2) = req(2.0);
        assert!(matches!(
            q.push(r2, Priority::Normal),
            Err(ServeError::Stopped)
        ));
        // The queued entry is still served, then pops report drained.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_until(Instant::now() + Duration::from_millis(5)).is_none());
    }

    #[test]
    fn pop_until_times_out_empty() {
        let q = RequestQueue::new(4);
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn cross_thread_handoff() {
        let q = std::sync::Arc::new(RequestQueue::new(8));
        let q2 = std::sync::Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(r) = q2.pop_blocking() {
                got.push(r.x[0]);
            }
            got
        });
        let mut rxs = Vec::new();
        for id in 0..6 {
            let (r, rx) = req(id as f32);
            q.push(r, Priority::Normal).unwrap();
            rxs.push(rx);
        }
        // Give the popper a chance to drain, then close to let it exit.
        while q.len() > 0 {
            std::thread::yield_now();
        }
        q.close();
        let got = popper.join().unwrap();
        assert_eq!(got.len(), 6);
    }
}
