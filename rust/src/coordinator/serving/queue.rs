//! The bounded priority request queue between client handles and workers.
//!
//! * **Bounded** — `push` never blocks and never grows the queue past its
//!   capacity; an over-capacity submit is rejected with
//!   [`ServeError::QueueFull`] so overload surfaces as backpressure at the
//!   caller instead of unbounded memory growth and latency collapse.
//! * **Admission-controlled per model** — a push may carry a resolved
//!   per-model quota (max queued entries for that model); a submit past it
//!   is rejected with [`ServeError::ModelQuotaExceeded`] *before* the
//!   shared capacity check, so one hot model saturating its quota cannot
//!   exhaust the queue other models share. The check and the enqueue are
//!   one critical section — the per-model count is exact under races.
//! * **Priority with bounded starvation** — entries live in one FIFO
//!   deque per class and pop in `(effective rank, arrival)` order. The
//!   *effective* rank is the class rank minus one per full
//!   `max_starvation` of queue wait: a [`Priority::Low`] entry competes as
//!   `Normal` after one period and as `High` — where FIFO arrival order
//!   then favors it over younger High traffic — after two, so sustained
//!   higher-class load delays Low work by a bounded amount instead of
//!   starving it forever. `max_starvation: None` restores strict priority.
//! * **Multi-model aware, O(popped) not O(depth)** — every request carries
//!   a [`ModelClaim`](super::registry::ModelClaim), and next to the
//!   primary per-class FIFOs the queue maintains a **secondary per-model
//!   index** (model id → per-class seq FIFOs). A model-filtered pop
//!   ([`RequestQueue::pop_model_until`], the straggler-collection
//!   primitive) peeks the live front of at most `CLASSES` deques — it
//!   never scans — so its cost is bounded by entries *returned*, not by
//!   how deep a hot model has piled the queue. See "Dual views" below.
//! * **Steal hints** — [`RequestQueue::pop_model_or_steal`] is the
//!   work-stealing form of the straggler pop: instead of waiting out the
//!   full straggler window on a model whose backlog is empty, it returns
//!   [`ModelPop::Steal`] the moment *another* model has queued work, so a
//!   worker cuts its batch short and serves that backlog instead of
//!   idling.
//! * **Deadlines** — a request may carry an absolute expiry [`Instant`].
//!   The queue stores it; *workers* check it at pop time and again
//!   immediately before flushing (see `worker`), so an expired request is
//!   answered with a typed error and never executed.
//!
//! # Dual views
//!
//! Entries are owned by one seq-keyed map; both views hold seqs only:
//!
//! ```text
//!   entries: seq → Entry            (the single owner)
//!   primary: [VecDeque<seq>; 3]     per-class FIFO, arrival order
//!   by_model: id → {[VecDeque<seq>; 3], queued}   same order, one model
//! ```
//!
//! A pop removes the entry from the map and from the view it came
//! through; the seq left in the *other* view becomes a **tombstone** that
//! the next front-peek of that view discards. Every seq is pushed once
//! into each view and becomes a tombstone in at most one, so cumulative
//! tombstone cleanup is bounded by cumulative pushes — pops are amortized
//! O(1) regardless of depth or skew (debug builds assert this budget on
//! every pop, and [`RequestQueue::check_invariants`] audits the full
//! bijection between the views). `by_model` holds exactly the models with
//! at least one queued entry — its `queued` counters are what admission
//! quotas check and steal hints scan.
//!
//! Closing the queue ([`RequestQueue::close`]) rejects new pushes with
//! [`ServeError::Stopped`] but keeps handing out already-queued entries —
//! that is what lets shutdown drain in-flight requests before joining.

use super::registry::ModelClaim;
use super::ServeError;
use crate::coordinator::metrics::ServingMetrics;
use crate::util::lock_recover;
use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduling class of a request; classes pop in this order, subject to
/// age promotion (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Served before everything else (health probes, latency-critical).
    High,
    /// The default class.
    #[default]
    Normal,
    /// Served only when no higher class is waiting (batch/offline traffic),
    /// but never starved: see `max_starvation`.
    Low,
}

impl Priority {
    fn rank(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

const CLASSES: usize = 3;

/// Per-request submit options (see `InferenceServer::submit_with`).
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// Time budget from submit; once exceeded the request is rejected with
    /// [`ServeError::DeadlineExceeded`] instead of being executed. `None`
    /// falls back to the server's `default_deadline` (which may be `None`:
    /// wait forever).
    pub deadline: Option<Duration>,
    /// Registered model to route to; `None` targets the server's default
    /// model. An id that is not registered is rejected synchronously with
    /// [`ServeError::UnknownModel`].
    pub model: Option<String>,
}

impl SubmitOptions {
    pub fn with_priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> SubmitOptions {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_model(mut self, model: impl Into<String>) -> SubmitOptions {
        self.model = Some(model.into());
        self
    }
}

/// Rendezvous slot for one shadowed request: the primary leg and its
/// mirror each deposit their logits here after flushing, and whichever
/// leg arrives *second* computes and returns the max-abs divergence.
///
/// The two legs run on different workers in either order (the mirror is
/// `Priority::Low`, so it usually lands later but is not required to),
/// and either leg may never flush at all (deadline, shutdown) — the pair
/// then simply never yields a sample. Exactly one caller can observe
/// `Some`, so a divergence sample is recorded at most once per request.
///
/// Pairs complete-or-expire: creation raises the `shadow_pending` gauge,
/// and the `Drop` impl settles the accounting when the *last* leg's
/// [`QueuedRequest`] goes away — answered, deadline-expired, failed by a
/// backend error, or discarded at shutdown. A pair that never saw both
/// deposits files `shadow_dropped` exactly once. No path can leak a pair:
/// any leak would be visible as a nonzero `shadow_pending` floor.
pub struct ShadowPair {
    /// `(primary logits, mirror logits)` — each written once.
    slots: Mutex<(Option<Vec<f32>>, Option<Vec<f32>>)>,
    /// Alias whose rollout experiment this pair samples (metrics key).
    alias: String,
    /// Sink for the settle accounting in `Drop`.
    metrics: Arc<ServingMetrics>,
}

impl ShadowPair {
    pub(crate) fn new(alias: &str, metrics: &Arc<ServingMetrics>) -> Arc<ShadowPair> {
        metrics.record_shadow_begun();
        Arc::new(ShadowPair {
            slots: Mutex::new((None, None)),
            alias: alias.to_string(),
            metrics: Arc::clone(metrics),
        })
    }

    /// Deposit one leg's logits; returns `Some(max-abs divergence)` iff
    /// the other leg already deposited (i.e. this call completed the
    /// pair). Rows of unequal length compare over the shorter prefix —
    /// alias legs are geometry-validated at configuration time, so that
    /// case cannot arise in practice.
    pub(crate) fn record(&self, mirror: bool, logits: &[f32]) -> Option<f64> {
        let mut g = lock_recover(&self.slots);
        let slot = if mirror { &mut g.1 } else { &mut g.0 };
        if slot.is_some() {
            return None; // double-flush guard: first deposit wins
        }
        *slot = Some(logits.to_vec());
        match (&g.0, &g.1) {
            (Some(a), Some(b)) => Some(
                a.iter()
                    .zip(b.iter())
                    .fold(0f64, |m, (&x, &y)| m.max((f64::from(x) - f64::from(y)).abs())),
            ),
            _ => None,
        }
    }
}

impl Drop for ShadowPair {
    fn drop(&mut self) {
        // Runs when the last Arc drops — both legs' requests are gone, on
        // whatever path they took (answered, expired, backend failure,
        // mirror push rejected, queue shutdown). `get_mut` needs no lock:
        // exclusive access is what Drop means.
        let complete = match self.slots.get_mut() {
            Ok(s) => s.0.is_some() && s.1.is_some(),
            Err(poisoned) => {
                let s = poisoned.into_inner();
                s.0.is_some() && s.1.is_some()
            }
        };
        if !complete {
            self.metrics.record_shadow_dropped(&self.alias);
        }
        self.metrics.record_shadow_settled();
    }
}

/// How a request reached the queue: directly by concrete model id
/// (`route: None`), through an alias, or as the shadow mirror of an
/// aliased request. Workers use this to file per-alias latency, canary
/// and divergence metrics at flush time; the queue itself never looks at
/// it — scheduling and quotas see only the concrete [`ModelClaim`].
pub enum RouteTag {
    /// The client-facing leg of an aliased request.
    Alias {
        alias: String,
        /// This request hashed into the alias's canary split.
        canary: bool,
        /// Present iff the alias has a shadow target *and* the mirror leg
        /// was enqueued; the flushing worker deposits the primary logits
        /// here.
        shadow: Option<Arc<ShadowPair>>,
    },
    /// The mirrored leg: executed on spare capacity, never answered to a
    /// client. Its only output is the divergence deposit.
    Shadow {
        alias: String,
        pair: Arc<ShadowPair>,
    },
}

/// One queued sample plus its response channel and model routing claim.
///
/// Public so the queue-level property suite (`tests/prop_queue.rs`) and
/// benches can drive the queue directly; production code constructs these
/// only inside `InferenceServer::submit_with`.
pub struct QueuedRequest {
    pub x: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute expiry; `None` waits indefinitely.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Result<Vec<f32>, ServeError>>,
    /// Which model serves this request. Holding the claim keeps that
    /// model's in-flight count exact until the request is answered or
    /// discarded (RAII), which is what lets `unregister_model` drain.
    pub claim: ModelClaim,
    /// Alias/shadow provenance for metrics; `None` for direct submits.
    pub route: Option<RouteTag>,
}

/// Outcome of a model-filtered pop that may yield a steal hint.
pub enum ModelPop {
    /// The earliest live entry for the requested model, in
    /// `(effective rank, arrival)` order.
    Popped(QueuedRequest),
    /// The requested model has nothing queued but at least one other model
    /// does: stop waiting for stragglers that cannot exist and serve that
    /// backlog instead (only returned by
    /// [`RequestQueue::pop_model_or_steal`]).
    Steal,
    /// Nothing arrived before the timeout, or the queue is closed and this
    /// model's backlog is drained.
    Empty,
}

struct Entry {
    /// Which class FIFO (primary and per-model) this entry was filed
    /// under at push time; promotion never moves entries, it re-ranks
    /// them at peek time.
    class: usize,
    req: QueuedRequest,
}

/// The per-model half of the dual view: this model's seqs in the same
/// class/arrival order as the primary FIFOs, plus its exact live count.
#[derive(Default)]
struct ModelIndex {
    classes: [VecDeque<u64>; CLASSES],
    /// Live (non-tombstone) entries for this model — the number admission
    /// quotas compare against and `model_backlog` reports. Maintained
    /// under the queue lock, so it is exact under races and can neither
    /// go negative nor drift from the deque contents.
    queued: usize,
}

struct QueueState {
    /// Every queued entry, keyed by seq — the single owner. Both views
    /// below hold seqs only; a seq missing from this map is a tombstone.
    entries: HashMap<u64, Entry>,
    /// Primary view: one FIFO per class, arrival order. The live front of
    /// each deque is both its oldest (most promoted) and lowest-seq entry.
    classes: [VecDeque<u64>; CLASSES],
    /// Secondary view: model id → per-class FIFOs. Holds exactly the
    /// models with `queued > 0` (emptied indexes are dropped, so steal
    /// scans and admission checks are O(live models), not O(ever seen)).
    by_model: HashMap<String, ModelIndex>,
    next_seq: u64,
    closed: bool,
    /// Entries ever pushed; each contributes one seq to each view.
    pushed: u64,
    /// Tombstones discarded by front peeks. A seq becomes a tombstone in
    /// at most one view, so `tombstones_cleaned <= pushed` always — the
    /// O(popped) certificate debug builds assert on every pop.
    tombstones_cleaned: u64,
}

/// Pop dead seqs off the view's front until a live one (or nothing) is
/// left, then return it without removing it. Amortized O(1): each
/// discarded seq was one past pop's leftover in this view.
fn front_live(
    view: &mut VecDeque<u64>,
    entries: &HashMap<u64, Entry>,
    cleaned: &mut u64,
) -> Option<u64> {
    while let Some(&seq) = view.front() {
        if entries.contains_key(&seq) {
            return Some(seq);
        }
        view.pop_front();
        *cleaned += 1;
    }
    None
}

impl QueueState {
    fn len(&self) -> usize {
        self.entries.len()
    }

    /// Remove the chosen live entry from the map and from the view it was
    /// peeked through (`via_primary`); the seq in the other view becomes a
    /// tombstone. Keeps the per-model live count exact and drops the
    /// model's index when it empties.
    fn remove(&mut self, seq: u64, class: usize, via_primary: bool) -> QueuedRequest {
        // analyze: allow(panic-freedom, reason="seq was peeked from a live front under the same lock hold")
        let e = self
            .entries
            .remove(&seq)
            .expect("chosen candidate is live under the queue lock");
        debug_assert_eq!(e.class, class, "entry filed under a different class");
        // analyze: allow(panic-freedom, reason="class is the entry's stored rank, always < CLASSES")
        if via_primary {
            let popped = self.classes[class].pop_front();
            debug_assert_eq!(popped, Some(seq));
        }
        let model = e.req.claim.id();
        // analyze: allow(panic-freedom, reason="push keeps a by_model index alive for every live entry")
        let ix = self
            .by_model
            .get_mut(model)
            .expect("every live entry has a model index");
        // analyze: allow(panic-freedom, reason="class is the entry's stored rank, always < CLASSES")
        if !via_primary {
            let popped = ix.classes[class].pop_front();
            debug_assert_eq!(popped, Some(seq));
        }
        ix.queued -= 1;
        if ix.queued == 0 {
            self.by_model.remove(model);
        }
        e.req
    }
}

/// Bounded, closable priority queue shared by every client handle and every
/// worker. All locking goes through [`lock_recover`]: a worker that panics
/// elsewhere must not wedge the queue for the rest of the fleet.
pub struct RequestQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    cap: usize,
    /// Age-promotion period; `None` disables promotion (strict priority),
    /// `Duration::ZERO` promotes immediately (pops degrade to pure arrival
    /// order across classes).
    max_starvation: Option<Duration>,
}

impl RequestQueue {
    pub fn new(cap: usize, max_starvation: Option<Duration>) -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState {
                entries: HashMap::new(),
                classes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                by_model: HashMap::new(),
                next_seq: 0,
                closed: false,
                pushed: 0,
                tombstones_cleaned: 0,
            }),
            available: Condvar::new(),
            cap: cap.max(1),
            max_starvation,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// Exact number of queued (not yet popped) entries for one model.
    pub fn model_backlog(&self, model: &str) -> usize {
        lock_recover(&self.state)
            .by_model
            .get(model)
            .map_or(0, |ix| ix.queued)
    }

    /// Exact queued count of every model with backlog, sorted by id.
    pub fn model_backlogs(&self) -> Vec<(String, usize)> {
        let s = lock_recover(&self.state);
        let mut v: Vec<(String, usize)> = s
            .by_model
            .iter()
            .map(|(m, ix)| (m.clone(), ix.queued))
            .collect();
        v.sort();
        v
    }

    /// Enqueue `req`; returns the queue depth after the push. Fails with
    /// [`ServeError::Stopped`] once closed, with
    /// [`ServeError::ModelQuotaExceeded`] when the request's model already
    /// has `quota` entries queued, and with [`ServeError::QueueFull`] at
    /// shared capacity — never blocks, never grows past `cap`. The quota
    /// check runs first: a model at its quota is told so even when the
    /// shared queue still has room, and its rejection frees no capacity
    /// other models could have used.
    pub fn push(
        &self,
        req: QueuedRequest,
        priority: Priority,
        quota: Option<usize>,
    ) -> Result<usize, ServeError> {
        let depth = {
            let mut s = lock_recover(&self.state);
            if s.closed {
                return Err(ServeError::Stopped);
            }
            let model = req.claim.id();
            if let Some(limit) = quota {
                let queued = s.by_model.get(model).map_or(0, |ix| ix.queued);
                if queued >= limit {
                    return Err(ServeError::ModelQuotaExceeded {
                        model: model.to_string(),
                        quota: limit,
                    });
                }
            }
            if s.entries.len() >= self.cap {
                return Err(ServeError::QueueFull { cap: self.cap });
            }
            let seq = s.next_seq;
            s.next_seq += 1;
            s.pushed += 1;
            let class = priority.rank();
            // analyze: allow(panic-freedom, reason="Priority::rank() is bounded below CLASSES")
            s.classes[class].push_back(seq);
            // The common case — the model already has backlog — must not
            // allocate its id again under the lock; only the first entry
            // of a burst pays the `String` key.
            // analyze: allow(panic-freedom, reason="class is Priority::rank(), bounded below CLASSES")
            if let Some(ix) = s.by_model.get_mut(model) {
                ix.classes[class].push_back(seq);
                ix.queued += 1;
            } else {
                let ix = s.by_model.entry(model.to_string()).or_default();
                ix.classes[class].push_back(seq);
                ix.queued += 1;
            }
            s.entries.insert(seq, Entry { class, req });
            s.entries.len()
        };
        // Wake every waiter: some may be model-filtered straggler waits
        // that this push does not satisfy, and the one it does satisfy
        // must not sleep through it.
        self.available.notify_all();
        Ok(depth)
    }

    /// Class rank after age promotion: one class per full `max_starvation`
    /// waited, saturating at High.
    fn effective_rank(&self, class: usize, now: Instant, enqueued: Instant) -> usize {
        match self.max_starvation {
            // A zero period promotes immediately — every live entry
            // competes at the top class and the seq tie-break makes pops
            // pure arrival order. Guarded here so the division below is
            // never by zero (a `Duration::ZERO` config used to be
            // silently coerced to strict priority, the opposite of what
            // "promote after zero wait" means).
            Some(period) if period.is_zero() => 0,
            Some(period) => {
                let waited = now.saturating_duration_since(enqueued);
                class.saturating_sub((waited.as_nanos() / period.as_nanos()) as usize)
            }
            None => class,
        }
    }

    /// Remove and return the most urgent entry — smallest
    /// `(effective rank, seq)` — optionally restricted to one model. The
    /// candidates are the live fronts of at most `CLASSES` deques (the
    /// primary ones, or the model's own index): within a class+model, the
    /// front is both the oldest (most promoted) and the lowest-seq entry,
    /// so peeking fronts is exhaustive. This never iterates entries —
    /// cost is O(1) per call plus amortized tombstone cleanup, bounded by
    /// entries returned across the queue's lifetime, not by queue depth.
    fn take_next(&self, s: &mut QueueState, model: Option<&str>) -> Option<QueuedRequest> {
        let now = Instant::now();
        let mut best: Option<(usize, u64, usize)> = None; // (eff, seq, class)
        for class in 0..CLASSES {
            // analyze: allow(panic-freedom, reason="class iterates 0..CLASSES and both deque arrays have CLASSES slots")
            let front = match model {
                None => front_live(&mut s.classes[class], &s.entries, &mut s.tombstones_cleaned),
                Some(m) => match s.by_model.get_mut(m) {
                    Some(ix) => {
                        front_live(&mut ix.classes[class], &s.entries, &mut s.tombstones_cleaned)
                    }
                    None => None,
                },
            };
            let Some(seq) = front else { continue };
            // analyze: allow(panic-freedom, reason="front_live only returns seqs that are live in entries")
            let enqueued = s.entries[&seq].req.enqueued;
            let eff = self.effective_rank(class, now, enqueued);
            if best.is_none_or(|(be, bs, _)| (eff, seq) < (be, bs)) {
                best = Some((eff, seq, class));
            }
        }
        // The O(popped) certificate: beyond the constant per-call front
        // peeks above, the only loop in this function is tombstone cleanup
        // — and a seq tombstones in at most one view, so cumulative
        // cleanup can never exceed cumulative pushes, no matter how deep
        // or skewed the queue gets. An O(depth) scan creeping back into
        // the pop path would blow this budget immediately.
        debug_assert!(
            s.tombstones_cleaned <= s.pushed,
            "pop scanned past its tombstone budget (cleaned {} > pushed {})",
            s.tombstones_cleaned,
            s.pushed,
        );
        let (_, seq, class) = best?;
        Some(s.remove(seq, class, model.is_none()))
    }

    /// The one pop loop behind every public pop: optional model filter,
    /// optional timeout, optional steal hint.
    fn pop_filtered(
        &self,
        model: Option<&str>,
        until: Option<Instant>,
        steal_hint: bool,
    ) -> ModelPop {
        debug_assert!(model.is_some() || !steal_hint, "steal hints are model-filtered");
        let mut s = lock_recover(&self.state);
        loop {
            if let Some(req) = self.take_next(&mut s, model) {
                return ModelPop::Popped(req);
            }
            // With a filter, `take_next` returning `None` means the model
            // has zero live entries (its index exists iff it has backlog),
            // so any surviving index is *another* model's backlog the
            // caller could serve instead of waiting here.
            if steal_hint && !s.by_model.is_empty() {
                return ModelPop::Steal;
            }
            if s.closed {
                return ModelPop::Empty;
            }
            match until {
                None => {
                    s = self
                        .available
                        .wait(s)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                Some(t) => {
                    let now = Instant::now();
                    if now >= t {
                        return ModelPop::Empty;
                    }
                    let (guard, _timeout) = self
                        .available
                        .wait_timeout(s, t - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    s = guard;
                }
            }
        }
    }

    /// Block until an entry is available. Returns `None` only once the
    /// queue is closed *and* drained (the shutdown exit condition).
    pub fn pop_blocking(&self) -> Option<QueuedRequest> {
        match self.pop_filtered(None, None, false) {
            ModelPop::Popped(req) => Some(req),
            _ => None,
        }
    }

    /// Pop, waiting at most until `until`; `None` on timeout or on
    /// closed-and-drained.
    pub fn pop_until(&self, until: Instant) -> Option<QueuedRequest> {
        match self.pop_filtered(None, Some(until), false) {
            ModelPop::Popped(req) => Some(req),
            _ => None,
        }
    }

    /// Pop the earliest entry *for one model*, waiting at most until
    /// `until`. The straggler-collection primitive: a worker filling a
    /// batch for `model` takes only that model's requests, so a flush
    /// never mixes models and other models' entries stay queued in order.
    pub fn pop_model_until(&self, model: &str, until: Instant) -> Option<QueuedRequest> {
        match self.pop_filtered(Some(model), Some(until), false) {
            ModelPop::Popped(req) => Some(req),
            _ => None,
        }
    }

    /// [`RequestQueue::pop_model_until`] with a steal hint: returns
    /// [`ModelPop::Steal`] the moment `model`'s backlog is empty while
    /// another model has queued work, so the caller can cut its straggler
    /// window and serve that backlog instead of idling until `until`.
    pub fn pop_model_or_steal(&self, model: &str, until: Instant) -> ModelPop {
        self.pop_filtered(Some(model), Some(until), true)
    }

    /// Reject future pushes; wake every waiter. Queued entries remain
    /// poppable so workers can drain before exiting.
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.available.notify_all();
    }

    /// Close *and* answer every still-queued request with
    /// [`ServeError::Stopped`] — the last live worker's exit path. Without
    /// this, a pool whose every worker died would leave queued clients
    /// blocked on receivers nobody will ever serve.
    pub fn close_and_fail_pending(&self) {
        let mut drained: Vec<(u64, Entry)> = {
            let mut s = lock_recover(&self.state);
            s.closed = true;
            for view in &mut s.classes {
                view.clear();
            }
            s.by_model.clear();
            s.entries.drain().collect()
        };
        self.available.notify_all();
        // Fail in arrival order: deterministic for tests and fair to the
        // longest waiters.
        drained.sort_by_key(|(seq, _)| *seq);
        for (_, e) in drained {
            let _ = e.req.respond.send(Err(ServeError::Stopped));
        }
    }

    /// Full O(n) audit of the dual-view bijection, for the property suite
    /// and fault-injection tests — not a hot-path helper. Panics with a
    /// description on the first violated invariant:
    ///
    /// * every live entry's seq appears exactly once in the primary view
    ///   and exactly once in its own model's index, in its push class;
    /// * both views keep strictly increasing seqs (FIFO/arrival order);
    /// * every model index has `queued > 0` and `queued` equal to its live
    ///   entry count (quota accounting can neither leak nor go negative);
    /// * cumulative tombstone cleanup is within the O(popped) budget.
    #[doc(hidden)]
    pub fn check_invariants(&self) {
        let s = lock_recover(&self.state);
        let mut live_primary = 0usize;
        for (class, view) in s.classes.iter().enumerate() {
            let mut last: Option<u64> = None;
            for &seq in view {
                assert!(
                    last.is_none_or(|p| p < seq),
                    "primary class {class} out of arrival order at seq {seq}"
                );
                last = Some(seq);
                if let Some(e) = s.entries.get(&seq) {
                    assert_eq!(e.class, class, "live seq {seq} filed under the wrong class");
                    live_primary += 1;
                }
            }
        }
        assert_eq!(
            live_primary,
            s.entries.len(),
            "primary view must hold every live entry exactly once"
        );
        let mut live_by_model = 0usize;
        for (model, ix) in &s.by_model {
            assert!(ix.queued > 0, "empty index for model '{model}' was not dropped");
            let mut live_here = 0usize;
            for (class, view) in ix.classes.iter().enumerate() {
                let mut last: Option<u64> = None;
                for &seq in view {
                    assert!(
                        last.is_none_or(|p| p < seq),
                        "model '{model}' class {class} out of arrival order at seq {seq}"
                    );
                    last = Some(seq);
                    if let Some(e) = s.entries.get(&seq) {
                        assert_eq!(
                            e.req.claim.id(),
                            model.as_str(),
                            "seq {seq} indexed under a foreign model"
                        );
                        assert_eq!(e.class, class, "model view disagrees on seq {seq}'s class");
                        live_here += 1;
                    }
                }
            }
            assert_eq!(
                live_here, ix.queued,
                "model '{model}' queued count drifted from its live entries"
            );
            live_by_model += live_here;
        }
        assert_eq!(
            live_by_model,
            s.entries.len(),
            "model views must hold every live entry exactly once"
        );
        assert!(
            s.tombstones_cleaned <= s.pushed,
            "tombstone cleanup ({}) exceeded pushes ({}) — pops are not O(popped)",
            s.tombstones_cleaned,
            s.pushed,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::registry::ModelClaim;
    use std::sync::mpsc;

    fn q(cap: usize) -> RequestQueue {
        // Promotion period far beyond test runtimes: strict priority.
        RequestQueue::new(cap, Some(Duration::from_secs(3600)))
    }

    fn req(id: f32) -> (QueuedRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        req_for("m", id)
    }

    fn req_for(
        model: &str,
        id: f32,
    ) -> (QueuedRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            QueuedRequest {
                x: vec![id],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
                claim: ModelClaim::detached(model, 1, 1, 1),
                route: None,
            },
            rx,
        )
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = q(16);
        for (id, p) in [
            (1.0, Priority::Normal),
            (2.0, Priority::Low),
            (3.0, Priority::High),
            (4.0, Priority::Normal),
            (5.0, Priority::High),
        ] {
            let (r, _rx) = req(id);
            q.push(r, p, None).unwrap();
        }
        q.check_invariants();
        let order: Vec<f32> = (0..5).map(|_| q.pop_blocking().unwrap().x[0]).collect();
        assert_eq!(order, vec![3.0, 5.0, 1.0, 4.0, 2.0]);
        q.check_invariants();
    }

    #[test]
    fn bounded_push_rejects_when_full() {
        let q = q(2);
        let (r1, _x1) = req(1.0);
        let (r2, _x2) = req(2.0);
        assert_eq!(q.push(r1, Priority::Normal, None).unwrap(), 1);
        assert_eq!(q.push(r2, Priority::Normal, None).unwrap(), 2);
        let (r3, _x3) = req(3.0);
        match q.push(r3, Priority::High, None) {
            Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 2),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Popping frees capacity again.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        let (r4, _x4) = req(4.0);
        assert!(q.push(r4, Priority::Normal, None).is_ok());
    }

    #[test]
    fn model_quota_is_exact_and_frees_on_pop() {
        let q = q(16);
        let quota = Some(2);
        let (a1, _ra1) = req_for("hot", 1.0);
        let (a2, _ra2) = req_for("hot", 2.0);
        assert!(q.push(a1, Priority::Normal, quota).is_ok());
        assert!(q.push(a2, Priority::Normal, quota).is_ok());
        assert_eq!(q.model_backlog("hot"), 2);
        // Third hot push: typed per-model rejection, not QueueFull.
        let (a3, _ra3) = req_for("hot", 3.0);
        match q.push(a3, Priority::High, quota) {
            Err(ServeError::ModelQuotaExceeded { model, quota }) => {
                assert_eq!((model.as_str(), quota), ("hot", 2));
            }
            other => panic!("expected ModelQuotaExceeded, got {other:?}"),
        }
        // A saturated hot model does not block other models' submits.
        let (c1, _rc1) = req_for("cold", 4.0);
        assert!(q.push(c1, Priority::Normal, Some(2)).is_ok());
        assert_eq!(q.model_backlog("cold"), 1);
        // Popping a hot entry frees hot quota again.
        assert_eq!(q.pop_model_until("hot", Instant::now()).unwrap().x[0], 1.0);
        let (a4, _ra4) = req_for("hot", 5.0);
        assert!(q.push(a4, Priority::Normal, quota).is_ok());
        assert_eq!(q.model_backlog("hot"), 2);
        q.check_invariants();
    }

    #[test]
    fn close_rejects_pushes_but_drains_pops() {
        let q = q(4);
        let (r1, _x1) = req(1.0);
        q.push(r1, Priority::Normal, None).unwrap();
        q.close();
        assert!(q.is_closed());
        let (r2, _x2) = req(2.0);
        assert!(matches!(
            q.push(r2, Priority::Normal, None),
            Err(ServeError::Stopped)
        ));
        // The queued entry is still served, then pops report drained.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_until(Instant::now() + Duration::from_millis(5)).is_none());
    }

    #[test]
    fn pop_until_times_out_empty() {
        let q = q(4);
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn model_filtered_pop_skips_other_models_in_order() {
        let q = q(16);
        let mut rxs = Vec::new();
        for (model, id, p) in [
            ("a", 1.0, Priority::Normal),
            ("b", 2.0, Priority::Normal),
            ("a", 3.0, Priority::Low),
            ("b", 4.0, Priority::High),
            ("a", 5.0, Priority::Normal),
        ] {
            let (r, rx) = req_for(model, id);
            q.push(r, p, None).unwrap();
            rxs.push(rx);
        }
        assert_eq!(
            q.model_backlogs(),
            vec![("a".to_string(), 3), ("b".to_string(), 2)]
        );
        let until = Instant::now() + Duration::from_millis(5);
        // Model-a entries come out in (priority, arrival) order…
        let a1 = q.pop_model_until("a", until).unwrap();
        assert_eq!((a1.claim.id(), a1.x[0]), ("a", 1.0));
        assert_eq!(q.pop_model_until("a", until).unwrap().x[0], 5.0);
        q.check_invariants();
        assert_eq!(q.pop_model_until("a", until).unwrap().x[0], 3.0);
        // …a drained model times out…
        assert!(q.pop_model_until("a", Instant::now() + Duration::from_millis(5)).is_none());
        assert_eq!(q.model_backlog("a"), 0);
        // …and model-b entries kept their own order throughout.
        assert_eq!(q.pop_model_until("b", until).unwrap().x[0], 4.0);
        assert_eq!(q.pop_blocking().map(|r| r.x[0]), Some(2.0));
        assert_eq!(q.len(), 0);
        q.check_invariants();
    }

    #[test]
    fn steal_hint_fires_only_when_other_backlog_exists() {
        let q = q(16);
        // Empty queue: no hint, just a timeout.
        assert!(matches!(
            q.pop_model_or_steal("a", Instant::now() + Duration::from_millis(5)),
            ModelPop::Empty
        ));
        let (ra, _xa) = req_for("a", 1.0);
        q.push(ra, Priority::Normal, None).unwrap();
        // Own backlog: popped, never a hint.
        assert!(matches!(
            q.pop_model_or_steal("a", Instant::now() + Duration::from_millis(5)),
            ModelPop::Popped(r) if r.x[0] == 1.0
        ));
        // Another model's backlog while "a" is drained: immediate hint,
        // well before the timeout.
        let (rb, _xb) = req_for("b", 2.0);
        q.push(rb, Priority::Low, None).unwrap();
        let t0 = Instant::now();
        assert!(matches!(
            q.pop_model_or_steal("a", t0 + Duration::from_secs(5)),
            ModelPop::Steal
        ));
        assert!(t0.elapsed() < Duration::from_secs(1), "hint must not wait");
        // The plain straggler pop keeps the old semantics: waits out the
        // timeout rather than hinting.
        assert!(q
            .pop_model_until("a", Instant::now() + Duration::from_millis(10))
            .is_none());
        assert_eq!(q.model_backlog("b"), 1);
        q.check_invariants();
    }

    #[test]
    fn aged_low_entry_is_promoted_past_sustained_high_traffic() {
        let period = Duration::from_millis(25);
        let q = RequestQueue::new(64, Some(period));
        let (low, _rx_low) = req(1.0);
        q.push(low, Priority::Low, None).unwrap();
        // Sustained High traffic: a fresh High entry arrives before every
        // pop. Strict priority would starve the Low entry forever; with
        // age promotion it must surface within ~2 promotion periods.
        let mut served_low_after = None;
        let mut rxs = Vec::new();
        for i in 0..40 {
            let (high, rx) = req(100.0 + i as f32);
            q.push(high, Priority::High, None).unwrap();
            rxs.push(rx);
            std::thread::sleep(Duration::from_millis(5));
            if q.pop_blocking().unwrap().x[0] == 1.0 {
                served_low_after = Some(i);
                break;
            }
        }
        let rounds = served_low_after.expect("aged Low entry must be served under High load");
        // Promotion to High takes 2 × 25 ms; at ≥5 ms per round the Low
        // entry must win well before the traffic stops.
        assert!(rounds < 39, "promoted far too late: {rounds} rounds");

        // Control: with promotion disabled the same pattern starves Low.
        let strict = RequestQueue::new(64, None);
        let (low, _rx_low2) = req(1.0);
        strict.push(low, Priority::Low, None).unwrap();
        for i in 0..10 {
            let (high, rx) = req(200.0 + i as f32);
            strict.push(high, Priority::High, None).unwrap();
            rxs.push(rx);
            std::thread::sleep(Duration::from_millis(5));
            assert_ne!(
                strict.pop_blocking().unwrap().x[0],
                1.0,
                "strict priority must not promote"
            );
        }
    }

    #[test]
    fn cross_thread_handoff() {
        let q = std::sync::Arc::new(self::q(8));
        let q2 = std::sync::Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(r) = q2.pop_blocking() {
                got.push(r.x[0]);
            }
            got
        });
        let mut rxs = Vec::new();
        for id in 0..6 {
            let (r, rx) = req(id as f32);
            q.push(r, Priority::Normal, None).unwrap();
            rxs.push(rx);
        }
        // Give the popper a chance to drain, then close to let it exit.
        while q.len() > 0 {
            std::thread::yield_now();
        }
        q.close();
        let got = popper.join().unwrap();
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn close_and_fail_pending_answers_in_arrival_order() {
        let q = q(8);
        let mut rxs = Vec::new();
        for (model, id) in [("a", 1.0), ("b", 2.0), ("a", 3.0)] {
            let (r, rx) = req_for(model, id);
            q.push(r, Priority::Normal, None).unwrap();
            rxs.push(rx);
        }
        q.close_and_fail_pending();
        for rx in &rxs {
            assert!(matches!(rx.recv().unwrap(), Err(ServeError::Stopped)));
        }
        assert_eq!(q.len(), 0);
        assert!(q.model_backlogs().is_empty());
        q.check_invariants();
    }

    #[test]
    fn shadow_pair_yields_exactly_one_divergence_sample() {
        let metrics = Arc::new(ServingMetrics::new(1));
        // Second depositor computes the divergence, whichever order the
        // legs land in.
        let p = ShadowPair::new("prod", &metrics);
        assert!(p.record(false, &[1.0, 2.0]).is_none());
        let d = p.record(true, &[1.0, 2.5]).expect("pair completed");
        assert!((d - 0.5).abs() < 1e-9);

        let p = ShadowPair::new("prod", &metrics);
        assert!(p.record(true, &[0.0, -3.0]).is_none());
        let d = p.record(false, &[0.0, 1.0]).expect("pair completed");
        assert!((d - 4.0).abs() < 1e-9);

        // A duplicate flush of the same leg never yields a second sample.
        assert!(p.record(false, &[9.0, 9.0]).is_none());
        assert!(p.record(true, &[9.0, 9.0]).is_none());
    }

    #[test]
    fn shadow_pair_drop_settles_gauge_and_counts_incomplete_as_dropped() {
        let metrics = Arc::new(ServingMetrics::new(1));

        // Completed pair: gauge returns to zero, nothing dropped.
        let p = ShadowPair::new("prod", &metrics);
        assert_eq!(metrics.shadow_pending(), 1);
        assert!(p.record(false, &[1.0]).is_none());
        assert!(p.record(true, &[1.0]).is_some());
        drop(p);
        assert_eq!(metrics.shadow_pending(), 0);
        assert!(
            metrics.alias_stats().iter().all(|a| a.shadow_dropped == 0),
            "a completed pair is never dropped coverage"
        );

        // One-deposit pair (the other leg died): dropped coverage.
        let p = ShadowPair::new("prod", &metrics);
        assert!(p.record(false, &[1.0]).is_none());
        drop(p);
        // Zero-deposit pair (both legs died): still exactly one drop.
        drop(ShadowPair::new("prod", &metrics));
        assert_eq!(metrics.shadow_pending(), 0);
        assert_eq!(metrics.alias_stats()[0].shadow_dropped, 2);
    }

    #[test]
    fn zero_starvation_period_promotes_immediately() {
        // Regression: `Duration::ZERO` used to be silently filtered to
        // `None` (strict priority — the opposite of promote-immediately),
        // and feeding it to `effective_rank` unfiltered would divide by
        // zero. With the guard, a zero period serves in arrival order.
        let q = RequestQueue::new(16, Some(Duration::ZERO));
        for (id, p) in [
            (1.0, Priority::Low),
            (2.0, Priority::High),
            (3.0, Priority::Normal),
        ] {
            let (r, _rx) = req(id);
            q.push(r, p, None).unwrap();
        }
        let order: Vec<f32> = (0..3).map(|_| q.pop_blocking().unwrap().x[0]).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0], "zero period = pure arrival order");
        q.check_invariants();

        // Control: the same traffic under strict priority pops High first.
        let q = RequestQueue::new(16, None);
        for (id, p) in [
            (1.0, Priority::Low),
            (2.0, Priority::High),
            (3.0, Priority::Normal),
        ] {
            let (r, _rx) = req(id);
            q.push(r, p, None).unwrap();
        }
        let order: Vec<f32> = (0..3).map(|_| q.pop_blocking().unwrap().x[0]).collect();
        assert_eq!(order, vec![2.0, 3.0, 1.0]);
    }
}
