//! The bounded priority request queue between client handles and workers.
//!
//! * **Bounded** — `push` never blocks and never grows the queue past its
//!   capacity; an over-capacity submit is rejected with
//!   [`ServeError::QueueFull`] so overload surfaces as backpressure at the
//!   caller instead of unbounded memory growth and latency collapse.
//! * **Priority with bounded starvation** — entries live in one FIFO
//!   deque per class and pop in `(effective rank, arrival)` order. The
//!   *effective* rank is the class rank minus one per full
//!   `max_starvation` of queue wait: a [`Priority::Low`] entry competes as
//!   `Normal` after one period and as `High` — where FIFO arrival order
//!   then favors it over younger High traffic — after two, so sustained
//!   higher-class load delays Low work by a bounded amount instead of
//!   starving it forever. `max_starvation: None` restores strict priority.
//! * **Multi-model aware** — every request carries a
//!   [`ModelClaim`](super::registry::ModelClaim); workers use
//!   [`RequestQueue::pop_model_until`] to collect stragglers *of one
//!   model only*, so a flush never mixes models while other models'
//!   requests keep their queue positions.
//! * **Deadlines** — a request may carry an absolute expiry [`Instant`].
//!   The queue stores it; *workers* check it at pop time and again
//!   immediately before flushing (see `worker`), so an expired request is
//!   answered with a typed error and never executed.
//!
//! Closing the queue ([`RequestQueue::close`]) rejects new pushes with
//! [`ServeError::Stopped`] but keeps handing out already-queued entries —
//! that is what lets shutdown drain in-flight requests before joining.

use super::registry::ModelClaim;
use super::ServeError;
use crate::util::lock_recover;
use std::collections::VecDeque;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduling class of a request; classes pop in this order, subject to
/// age promotion (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Served before everything else (health probes, latency-critical).
    High,
    /// The default class.
    #[default]
    Normal,
    /// Served only when no higher class is waiting (batch/offline traffic),
    /// but never starved: see `max_starvation`.
    Low,
}

impl Priority {
    fn rank(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

const CLASSES: usize = 3;

/// Per-request submit options (see `InferenceServer::submit_with`).
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// Time budget from submit; once exceeded the request is rejected with
    /// [`ServeError::DeadlineExceeded`] instead of being executed. `None`
    /// falls back to the server's `default_deadline` (which may be `None`:
    /// wait forever).
    pub deadline: Option<Duration>,
    /// Registered model to route to; `None` targets the server's default
    /// model. An id that is not registered is rejected synchronously with
    /// [`ServeError::UnknownModel`].
    pub model: Option<String>,
}

impl SubmitOptions {
    pub fn with_priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> SubmitOptions {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_model(mut self, model: impl Into<String>) -> SubmitOptions {
        self.model = Some(model.into());
        self
    }
}

/// One queued sample plus its response channel and model routing claim.
pub(crate) struct QueuedRequest {
    pub x: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute expiry; `None` waits indefinitely.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Result<Vec<f32>, ServeError>>,
    /// Which model serves this request. Holding the claim keeps that
    /// model's in-flight count exact until the request is answered or
    /// discarded (RAII), which is what lets `unregister_model` drain.
    pub claim: ModelClaim,
}

struct Entry {
    seq: u64,
    req: QueuedRequest,
}

struct QueueState {
    /// One FIFO per class, indexed by `Priority::rank` — FIFO within a
    /// class is arrival order, and the front of each deque is both its
    /// oldest (most promoted) and lowest-seq entry.
    classes: [VecDeque<Entry>; CLASSES],
    next_seq: u64,
    closed: bool,
}

impl QueueState {
    fn len(&self) -> usize {
        self.classes.iter().map(VecDeque::len).sum()
    }
}

/// Bounded, closable priority queue shared by every client handle and every
/// worker. All locking goes through [`lock_recover`]: a worker that panics
/// elsewhere must not wedge the queue for the rest of the fleet.
pub(crate) struct RequestQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    cap: usize,
    /// Age-promotion period; `None` disables promotion (strict priority).
    max_starvation: Option<Duration>,
}

impl RequestQueue {
    pub fn new(cap: usize, max_starvation: Option<Duration>) -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState {
                classes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                next_seq: 0,
                closed: false,
            }),
            available: Condvar::new(),
            cap: cap.max(1),
            max_starvation: max_starvation.filter(|s| !s.is_zero()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).len()
    }

    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// Enqueue `req`; returns the queue depth after the push. Fails with
    /// [`ServeError::Stopped`] once closed and [`ServeError::QueueFull`] at
    /// capacity — never blocks, never grows past `cap`.
    pub fn push(&self, req: QueuedRequest, priority: Priority) -> Result<usize, ServeError> {
        let depth = {
            let mut s = lock_recover(&self.state);
            if s.closed {
                return Err(ServeError::Stopped);
            }
            if s.len() >= self.cap {
                return Err(ServeError::QueueFull { cap: self.cap });
            }
            let seq = s.next_seq;
            s.next_seq += 1;
            s.classes[priority.rank()].push_back(Entry { seq, req });
            s.len()
        };
        // Wake every waiter: some may be model-filtered straggler waits
        // that this push does not satisfy, and the one it does satisfy
        // must not sleep through it.
        self.available.notify_all();
        Ok(depth)
    }

    /// Class rank after age promotion: one class per full `max_starvation`
    /// waited, saturating at High.
    fn effective_rank(&self, class: usize, now: Instant, enqueued: Instant) -> usize {
        match self.max_starvation {
            Some(period) => {
                let waited = now.saturating_duration_since(enqueued);
                class.saturating_sub((waited.as_nanos() / period.as_nanos()) as usize)
            }
            None => class,
        }
    }

    /// Remove and return the most urgent entry — smallest
    /// `(effective rank, seq)` — optionally restricted to one model. With
    /// a filter, the candidate per class is its earliest *matching* entry,
    /// so other models' requests keep their positions untouched.
    fn take_next(&self, s: &mut QueueState, model: Option<&str>) -> Option<QueuedRequest> {
        let now = Instant::now();
        let mut best: Option<(usize, u64, usize, usize)> = None; // (eff, seq, class, idx)
        for class in 0..CLASSES {
            let candidate = match model {
                None => s.classes[class].front().map(|e| (0, e)),
                Some(m) => s.classes[class]
                    .iter()
                    .enumerate()
                    .find(|(_, e)| e.req.claim.id() == m),
            };
            if let Some((idx, e)) = candidate {
                let eff = self.effective_rank(class, now, e.req.enqueued);
                if best.is_none_or(|(be, bs, _, _)| (eff, e.seq) < (be, bs)) {
                    best = Some((eff, e.seq, class, idx));
                }
            }
        }
        best.map(|(_, _, class, idx)| {
            s.classes[class]
                .remove(idx)
                .expect("candidate index is in range under the lock")
                .req
        })
    }

    fn pop_inner(&self, model: Option<&str>, until: Option<Instant>) -> Option<QueuedRequest> {
        let mut s = lock_recover(&self.state);
        loop {
            if let Some(req) = self.take_next(&mut s, model) {
                return Some(req);
            }
            if s.closed {
                return None;
            }
            match until {
                None => {
                    s = self
                        .available
                        .wait(s)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                Some(t) => {
                    let now = Instant::now();
                    if now >= t {
                        return None;
                    }
                    let (guard, _timeout) = self
                        .available
                        .wait_timeout(s, t - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    s = guard;
                }
            }
        }
    }

    /// Block until an entry is available. Returns `None` only once the
    /// queue is closed *and* drained (the shutdown exit condition).
    pub fn pop_blocking(&self) -> Option<QueuedRequest> {
        self.pop_inner(None, None)
    }

    /// Pop, waiting at most until `until`; `None` on timeout or on
    /// closed-and-drained.
    pub fn pop_until(&self, until: Instant) -> Option<QueuedRequest> {
        self.pop_inner(None, Some(until))
    }

    /// Pop the earliest entry *for one model*, waiting at most until
    /// `until`. The straggler-collection primitive: a worker filling a
    /// batch for `model` takes only that model's requests, so a flush
    /// never mixes models and other models' entries stay queued in order.
    pub fn pop_model_until(&self, model: &str, until: Instant) -> Option<QueuedRequest> {
        self.pop_inner(Some(model), Some(until))
    }

    /// Reject future pushes; wake every waiter. Queued entries remain
    /// poppable so workers can drain before exiting.
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.available.notify_all();
    }

    /// Close *and* answer every still-queued request with
    /// [`ServeError::Stopped`] — the last live worker's exit path. Without
    /// this, a pool whose every worker died would leave queued clients
    /// blocked on receivers nobody will ever serve.
    pub fn close_and_fail_pending(&self) {
        let drained: Vec<Entry> = {
            let mut s = lock_recover(&self.state);
            s.closed = true;
            s.classes
                .iter_mut()
                .flat_map(std::mem::take)
                .collect()
        };
        self.available.notify_all();
        for e in drained {
            let _ = e.req.respond.send(Err(ServeError::Stopped));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::registry::test_claim;
    use std::sync::mpsc;

    fn q(cap: usize) -> RequestQueue {
        // Promotion period far beyond test runtimes: strict priority.
        RequestQueue::new(cap, Some(Duration::from_secs(3600)))
    }

    fn req(id: f32) -> (QueuedRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        req_for("m", id)
    }

    fn req_for(
        model: &str,
        id: f32,
    ) -> (QueuedRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            QueuedRequest {
                x: vec![id],
                enqueued: Instant::now(),
                deadline: None,
                respond: tx,
                claim: test_claim(model, 1, 1, 1),
            },
            rx,
        )
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = q(16);
        for (id, p) in [
            (1.0, Priority::Normal),
            (2.0, Priority::Low),
            (3.0, Priority::High),
            (4.0, Priority::Normal),
            (5.0, Priority::High),
        ] {
            let (r, _rx) = req(id);
            q.push(r, p).unwrap();
        }
        let order: Vec<f32> = (0..5).map(|_| q.pop_blocking().unwrap().x[0]).collect();
        assert_eq!(order, vec![3.0, 5.0, 1.0, 4.0, 2.0]);
    }

    #[test]
    fn bounded_push_rejects_when_full() {
        let q = q(2);
        let (r1, _x1) = req(1.0);
        let (r2, _x2) = req(2.0);
        assert_eq!(q.push(r1, Priority::Normal).unwrap(), 1);
        assert_eq!(q.push(r2, Priority::Normal).unwrap(), 2);
        let (r3, _x3) = req(3.0);
        match q.push(r3, Priority::High) {
            Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 2),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Popping frees capacity again.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        let (r4, _x4) = req(4.0);
        assert!(q.push(r4, Priority::Normal).is_ok());
    }

    #[test]
    fn close_rejects_pushes_but_drains_pops() {
        let q = q(4);
        let (r1, _x1) = req(1.0);
        q.push(r1, Priority::Normal).unwrap();
        q.close();
        assert!(q.is_closed());
        let (r2, _x2) = req(2.0);
        assert!(matches!(
            q.push(r2, Priority::Normal),
            Err(ServeError::Stopped)
        ));
        // The queued entry is still served, then pops report drained.
        assert_eq!(q.pop_blocking().unwrap().x[0], 1.0);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_until(Instant::now() + Duration::from_millis(5)).is_none());
    }

    #[test]
    fn pop_until_times_out_empty() {
        let q = q(4);
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn model_filtered_pop_skips_other_models_in_order() {
        let q = q(16);
        let mut rxs = Vec::new();
        for (model, id, p) in [
            ("a", 1.0, Priority::Normal),
            ("b", 2.0, Priority::Normal),
            ("a", 3.0, Priority::Low),
            ("b", 4.0, Priority::High),
            ("a", 5.0, Priority::Normal),
        ] {
            let (r, rx) = req_for(model, id);
            q.push(r, p).unwrap();
            rxs.push(rx);
        }
        let until = Instant::now() + Duration::from_millis(5);
        // Model-a entries come out in (priority, arrival) order…
        let a1 = q.pop_model_until("a", until).unwrap();
        assert_eq!((a1.claim.id(), a1.x[0]), ("a", 1.0));
        assert_eq!(q.pop_model_until("a", until).unwrap().x[0], 5.0);
        assert_eq!(q.pop_model_until("a", until).unwrap().x[0], 3.0);
        // …a drained model times out…
        assert!(q.pop_model_until("a", Instant::now() + Duration::from_millis(5)).is_none());
        // …and model-b entries kept their own order throughout.
        assert_eq!(q.pop_model_until("b", until).unwrap().x[0], 4.0);
        assert_eq!(q.pop_blocking().map(|r| r.x[0]), Some(2.0));
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn aged_low_entry_is_promoted_past_sustained_high_traffic() {
        let period = Duration::from_millis(25);
        let q = RequestQueue::new(64, Some(period));
        let (low, _rx_low) = req(1.0);
        q.push(low, Priority::Low).unwrap();
        // Sustained High traffic: a fresh High entry arrives before every
        // pop. Strict priority would starve the Low entry forever; with
        // age promotion it must surface within ~2 promotion periods.
        let mut served_low_after = None;
        let mut rxs = Vec::new();
        for i in 0..40 {
            let (high, rx) = req(100.0 + i as f32);
            q.push(high, Priority::High).unwrap();
            rxs.push(rx);
            std::thread::sleep(Duration::from_millis(5));
            if q.pop_blocking().unwrap().x[0] == 1.0 {
                served_low_after = Some(i);
                break;
            }
        }
        let rounds = served_low_after.expect("aged Low entry must be served under High load");
        // Promotion to High takes 2 × 25 ms; at ≥5 ms per round the Low
        // entry must win well before the traffic stops.
        assert!(rounds < 39, "promoted far too late: {rounds} rounds");

        // Control: with promotion disabled the same pattern starves Low.
        let strict = RequestQueue::new(64, None);
        let (low, _rx_low2) = req(1.0);
        strict.push(low, Priority::Low).unwrap();
        for i in 0..10 {
            let (high, rx) = req(200.0 + i as f32);
            strict.push(high, Priority::High).unwrap();
            rxs.push(rx);
            std::thread::sleep(Duration::from_millis(5));
            assert_ne!(
                strict.pop_blocking().unwrap().x[0],
                1.0,
                "strict priority must not promote"
            );
        }
    }

    #[test]
    fn cross_thread_handoff() {
        let q = std::sync::Arc::new(self::q(8));
        let q2 = std::sync::Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(r) = q2.pop_blocking() {
                got.push(r.x[0]);
            }
            got
        });
        let mut rxs = Vec::new();
        for id in 0..6 {
            let (r, rx) = req(id as f32);
            q.push(r, Priority::Normal).unwrap();
            rxs.push(rx);
        }
        // Give the popper a chance to drain, then close to let it exit.
        while q.len() > 0 {
            std::thread::yield_now();
        }
        q.close();
        let got = popper.join().unwrap();
        assert_eq!(got.len(), 6);
    }
}
