//! Serving backends: what a worker executes once the batcher has assembled
//! a padded batch.
//!
//! * [`NativeSparseModel`] — the default build's backend: a sparse MLP
//!   executed through the [`SparseKernel`](crate::kernels::registry::SparseKernel)
//!   plan layer. Plans come from a shared [`PlanCache`], so every flush —
//!   full or padded — reuses the structure derived once at warm-up instead
//!   of rebuilding `local_cols`/scratch per batch. Multiple workers built
//!   from one cache resolve the same cached derivation (one build per
//!   structure, pool-wide) and each detach a private working copy to
//!   execute from, so flushes neither contend on a plan lock nor share
//!   mutable scratch.
//! * the XLA backend (feature `xla`) — compiles an AOT artifact on a PJRT
//!   client (handles are not `Send`, so each worker compiles its own).

use crate::coordinator::metrics::TunedStatus;
use crate::kernels::autotune::{TuneKey, TuneMode};
use crate::kernels::plan::{KernelPlan, PlanCache, PlanRequest, SparseMatrix};
use crate::kernels::registry::KernelRegistry;
use std::sync::{Arc, Mutex};

/// What the batcher needs from a model: fixed batch geometry plus a
/// full-batch forward. `x` is `(batch × in_dim)` row-major; the result is
/// `(batch × classes)` row-major.
///
/// The two namespace accessors tie a model to the plan-cache lifecycle:
/// a plan-cached backend reports which structure hashes its plans live
/// under and which shared [`PlanCache`] they live in, so the serving
/// registry can evict exactly a retired model's namespaces (and nothing a
/// surviving model still claims) on `unregister_model`. Backends without
/// cached plans keep the defaults.
pub trait BatchModel: Send {
    fn batch(&self) -> usize;
    fn in_dim(&self) -> usize;
    fn classes(&self) -> usize;
    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>>;

    /// Structure-hash namespaces this model's plans occupy in
    /// [`BatchModel::plan_cache`] (deduplicated; empty when not
    /// plan-cached).
    fn structures(&self) -> Vec<u64> {
        Vec::new()
    }

    /// The shared plan cache this model resolves plans from, if any.
    fn plan_cache(&self) -> Option<Arc<PlanCache>> {
        None
    }

    /// Per-layer tuned-schedule status: what the search recorded plus the
    /// achieved-throughput EWMA observed on real flushes. Empty when the
    /// backend is not plan-tuned (or plans are not resolved yet).
    fn tuned_status(&self) -> Vec<TunedStatus> {
        Vec::new()
    }

    /// Worst (lowest) achieved/tuned throughput ratio across layers, once
    /// enough flush samples accumulated. `None` until then — the drift
    /// re-tune trigger must never fire on cold or untuned models.
    fn drift(&self) -> Option<f64> {
        self.tuned_status()
            .iter()
            .filter_map(|s| s.drift())
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Re-run the schedule search and swap in fresh plans. Called by an
    /// idle worker when [`BatchModel::drift`] crosses the configured
    /// threshold; a no-op for backends without tuned plans.
    fn retune(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Adopt plans a pool peer's completed re-tune left in the shared
    /// cache: re-resolve working copies *without* invalidating anything
    /// and without searching. Called when a worker's local re-tune epoch
    /// lags the registry entry's; a no-op for backends without cached
    /// plans.
    fn refresh(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// EWMA weight for per-flush achieved-throughput samples: heavy enough
/// history (5-sample time constant) that one slow flush cannot trigger a
/// re-tune, light enough that genuine regressions surface within a dozen
/// flushes.
const EWMA_ALPHA: f64 = 0.2;

/// Achieved-throughput tracker for one layer's kernel: an EWMA of GFLOP/s
/// measured on real (non-synthetic) flushes, compared against the tuning
/// search's recorded expectation to detect drift.
#[derive(Clone, Copy, Default)]
struct LayerPerf {
    ewma_gflops: f64,
    samples: usize,
}

impl LayerPerf {
    fn observe(&mut self, gflops: f64) {
        if !gflops.is_finite() || gflops <= 0.0 {
            return;
        }
        self.ewma_gflops = if self.samples == 0 {
            gflops
        } else {
            EWMA_ALPHA * gflops + (1.0 - EWMA_ALPHA) * self.ewma_gflops
        };
        self.samples += 1;
    }
}

/// The native serving backend: a two-layer sparse MLP
/// (`x → W1 (sparse) → ReLU → W2 → logits`) executed through the
/// [`SparseKernel`](crate::kernels::registry::SparseKernel) plan layer.
/// All scratch is preallocated; both layer plans are resolved through the
/// shared [`PlanCache`] (derivation amortized pool-wide) and then detached
/// as private working copies, so a warmed model's forward performs no
/// allocation, no structure derivation and no lock acquisition regardless
/// of how the batcher flushes or how many sibling workers run.
pub struct NativeSparseModel {
    w1: SparseMatrix,
    b1: Vec<f32>,
    w2: SparseMatrix,
    b2: Vec<f32>,
    batch: usize,
    threads: usize,
    /// How hard warm-up searches for kernel schedules (default Quick —
    /// warming now tunes; the search result is cached per plan key).
    tune: TuneMode,
    registry: KernelRegistry,
    cache: Arc<PlanCache>,
    // Private working copies of the two layer plans, detached once from
    // the shared cache (lazily, or eagerly via `warm`). The *derivation*
    // is amortized through the cache — counters show one build per
    // structure pool-wide — but execution runs from a per-model copy:
    // plans carry mutable pack scratch, so sharing one `Mutex<KernelPlan>`
    // across workers would serialize their flushes, and a worker panicking
    // mid-execute would poison every peer's next lock.
    plan1: Option<KernelPlan>,
    plan2: Option<KernelPlan>,
    // Achieved-throughput EWMAs per layer, fed by `forward` and read by
    // the drift re-tune trigger.
    perf1: LayerPerf,
    perf2: LayerPerf,
    // Preallocated scratch: transposed input, hidden, logits.
    xt: Vec<f32>,
    hid: Vec<f32>,
    logits: Vec<f32>,
}

impl NativeSparseModel {
    /// Build from explicit weights. `w1` is (hidden × in_dim), `w2` is
    /// (classes × hidden); biases match the row counts.
    pub fn new(
        w1: SparseMatrix,
        b1: Vec<f32>,
        w2: SparseMatrix,
        b2: Vec<f32>,
        batch: usize,
        threads: usize,
        cache: Arc<PlanCache>,
    ) -> anyhow::Result<NativeSparseModel> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        anyhow::ensure!(
            w2.cols() == w1.rows(),
            "layer shapes disagree: W2 cols {} != W1 rows {}",
            w2.cols(),
            w1.rows()
        );
        anyhow::ensure!(b1.len() == w1.rows(), "b1 length mismatch");
        anyhow::ensure!(b2.len() == w2.rows(), "b2 length mismatch");
        let (h, d, c) = (w1.rows(), w1.cols(), w2.rows());
        Ok(NativeSparseModel {
            w1,
            b1,
            w2,
            b2,
            batch,
            threads: threads.max(1),
            tune: TuneMode::default(),
            registry: KernelRegistry::builtin(),
            cache,
            plan1: None,
            plan2: None,
            perf1: LayerPerf::default(),
            perf2: LayerPerf::default(),
            xt: vec![0.0; d * batch],
            hid: vec![0.0; h * batch],
            logits: vec![0.0; c * batch],
        })
    }

    /// A self-contained demo model on a small RBGP4 hidden layer (256→256
    /// at 75 % sparsity) — the featureless `rbgp serve` backend and the
    /// test fixture. Deterministic in `seed`.
    pub fn rbgp4_demo(
        classes: usize,
        batch: usize,
        threads: usize,
        seed: u64,
        cache: Arc<PlanCache>,
    ) -> anyhow::Result<NativeSparseModel> {
        use crate::sparsity::rbgp4::{GraphSpec, Rbgp4Config, Rbgp4Mask, Rbgp4Matrix};
        use crate::util::rng::Rng;
        let cfg = Rbgp4Config {
            go: GraphSpec::new(8, 16, 0.5),
            gr: (2, 1),
            gi: GraphSpec::new(16, 16, 0.5),
            gb: (1, 1),
        };
        let mut rng = Rng::new(seed);
        let mask = Rbgp4Mask::sample(cfg, &mut rng)?;
        let w1 = Rbgp4Matrix::random(mask, &mut rng);
        let h = w1.mask.rows();
        let w2scale = (1.0 / h as f64).sqrt() as f32;
        let w2 = rng.normal_vec_f32(classes * h, w2scale);
        NativeSparseModel::new(
            SparseMatrix::Rbgp4(w1),
            vec![0.0; h],
            SparseMatrix::dense(w2, classes, h),
            vec![0.0; classes],
            batch,
            threads,
            cache,
        )
    }

    /// Set the tune mode warm-up resolves plans under (builder-style;
    /// call before [`NativeSparseModel::warm`] / the first forward).
    pub fn with_tune(mut self, tune: TuneMode) -> NativeSparseModel {
        self.tune = tune;
        self
    }

    /// Pre-build both layers' plans for this model's batch class so the
    /// first request pays no plan-construction latency. Under the default
    /// [`TuneMode::Quick`] this also runs the schedule search — warming
    /// tunes, and the tuned plan lands in the shared cache for the pool.
    pub fn warm(&mut self) -> anyhow::Result<()> {
        self.resolve_plans()
    }

    /// Resolve the two layer plans from the shared cache and detach
    /// private working copies. Idempotent; called lazily by `forward` if
    /// `warm` wasn't. The lock is recovered if poisoned: a peer that
    /// crashed mid-detach must not take this model down with it.
    fn resolve_plans(&mut self) -> anyhow::Result<()> {
        let req = PlanRequest::new(self.batch, self.threads).with_tune(self.tune);
        let detach = |shared: Arc<Mutex<KernelPlan>>| -> KernelPlan {
            crate::util::lock_recover(&shared).clone()
        };
        if self.plan1.is_none() {
            self.plan1 = Some(detach(self.cache.plan_for(&self.registry, &self.w1, &req)?));
        }
        if self.plan2.is_none() {
            self.plan2 = Some(detach(self.cache.plan_for(&self.registry, &self.w2, &req)?));
        }
        Ok(())
    }

    /// The plan cache this model executes from (shared; inspect for stats).
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }
}

impl BatchModel for NativeSparseModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.w1.cols()
    }

    fn classes(&self) -> usize {
        self.w2.rows()
    }

    fn structures(&self) -> Vec<u64> {
        let mut s = vec![self.w1.structure_hash(), self.w2.structure_hash()];
        s.sort_unstable();
        s.dedup();
        s
    }

    fn plan_cache(&self) -> Option<Arc<PlanCache>> {
        Some(Arc::clone(&self.cache))
    }

    fn tuned_status(&self) -> Vec<TunedStatus> {
        let layer = |name: &str,
                     w: &SparseMatrix,
                     plan: &Option<KernelPlan>,
                     perf: &LayerPerf|
         -> Option<TunedStatus> {
            let tuned = plan.as_ref()?.tuned.as_ref()?;
            Some(TunedStatus {
                layer: name.to_string(),
                structure: w.structure_hash(),
                params: tuned.params.clone(),
                tuned_gflops: tuned.gflops,
                roofline_fraction: tuned.roofline_fraction,
                ewma_gflops: (perf.samples > 0).then_some(perf.ewma_gflops),
                samples: perf.samples,
            })
        };
        [
            layer("w1", &self.w1, &self.plan1, &self.perf1),
            layer("w2", &self.w2, &self.plan2, &self.perf2),
        ]
        .into_iter()
        .flatten()
        .collect()
    }

    /// Re-tune: drop the persistent cache's entries for both layers (so
    /// the fresh search *measures* instead of warm-starting on the very
    /// winner that drifted), evict the shared plan-cache namespaces, then
    /// resolve new plans. The old detached plans serve requests until the
    /// moment of the swap — callers run this on an idle worker.
    fn retune(&mut self) -> anyhow::Result<()> {
        let req = PlanRequest::new(self.batch, self.threads);
        if let Some(tc) = self.cache.tune_cache() {
            tc.invalidate(&TuneKey::of(&self.w1, &req));
            tc.invalidate(&TuneKey::of(&self.w2, &req));
        }
        for s in self.structures() {
            self.cache.invalidate_structure(s);
        }
        self.plan1 = None;
        self.plan2 = None;
        self.perf1 = LayerPerf::default();
        self.perf2 = LayerPerf::default();
        self.resolve_plans()
    }

    /// Refresh: drop the detached working copies and re-resolve from the
    /// shared cache. When a peer's re-tune already rebuilt the cached
    /// plans this is a pair of cache hits — no invalidation, no search;
    /// the EWMAs reset because they measured the replaced plans.
    fn refresh(&mut self) -> anyhow::Result<()> {
        self.plan1 = None;
        self.plan2 = None;
        self.perf1 = LayerPerf::default();
        self.perf2 = LayerPerf::default();
        self.resolve_plans()
    }

    fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let (b, d) = (self.batch, self.w1.cols());
        let (h, c) = (self.w1.rows(), self.w2.rows());
        anyhow::ensure!(x.len() == b * d, "batch input length mismatch");
        self.resolve_plans()?;
        // (batch × d) → (d × batch): kernels consume column-major batches.
        // analyze: allow(panic-freedom, reason="xt is sized b*d at construction and x.len()==b*d is ensured above")
        for r in 0..b {
            for col in 0..d {
                self.xt[col * b + r] = x[r * d + col];
            }
        }
        // Execute straight from the detached plan copies: no structure
        // re-hash, no cache-map lock, and *no plan lock at all* on the
        // flush path — concurrent workers never contend here.
        let kernel1 = self.registry.for_matrix(&self.w1)?;
        let kernel2 = self.registry.for_matrix(&self.w2)?;
        let plan1 = self
            .plan1
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("layer-1 plan missing after resolve_plans"))?;
        let t1 = std::time::Instant::now();
        kernel1.execute(&self.w1, plan1, &self.xt, &mut self.hid, b)?;
        let secs1 = t1.elapsed().as_secs_f64();
        self.perf1.observe(self.w1.flops(b) / secs1.max(1e-12) / 1e9);
        // analyze: allow(panic-freedom, reason="hid is sized h*b and b1 is sized h at construction; r<h, j<b")
        for r in 0..h {
            let bias = self.b1[r];
            for j in 0..b {
                let v = self.hid[r * b + j] + bias;
                self.hid[r * b + j] = if v > 0.0 { v } else { 0.0 };
            }
        }
        let plan2 = self
            .plan2
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("layer-2 plan missing after resolve_plans"))?;
        let t2 = std::time::Instant::now();
        kernel2.execute(&self.w2, plan2, &self.hid, &mut self.logits, b)?;
        let secs2 = t2.elapsed().as_secs_f64();
        self.perf2.observe(self.w2.flops(b) / secs2.max(1e-12) / 1e9);
        // (c × batch) + bias → (batch × c) row-major for the batcher.
        let mut out = vec![0.0f32; b * c];
        // analyze: allow(panic-freedom, reason="out allocated b*c on the previous line; logits is c*b and b2 is c by construction")
        for j in 0..b {
            for r in 0..c {
                out[j * c + r] = self.logits[r * b + j] + self.b2[r];
            }
        }
        Ok(out)
    }
}

#[cfg(feature = "xla")]
pub(crate) mod xla_backend {
    use super::BatchModel;
    use crate::runtime::executor::{Executor, HostTensor};
    use std::path::{Path, PathBuf};

    /// The PJRT-backed model: a compiled `forward` artifact plus its served
    /// parameters.
    pub struct XlaModel {
        exe: Executor,
        params: Vec<HostTensor>,
        batch: usize,
        in_dim: usize,
        classes: usize,
    }

    impl XlaModel {
        pub fn load(artifacts_dir: &Path, checkpoint: Option<PathBuf>) -> anyhow::Result<XlaModel> {
            let exe = Executor::compile(artifacts_dir, "forward")?;
            let meta = &exe.artifact.meta;
            let batch = meta
                .batch()
                .ok_or_else(|| anyhow::anyhow!("forward metadata missing batch"))?;
            let in_dim = meta.raw.req_usize("in_dim")?;
            let classes = meta.raw.req_usize("classes")?;
            // Parameters served: a trained checkpoint when given, else the
            // exported init values.
            let params_path =
                checkpoint.unwrap_or_else(|| artifacts_dir.join("init_params.json"));
            let init_text = std::fs::read_to_string(&params_path)?;
            let init = crate::util::json::Json::parse(&init_text)?;
            let mut params = Vec::new();
            for (idx, name) in meta.param_order.iter().enumerate() {
                // analyze: allow(panic-freedom, reason="ModuleMeta keeps param_order and inputs the same length")
                let sig = &meta.inputs[idx];
                let vals: Vec<f32> = init
                    .req_arr(name)?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                params.push(HostTensor::new(vals, &sig.shape));
            }
            Ok(XlaModel {
                exe,
                params,
                batch,
                in_dim,
                classes,
            })
        }
    }

    impl BatchModel for XlaModel {
        fn batch(&self) -> usize {
            self.batch
        }

        fn in_dim(&self) -> usize {
            self.in_dim
        }

        fn classes(&self) -> usize {
            self.classes
        }

        fn forward(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            let mut inputs = self.params.clone();
            inputs.push(HostTensor::new(x.to_vec(), &[self.batch, self.in_dim]));
            let out = self.exe.run(&inputs)?;
            // analyze: allow(panic-freedom, reason="XLA executables always produce at least one output tensor")
            Ok(out[0].data.clone())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo(seed: u64, cache: Arc<PlanCache>) -> NativeSparseModel {
        NativeSparseModel::rbgp4_demo(10, 8, 2, seed, cache).unwrap()
    }

    #[test]
    fn native_model_shapes_and_determinism() {
        let cache = Arc::new(PlanCache::new());
        let mut m = demo(42, Arc::clone(&cache));
        assert_eq!(m.in_dim(), 256);
        assert_eq!(m.classes(), 10);
        assert_eq!(m.batch(), 8);
        m.warm().unwrap();
        let (_, misses) = cache.stats();
        assert_eq!(misses, 2, "warm builds one plan per layer");
        let x: Vec<f32> = (0..8 * 256).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let a = m.forward(&x).unwrap();
        let b = m.forward(&x).unwrap();
        assert_eq!(a, b, "same input, same plan → same logits");
        assert_eq!(a.len(), 8 * 10);
        assert!(a.iter().all(|v| v.is_finite()));
        // The flush path holds the plan handles: after warm-up, forward
        // generates no cache traffic at all (no re-hash, no map lock).
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "forward never rebuilds plans");
        assert_eq!(hits, 0, "forward bypasses the cache map entirely");
        // A second model on the same cache shares the warmed plans.
        let mut m2 = demo(42, Arc::clone(&cache));
        m2.warm().unwrap();
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "same structure → no new plan builds");
        assert_eq!(hits, 2, "second model resolves both plans from cache");
    }

    #[test]
    fn tuned_status_tracks_flushes_and_retune_rebuilds_plans() {
        let cache = Arc::new(PlanCache::new());
        let mut m = demo(7, Arc::clone(&cache));
        assert!(m.tuned_status().is_empty(), "no plans before warm-up");
        assert!(m.drift().is_none());
        m.warm().unwrap();
        let st = m.tuned_status();
        assert_eq!(st.len(), 2, "Quick tune records a config per layer");
        assert!(st.iter().any(|s| s.layer == "w1"));
        assert!(st.iter().any(|s| s.layer == "w2"));
        assert!(
            st.iter().all(|s| s.ewma_gflops.is_none() && s.samples == 0),
            "no flush samples before the first forward"
        );
        let x: Vec<f32> = (0..8 * 256).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        for _ in 0..crate::coordinator::metrics::DRIFT_MIN_SAMPLES {
            m.forward(&x).unwrap();
        }
        let st = m.tuned_status();
        assert!(
            st.iter()
                .all(|s| s.samples == crate::coordinator::metrics::DRIFT_MIN_SAMPLES),
            "every forward feeds both layer EWMAs"
        );
        assert!(st.iter().all(|s| s.ewma_gflops.unwrap_or(0.0) > 0.0));
        assert!(
            m.drift().unwrap_or(0.0) > 0.0,
            "enough samples → a finite drift ratio"
        );
        // Re-tune: evicts + rebuilds both plans and resets the EWMAs.
        let (_, misses_before) = cache.stats();
        m.retune().unwrap();
        let (_, misses_after) = cache.stats();
        assert_eq!(misses_after, misses_before + 2, "retune rebuilds both plans");
        let st = m.tuned_status();
        assert_eq!(st.len(), 2, "fresh plans carry fresh tuned configs");
        assert!(st.iter().all(|s| s.samples == 0), "EWMAs reset on swap");
        let a = m.forward(&x).unwrap();
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn refresh_adopts_cached_plans_without_invalidation() {
        let cache = Arc::new(PlanCache::new());
        let mut m = demo(3, Arc::clone(&cache));
        m.warm().unwrap();
        let (hits0, misses0) = cache.stats();
        m.refresh().unwrap();
        let (hits, misses) = cache.stats();
        assert_eq!(misses, misses0, "refresh never rebuilds or evicts plans");
        assert_eq!(hits, hits0 + 2, "refresh re-resolves both layers from cache");
        let st = m.tuned_status();
        assert!(st.iter().all(|s| s.samples == 0), "EWMAs reset on adoption");
        let x: Vec<f32> = (0..8 * 256).map(|i| (i % 7) as f32 / 7.0).collect();
        assert!(m.forward(&x).unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn native_model_reports_its_plan_namespaces() {
        let cache = Arc::new(PlanCache::new());
        let mut m = demo(5, Arc::clone(&cache));
        m.warm().unwrap();
        let structures = m.structures();
        assert_eq!(structures.len(), 2, "w1 + w2 namespaces: {structures:?}");
        // Every reported namespace is live in the reported cache — the
        // invariant the serving registry's unregister eviction relies on.
        let reported = m.plan_cache().expect("native backend is plan-cached");
        assert!(Arc::ptr_eq(&reported, &cache));
        for s in structures {
            assert!(cache.structure_plan_count(s) >= 1, "structure {s:016x}");
        }
    }
}
