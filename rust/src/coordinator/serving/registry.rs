//! The model registry: one worker pool serving **many models** concurrently.
//!
//! The paper's point is that RBGP4 structure is derived once and executed
//! everywhere; PR 3 made the shared [`PlanCache`] *namespaced by structure
//! hash* so dead structures are evictable. This module is the production
//! consumer of that namespace API: a registry maps model ids to factories,
//! every request resolves to a registered model before it is queued, each
//! worker materializes its own instance of every registered model (all
//! sharing one plan cache, so cache builds scale with *structures*, not
//! models × workers), and retiring a model drains its in-flight requests
//! and then evicts exactly the plan namespaces no surviving model still
//! claims.
//!
//! Lifecycle of a request: `submit_with(model: Some(id))` →
//! [`ModelRegistry::resolve`] hands back a [`ModelClaim`] (an RAII token
//! that keeps the entry's in-flight count exact) → the claim rides inside
//! the queued request → a worker batches it with same-model requests only
//! → the response is sent and the claim drops. `unregister_model` flips
//! the entry to *retired* (new submits get
//! [`ServeError::UnknownModel`]), waits for the in-flight count to reach
//! zero, removes the entry (workers drop their instances at the next
//! sync), and invalidates the retired structures in the entry's plan
//! cache — reporting exact eviction counters.

use super::backend::BatchModel;
use super::ServeError;
use crate::kernels::plan::PlanCache;
use crate::util::lock_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// The id [`super::InferenceServer::start_model`] registers its initial
/// model under, and the id requests without an explicit
/// [`super::SubmitOptions::model`] route to.
pub const DEFAULT_MODEL: &str = "default";

/// A model constructor, run once per worker thread (and once as a probe on
/// the registering thread): some backends own handles that are not `Send`,
/// and per-worker instances keep flushes lock-free.
pub(crate) type ModelFactory =
    Arc<dyn Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync>;

/// Batch geometry of a registered model, captured from its probe (or
/// first worker) instance; what submit validates widths against and the
/// batcher sizes flushes by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct ModelSpec {
    pub batch: usize,
    pub in_dim: usize,
    pub classes: usize,
}

/// What the registry knows about a model once an instance has existed.
pub(crate) struct ModelInfo {
    pub spec: ModelSpec,
    /// Structure-hash namespaces this model's plans occupy in `cache`
    /// (empty for backends that are not plan-cached).
    pub structures: Vec<u64>,
    /// The shared plan cache the model resolves plans from, if any — the
    /// handle `unregister` evicts retired namespaces through.
    pub cache: Option<Arc<PlanCache>>,
}

/// One registered model: id, factory, geometry, admission quota, and the
/// in-flight accounting that makes unregistration a *drain*, not a drop.
pub(crate) struct ModelEntry {
    pub id: String,
    pub factory: ModelFactory,
    info: OnceLock<ModelInfo>,
    /// Resolved per-model admission cap: the max entries this model may
    /// have *queued* at once (`None` = unlimited, only the shared queue
    /// cap applies). Fixed at registration — see
    /// [`super::ModelQuota::limit`].
    quota: Option<usize>,
    /// Accepted-but-unanswered requests holding a [`ModelClaim`] on this
    /// entry.
    in_flight: AtomicUsize,
    /// Set by `begin_retire`: resolves are rejected, queued requests keep
    /// draining.
    retired: AtomicBool,
    drain_lock: Mutex<()>,
    drained: Condvar,
}

impl ModelEntry {
    fn new(id: &str, factory: ModelFactory, quota: Option<usize>) -> ModelEntry {
        ModelEntry {
            id: id.to_string(),
            factory,
            info: OnceLock::new(),
            quota,
            in_flight: AtomicUsize::new(0),
            retired: AtomicBool::new(false),
            drain_lock: Mutex::new(()),
            drained: Condvar::new(),
        }
    }

    /// Record the probe/first-instance report; first write wins (workers
    /// all report the same geometry — disagreement aborts startup).
    pub fn set_info(&self, info: ModelInfo) {
        let _ = self.info.set(info);
    }

    pub fn info(&self) -> Option<&ModelInfo> {
        self.info.get()
    }

    pub fn spec(&self) -> ModelSpec {
        self.info
            .get()
            .expect("model info is set before the entry can serve requests")
            .spec
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Block until every claim on this entry has dropped — requests were
    /// answered (by a worker) or discarded (queue failed them). Claims
    /// drop on every exit path including worker panic unwind, so this
    /// cannot wedge on a dead pool.
    pub fn wait_drained(&self) {
        let mut g = lock_recover(&self.drain_lock);
        while self.in_flight.load(Ordering::Acquire) != 0 {
            g = self
                .drained
                .wait(g)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// RAII routing token: which model a request targets, plus the in-flight
/// count that lets `unregister_model` drain exactly. Created under the
/// registry lock (so it cannot race a retire) and dropped whenever the
/// request is answered or discarded — including a worker's panic unwind.
///
/// Public (with private fields) because every
/// [`QueuedRequest`](super::queue::QueuedRequest) carries one; the
/// queue-level property suite constructs detached claims via
/// [`ModelClaim::detached`].
pub struct ModelClaim {
    entry: Arc<ModelEntry>,
}

impl ModelClaim {
    fn new(entry: Arc<ModelEntry>) -> ModelClaim {
        entry.in_flight.fetch_add(1, Ordering::AcqRel);
        ModelClaim { entry }
    }

    /// Fixture for queue-level tests and benches: a claim with the given
    /// id and geometry backed by a private entry (no registry, no
    /// factory), still with exact RAII in-flight accounting.
    #[doc(hidden)]
    pub fn detached(id: &str, batch: usize, in_dim: usize, classes: usize) -> ModelClaim {
        let entry = Arc::new(ModelEntry::new(
            id,
            Arc::new(|| anyhow::bail!("detached claim has no factory")),
            None,
        ));
        entry.set_info(ModelInfo {
            spec: ModelSpec {
                batch,
                in_dim,
                classes,
            },
            structures: Vec::new(),
            cache: None,
        });
        ModelClaim::new(entry)
    }

    pub fn id(&self) -> &str {
        &self.entry.id
    }

    pub(crate) fn spec(&self) -> ModelSpec {
        self.entry.spec()
    }

    /// The resolved admission cap of the claimed model (max queued
    /// entries), threaded into `RequestQueue::push` at submit time.
    pub(crate) fn quota_limit(&self) -> Option<usize> {
        self.entry.quota
    }
}

impl Drop for ModelClaim {
    fn drop(&mut self) {
        if self.entry.in_flight.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Take the drain lock before notifying so a waiter between its
            // count check and its wait cannot miss the wakeup.
            let _g = lock_recover(&self.entry.drain_lock);
            self.entry.drained.notify_all();
        }
    }
}

/// Outcome of `unregister_model`: what was drained and exactly which plan
/// namespaces were evicted vs. retained (shared with a surviving model).
#[derive(Clone, Debug, Default)]
pub struct UnregisterReport {
    pub model: String,
    /// Requests still in flight when unregistration began; all were
    /// answered before the model was dropped.
    pub drained_requests: usize,
    /// Structure hashes whose plans were evicted (no surviving model
    /// claims them).
    pub evicted_structures: Vec<u64>,
    /// Structure hashes kept because a surviving model still claims them
    /// (e.g. a dense classifier shape shared across checkpoints).
    pub retained_structures: Vec<u64>,
    /// Plans removed from the shared cache, summed over
    /// `evicted_structures`.
    pub evicted_plans: usize,
}

/// The registry proper: model id → entry, plus a generation counter the
/// workers poll to keep their local instance sets in sync.
pub(crate) struct ModelRegistry {
    state: Mutex<HashMap<String, Arc<ModelEntry>>>,
    /// Bumped on register and on retire *completion*; a worker whose local
    /// generation matches has an exact mirror of the entry map.
    generation: AtomicUsize,
    default_id: String,
}

impl ModelRegistry {
    pub fn new(default_id: &str) -> ModelRegistry {
        ModelRegistry {
            state: Mutex::new(HashMap::new()),
            generation: AtomicUsize::new(0),
            default_id: default_id.to_string(),
        }
    }

    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::Acquire)
    }

    pub fn default_id(&self) -> &str {
        &self.default_id
    }

    /// Add a model. `info` is `None` only for the startup default model,
    /// whose first worker instance reports it before the server constructor
    /// returns (no submit can race that window). `quota` is the resolved
    /// per-model admission cap ([`super::ModelQuota::limit`]).
    pub fn register(
        &self,
        id: &str,
        factory: ModelFactory,
        info: Option<ModelInfo>,
        quota: Option<usize>,
    ) -> anyhow::Result<Arc<ModelEntry>> {
        anyhow::ensure!(!id.is_empty(), "model id must be non-empty");
        let entry = {
            let mut map = lock_recover(&self.state);
            anyhow::ensure!(
                !map.contains_key(id),
                "model '{id}' is already registered"
            );
            let entry = Arc::new(ModelEntry::new(id, factory, quota));
            if let Some(info) = info {
                entry.set_info(info);
            }
            map.insert(id.to_string(), Arc::clone(&entry));
            entry
        };
        self.generation.fetch_add(1, Ordering::AcqRel);
        Ok(entry)
    }

    /// Resolve a submit's target (`None` → the default id) to a claim.
    /// Claim creation happens under the registry lock, so a request either
    /// resolves before a retire begins (and is drained) or is rejected.
    pub fn resolve(&self, id: Option<&str>) -> Result<ModelClaim, ServeError> {
        let map = lock_recover(&self.state);
        let id = id.unwrap_or(self.default_id.as_str());
        match map.get(id) {
            Some(e) if !e.retired.load(Ordering::Acquire) => {
                Ok(ModelClaim::new(Arc::clone(e)))
            }
            _ => Err(ServeError::UnknownModel {
                model: id.to_string(),
            }),
        }
    }

    /// Whether `id` currently has an entry (live or draining). Used to
    /// fail duplicate registrations *before* the expensive factory probe —
    /// a probe for a doomed registration would warm orphan plan namespaces
    /// into the shared cache that no entry (and so no unregister) owns.
    pub fn is_registered(&self, id: &str) -> bool {
        lock_recover(&self.state).contains_key(id)
    }

    /// Every entry, including retired-but-draining ones (workers must keep
    /// serving those until the drain completes).
    pub fn snapshot(&self) -> Vec<Arc<ModelEntry>> {
        lock_recover(&self.state).values().map(Arc::clone).collect()
    }

    /// Live (non-retired) model ids, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut ids: Vec<String> = lock_recover(&self.state)
            .values()
            .filter(|e| !e.retired.load(Ordering::Acquire))
            .map(|e| e.id.clone())
            .collect();
        ids.sort();
        ids
    }

    /// Phase 1 of unregistration: stop new submits resolving to `id`.
    /// Queued requests keep draining through the workers.
    pub fn begin_retire(&self, id: &str) -> anyhow::Result<Arc<ModelEntry>> {
        let map = lock_recover(&self.state);
        let entry = map
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("model '{id}' is not registered"))?;
        anyhow::ensure!(
            !entry.retired.swap(true, Ordering::AcqRel),
            "model '{id}' is already being unregistered"
        );
        Ok(Arc::clone(entry))
    }

    /// Phase 2, after the drain: remove the entry (workers drop their
    /// instances at the next sync) and evict exactly the plan namespaces
    /// no surviving model still claims.
    pub fn finish_retire(&self, entry: &Arc<ModelEntry>) -> UnregisterReport {
        let live: Vec<u64> = {
            let mut map = lock_recover(&self.state);
            map.remove(&entry.id);
            map.values()
                .filter_map(|e| e.info())
                .flat_map(|i| i.structures.iter().copied())
                .collect()
        };
        self.generation.fetch_add(1, Ordering::AcqRel);
        let mut report = UnregisterReport {
            model: entry.id.clone(),
            ..UnregisterReport::default()
        };
        if let Some(info) = entry.info() {
            for &s in &info.structures {
                if live.contains(&s) {
                    report.retained_structures.push(s);
                } else if let Some(cache) = &info.cache {
                    report.evicted_plans += cache.invalidate_structure(s);
                    report.evicted_structures.push(s);
                }
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noop_factory() -> ModelFactory {
        Arc::new(|| anyhow::bail!("never built in these tests"))
    }

    fn info(batch: usize, structures: Vec<u64>) -> ModelInfo {
        ModelInfo {
            spec: ModelSpec {
                batch,
                in_dim: 4,
                classes: 2,
            },
            structures,
            cache: None,
        }
    }

    #[test]
    fn register_resolve_and_duplicate_rejection() {
        let r = ModelRegistry::new(DEFAULT_MODEL);
        let gen0 = r.generation();
        r.register(DEFAULT_MODEL, noop_factory(), Some(info(8, vec![1])), None)
            .unwrap();
        r.register("b", noop_factory(), Some(info(4, vec![2])), Some(16))
            .unwrap();
        assert_eq!(r.generation(), gen0 + 2);
        assert!(r.register("b", noop_factory(), None, None).is_err());
        assert_eq!(r.models(), vec!["b".to_string(), DEFAULT_MODEL.to_string()]);

        let claim = r.resolve(None).unwrap();
        assert_eq!(claim.id(), DEFAULT_MODEL);
        assert_eq!(claim.spec().batch, 8);
        assert_eq!(claim.quota_limit(), None, "default model: unlimited");
        let claim_b = r.resolve(Some("b")).unwrap();
        assert_eq!(claim_b.spec().batch, 4);
        assert_eq!(claim_b.quota_limit(), Some(16), "claims carry the resolved quota");
        match r.resolve(Some("nope")) {
            Err(ServeError::UnknownModel { model }) => assert_eq!(model, "nope"),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn claims_gate_the_drain_and_retire_blocks_resolves() {
        let r = ModelRegistry::new(DEFAULT_MODEL);
        let entry = r
            .register("m", noop_factory(), Some(info(2, vec![7, 9])), None)
            .unwrap();
        let c1 = r.resolve(Some("m")).unwrap();
        let c2 = r.resolve(Some("m")).unwrap();
        assert_eq!(entry.in_flight(), 2);

        let retired = r.begin_retire("m").unwrap();
        assert!(r.resolve(Some("m")).is_err(), "retired: no new claims");
        assert!(r.begin_retire("m").is_err(), "double retire rejected");
        // Still visible to workers (snapshot) so the drain can be served,
        // but gone from the public model list.
        assert_eq!(r.snapshot().len(), 1);
        assert!(r.models().is_empty());

        // Drain completes from another thread while we wait.
        let h = std::thread::spawn(move || {
            drop(c1);
            drop(c2);
        });
        retired.wait_drained();
        h.join().unwrap();
        assert_eq!(retired.in_flight(), 0);

        let report = r.finish_retire(&retired);
        assert_eq!(report.model, "m");
        // No cache attached: nothing evictable, nothing retained.
        assert!(report.evicted_structures.is_empty());
        assert_eq!(report.evicted_plans, 0);
        assert!(r.snapshot().is_empty());
        // The id is free again.
        r.register("m", noop_factory(), Some(info(2, vec![7])), None).unwrap();
    }

    #[test]
    fn finish_retire_spares_structures_shared_with_survivors() {
        use crate::kernels::plan::{PlanRequest, SparseMatrix};
        use crate::kernels::registry::KernelRegistry;

        let cache = Arc::new(PlanCache::new());
        let kernels = KernelRegistry::builtin();
        let shared = SparseMatrix::dense(vec![0.0; 8], 2, 4);
        let own = SparseMatrix::dense(vec![0.0; 12], 3, 4);
        let req = PlanRequest::new(4, 1);
        cache.plan_for(&kernels, &shared, &req).unwrap();
        cache.plan_for(&kernels, &own, &req).unwrap();

        let r = ModelRegistry::new(DEFAULT_MODEL);
        let mk_info = |structures: Vec<u64>| ModelInfo {
            spec: ModelSpec {
                batch: 2,
                in_dim: 4,
                classes: 2,
            },
            structures,
            cache: Some(Arc::clone(&cache)),
        };
        r.register(
            "keep",
            noop_factory(),
            Some(mk_info(vec![shared.structure_hash()])),
            None,
        )
        .unwrap();
        let retired = r
            .register(
                "kill",
                noop_factory(),
                Some(mk_info(vec![shared.structure_hash(), own.structure_hash()])),
                None,
            )
            .unwrap();

        let entry = r.begin_retire("kill").unwrap();
        entry.wait_drained(); // nothing in flight
        let report = r.finish_retire(&retired);
        assert_eq!(report.evicted_structures, vec![own.structure_hash()]);
        assert_eq!(report.retained_structures, vec![shared.structure_hash()]);
        assert_eq!(report.evicted_plans, 1);
        assert_eq!(cache.structure_plan_count(own.structure_hash()), 0);
        assert_eq!(cache.structure_plan_count(shared.structure_hash()), 1);
    }
}
