//! The model registry: one worker pool serving **many models** concurrently,
//! plus the fleet-level rollout layer built on top of it.
//!
//! The paper's point is that RBGP4 structure is derived once and executed
//! everywhere; PR 3 made the shared [`PlanCache`] *namespaced by structure
//! hash* so dead structures are evictable. This module is the production
//! consumer of that namespace API: a registry maps model ids to factories,
//! every request resolves to a registered model before it is queued, each
//! worker materializes its own instance of every registered model (all
//! sharing one plan cache, so cache builds scale with *structures*, not
//! models × workers), and retiring a model drains its in-flight requests
//! and then evicts exactly the plan namespaces no surviving model still
//! claims.
//!
//! On top of the id → entry map sits the **alias table**: an alias
//! (`prod`) names an [`AliasRoute`] — a concrete target model, an optional
//! canary leg (N% of traffic to a second model, chosen by a deterministic
//! per-request hash so replays reproduce), and an optional shadow target
//! (requests mirrored for divergence measurement, never answered from).
//! Both maps live under **one lock**, so an alias flip is atomic with
//! respect to resolution: no request ever observes a half-flipped route,
//! and a claim created through an alias pins the *concrete* model — drain
//! accounting stays exact through canary splits and flips.
//!
//! Lifecycle of a request: `submit_with(model: Some(id))` →
//! [`ModelRegistry::resolve_request`] hands back a [`Resolution`] whose
//! [`ModelClaim`] (an RAII token that keeps the concrete entry's in-flight
//! count exact) rides inside the queued request → a worker batches it with
//! same-model requests only → the response is sent and the claim drops.
//! `unregister_model` flips the entry to *retired* (new submits get
//! [`ServeError::UnknownModel`]), waits for the in-flight count to reach
//! zero, removes the entry (workers drop their instances at the next
//! sync), and invalidates the retired structures in the entry's plan
//! cache — reporting exact eviction counters.

use super::backend::BatchModel;
use super::{ModelQuota, ServeError};
use crate::kernels::plan::PlanCache;
use crate::util::lock_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// The id [`super::InferenceServer::start_model`] registers its initial
/// model under, and the id requests without an explicit
/// [`super::SubmitOptions::model`] route to.
pub const DEFAULT_MODEL: &str = "default";

/// A model constructor, run once per worker thread (and once as a probe on
/// the registering thread): some backends own handles that are not `Send`,
/// and per-worker instances keep flushes lock-free.
pub(crate) type ModelFactory =
    Arc<dyn Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync>;

/// Batch geometry of a registered model, captured from its probe (or
/// first worker) instance; what submit validates widths against and the
/// batcher sizes flushes by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct ModelSpec {
    pub batch: usize,
    pub in_dim: usize,
    pub classes: usize,
}

/// What the registry knows about a model once an instance has existed.
pub(crate) struct ModelInfo {
    pub spec: ModelSpec,
    /// Structure-hash namespaces this model's plans occupy in `cache`
    /// (empty for backends that are not plan-cached).
    pub structures: Vec<u64>,
    /// The shared plan cache the model resolves plans from, if any — the
    /// handle `unregister` evicts retired namespaces through.
    pub cache: Option<Arc<PlanCache>>,
}

/// One registered model: id, factory, geometry, admission quota, and the
/// in-flight accounting that makes unregistration a *drain*, not a drop.
pub(crate) struct ModelEntry {
    pub id: String,
    pub factory: ModelFactory,
    info: OnceLock<ModelInfo>,
    /// Admission policy as configured. [`ModelQuota::FairShare`] is
    /// membership-dependent, so the registry re-resolves `quota` from this
    /// policy whenever a model registers or finishes retiring.
    quota_policy: ModelQuota,
    /// Currently-resolved per-model admission cap: the max entries this
    /// model may have *queued* at once (`usize::MAX` = unlimited, only the
    /// shared queue cap applies). Read per push via
    /// [`ModelClaim::quota_limit`]; already-queued entries are never
    /// re-checked when it shrinks — they drain normally.
    quota: AtomicUsize,
    /// Accepted-but-unanswered requests holding a [`ModelClaim`] on this
    /// entry.
    in_flight: AtomicUsize,
    /// Set by `begin_retire`: resolves are rejected, queued requests keep
    /// draining.
    retired: AtomicBool,
    /// Set while one worker runs this model's drift re-tune (the search
    /// invalidates the shared TuneCache entry and evicts the plan
    /// namespace — running it twice for one drift event would double both
    /// and double-count `ModelStats::retunes`). Pool peers that lose the
    /// race skip; they pick up the fresh plans via `retune_epoch`.
    retuning: AtomicBool,
    /// Bumped once per *completed* re-tune. A worker whose local epoch
    /// lags re-resolves plans from the shared cache (no invalidation, not
    /// counted as a re-tune) instead of re-running the search.
    retune_epoch: AtomicUsize,
    drain_lock: Mutex<()>,
    drained: Condvar,
}

impl ModelEntry {
    fn new(id: &str, factory: ModelFactory, quota_policy: ModelQuota) -> ModelEntry {
        ModelEntry {
            id: id.to_string(),
            factory,
            info: OnceLock::new(),
            quota_policy,
            // Placeholder until the registering `reresolve_quotas` pass
            // runs (detached test claims keep it: unlimited).
            quota: AtomicUsize::new(usize::MAX),
            in_flight: AtomicUsize::new(0),
            retired: AtomicBool::new(false),
            retuning: AtomicBool::new(false),
            retune_epoch: AtomicUsize::new(0),
            drain_lock: Mutex::new(()),
            drained: Condvar::new(),
        }
    }

    /// Record the probe/first-instance report; first write wins (workers
    /// all report the same geometry — disagreement aborts startup).
    pub fn set_info(&self, info: ModelInfo) {
        let _ = self.info.set(info);
    }

    pub fn info(&self) -> Option<&ModelInfo> {
        self.info.get()
    }

    /// Geometry, once the probe (or first worker) has reported it. `None`
    /// during the registration window — resolution maps that to the typed
    /// [`ServeError::ModelNotReady`] instead of panicking on a submit that
    /// races the probe.
    pub fn spec(&self) -> Option<ModelSpec> {
        self.info.get().map(|i| i.spec)
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// The currently-resolved admission cap (`None` = unlimited). Relaxed
    /// is enough: the value is a self-contained limit, not a handoff — a
    /// push racing a re-resolve is admitted under one of the two caps,
    /// exactly as if it had arrived a moment earlier or later.
    pub fn quota_limit(&self) -> Option<usize> {
        match self.quota.load(Ordering::Relaxed) {
            usize::MAX => None,
            n => Some(n),
        }
    }

    /// Install a freshly resolved cap (`None` = unlimited).
    fn set_quota_limit(&self, limit: Option<usize>) {
        self.quota.store(limit.unwrap_or(usize::MAX), Ordering::Relaxed);
    }

    /// Claim the exclusive right to run this model's drift re-tune; the
    /// loser of a same-tick race gets `false` and must not search.
    pub fn try_begin_retune(&self) -> bool {
        !self.retuning.swap(true, Ordering::AcqRel)
    }

    pub fn end_retune(&self) {
        self.retuning.store(false, Ordering::Release);
    }

    pub fn retune_epoch(&self) -> usize {
        self.retune_epoch.load(Ordering::Acquire)
    }

    /// Record a completed re-tune; peers observe the bump and refresh
    /// their detached plans from the shared cache.
    pub fn note_retuned(&self) {
        self.retune_epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Block until every claim on this entry has dropped — requests were
    /// answered (by a worker) or discarded (queue failed them). Claims
    /// drop on every exit path including worker panic unwind, so this
    /// cannot wedge on a dead pool.
    pub fn wait_drained(&self) {
        let mut g = lock_recover(&self.drain_lock);
        while self.in_flight.load(Ordering::Acquire) != 0 {
            g = self
                .drained
                .wait(g)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// RAII routing token: which model a request targets, plus the in-flight
/// count that lets `unregister_model` drain exactly. Created under the
/// registry lock (so it cannot race a retire) and dropped whenever the
/// request is answered or discarded — including a worker's panic unwind.
///
/// The claim snapshots the model's [`ModelSpec`] at creation, so readers
/// on the flush path never touch the entry's `OnceLock` — an entry whose
/// probe has not reported yet is rejected typed at resolve time and can
/// never be claimed.
///
/// Public (with private fields) because every
/// [`QueuedRequest`](super::queue::QueuedRequest) carries one; the
/// queue-level property suite constructs detached claims via
/// [`ModelClaim::detached`].
pub struct ModelClaim {
    entry: Arc<ModelEntry>,
    spec: ModelSpec,
}

impl ModelClaim {
    fn new(entry: Arc<ModelEntry>, spec: ModelSpec) -> ModelClaim {
        entry.in_flight.fetch_add(1, Ordering::AcqRel);
        ModelClaim { entry, spec }
    }

    /// Fixture for queue-level tests and benches: a claim with the given
    /// id and geometry backed by a private entry (no registry, no
    /// factory), still with exact RAII in-flight accounting.
    #[doc(hidden)]
    pub fn detached(id: &str, batch: usize, in_dim: usize, classes: usize) -> ModelClaim {
        let entry = Arc::new(ModelEntry::new(
            id,
            Arc::new(|| anyhow::bail!("detached claim has no factory")),
            ModelQuota::Unlimited,
        ));
        let spec = ModelSpec {
            batch,
            in_dim,
            classes,
        };
        entry.set_info(ModelInfo {
            spec,
            structures: Vec::new(),
            cache: None,
        });
        ModelClaim::new(entry, spec)
    }

    /// Another claim on the same concrete entry (same RAII accounting) —
    /// lets the queue property suite model several aliases resolving to
    /// one model without a registry.
    #[doc(hidden)]
    pub fn duplicate(&self) -> ModelClaim {
        ModelClaim::new(Arc::clone(&self.entry), self.spec)
    }

    /// The claimed entry's current in-flight count (includes this claim).
    #[doc(hidden)]
    pub fn in_flight(&self) -> usize {
        self.entry.in_flight()
    }

    pub fn id(&self) -> &str {
        &self.entry.id
    }

    pub(crate) fn spec(&self) -> ModelSpec {
        self.spec
    }

    /// The claimed model's *current* admission cap (max queued entries),
    /// threaded into `RequestQueue::push` at submit time. Reads the live
    /// value, not a registration-time snapshot: fair-share caps move when
    /// registry membership changes, and every push must observe the cap
    /// in force at that moment.
    pub(crate) fn quota_limit(&self) -> Option<usize> {
        self.entry.quota_limit()
    }
}

impl Drop for ModelClaim {
    fn drop(&mut self) {
        if self.entry.in_flight.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Take the drain lock before notifying so a waiter between its
            // count check and its wait cannot miss the wakeup.
            let _g = lock_recover(&self.entry.drain_lock);
            self.entry.drained.notify_all();
        }
    }
}

/// Outcome of `unregister_model`: what was drained and exactly which plan
/// namespaces were evicted vs. retained (shared with a surviving model).
#[derive(Clone, Debug, Default)]
pub struct UnregisterReport {
    pub model: String,
    /// Requests still in flight when unregistration began; all were
    /// answered before the model was dropped.
    pub drained_requests: usize,
    /// Structure hashes whose plans were evicted (no surviving model
    /// claims them).
    pub evicted_structures: Vec<u64>,
    /// Structure hashes kept because a surviving model still claims them
    /// (e.g. a dense classifier shape shared across checkpoints).
    pub retained_structures: Vec<u64>,
    /// Plans removed from the shared cache, summed over
    /// `evicted_structures`.
    pub evicted_plans: usize,
}

/// One alias's routing state: the concrete target, plus optional canary
/// and shadow legs. Lives under the registry lock — every mutation is
/// atomic with respect to resolution.
#[derive(Clone)]
pub(crate) struct AliasRoute {
    pub target: String,
    /// `(model, percent)`: requests whose deterministic key lands below
    /// `percent` (of 100) resolve to `model` instead of `target`.
    pub canary: Option<(String, u8)>,
    /// Requests are mirrored to this model on spare capacity; the mirror
    /// never answers the client, only records logit divergence.
    pub shadow: Option<String>,
}

/// Public snapshot of one alias's route (see
/// [`super::InferenceServer::aliases`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AliasInfo {
    pub alias: String,
    pub target: String,
    pub canary: Option<(String, u8)>,
    pub shadow: Option<String>,
}

/// What a submit's target resolved to: the concrete claim (alias already
/// unwrapped, canary leg already chosen) plus the routing context the
/// worker needs for per-alias metrics and shadow divergence recording.
pub(crate) struct Resolution {
    pub claim: ModelClaim,
    /// `(alias, canary)` when the submit named an alias: which alias, and
    /// whether the canary leg was chosen for this request.
    pub alias: Option<(String, bool)>,
    /// A claim on the alias's shadow target, when one is configured and
    /// currently resolvable (a retiring shadow target silently drops the
    /// mirror — shadow traffic must never fail the primary).
    pub shadow: Option<ModelClaim>,
}

/// Deterministic per-request routing key: FNV-1a over the alias name and
/// the request payload's bit pattern. Replaying the same request against
/// the same alias always lands on the same canary leg.
pub(crate) fn request_key(x: &[f32], alias: &str) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for b in alias.as_bytes() {
        h = (h ^ u64::from(*b)).wrapping_mul(PRIME);
    }
    for v in x {
        for b in v.to_bits().to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(PRIME);
        }
    }
    h
}

/// Entry map + alias table, guarded together: a resolve sees either the
/// route before a flip or the route after it, never a mixture.
struct RegistryState {
    entries: HashMap<String, Arc<ModelEntry>>,
    aliases: HashMap<String, AliasRoute>,
}

/// The registry proper: model id → entry, alias → route, plus a
/// generation counter the workers poll to keep their local instance sets
/// in sync. Alias names and model ids are disjoint namespaces.
pub(crate) struct ModelRegistry {
    state: Mutex<RegistryState>,
    /// Bumped on register and on retire *completion*; a worker whose local
    /// generation matches has an exact mirror of the entry map.
    generation: AtomicUsize,
    default_id: String,
    /// Shared queue capacity fair-share quotas resolve against.
    queue_cap: usize,
}

impl ModelRegistry {
    pub fn new(default_id: &str, queue_cap: usize) -> ModelRegistry {
        ModelRegistry {
            state: Mutex::new(RegistryState {
                entries: HashMap::new(),
                aliases: HashMap::new(),
            }),
            generation: AtomicUsize::new(0),
            default_id: default_id.to_string(),
            queue_cap: queue_cap.max(1),
        }
    }

    /// Re-resolve every live entry's admission cap from its policy. Runs
    /// under the state lock at each membership change (register, retire
    /// completion) — the same points that bump `generation`. Fixed
    /// policies (`Unlimited`, `Absolute`) are idempotent here; fair
    /// shares shrink as live models join and widen as they leave.
    fn reresolve_quotas(&self, st: &RegistryState) {
        let live = st
            .entries
            .values()
            .filter(|e| !e.retired.load(Ordering::Acquire))
            .count();
        for e in st.entries.values() {
            e.set_quota_limit(e.quota_policy.resolve(self.queue_cap, live));
        }
    }

    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::Acquire)
    }

    pub fn default_id(&self) -> &str {
        &self.default_id
    }

    /// Add a model. `info` is `None` only for the startup default model,
    /// whose first worker instance reports it before the server constructor
    /// returns; a submit that races that window is rejected with the typed
    /// [`ServeError::ModelNotReady`], never a panic. `quota` is the
    /// admission *policy*; the registry resolves it against the queue
    /// capacity and current membership, and keeps re-resolving fair shares
    /// as membership changes.
    pub fn register(
        &self,
        id: &str,
        factory: ModelFactory,
        info: Option<ModelInfo>,
        quota: ModelQuota,
    ) -> anyhow::Result<Arc<ModelEntry>> {
        anyhow::ensure!(!id.is_empty(), "model id must be non-empty");
        let entry = {
            let mut st = lock_recover(&self.state);
            anyhow::ensure!(
                !st.entries.contains_key(id),
                "model '{id}' is already registered"
            );
            anyhow::ensure!(
                !st.aliases.contains_key(id),
                "'{id}' is an alias; model ids and aliases are disjoint namespaces"
            );
            let entry = Arc::new(ModelEntry::new(id, factory, quota));
            if let Some(info) = info {
                entry.set_info(info);
            }
            st.entries.insert(id.to_string(), Arc::clone(&entry));
            // Membership grew: every fair-share cap (including the new
            // entry's own) shrinks to its new split, atomically with the
            // insert — no push can observe the new member under a stale
            // cap.
            self.reresolve_quotas(&st);
            entry
        };
        self.generation.fetch_add(1, Ordering::AcqRel);
        Ok(entry)
    }

    /// Claim a live concrete model inside an already-held state lock.
    fn claim_in(st: &RegistryState, id: &str) -> Result<ModelClaim, ServeError> {
        match st.entries.get(id) {
            Some(e) if !e.retired.load(Ordering::Acquire) => {
                let spec = e.spec().ok_or_else(|| ServeError::ModelNotReady {
                    model: id.to_string(),
                })?;
                Ok(ModelClaim::new(Arc::clone(e), spec))
            }
            _ => Err(ServeError::UnknownModel {
                model: id.to_string(),
            }),
        }
    }

    /// Resolve a submit's target (`None` → the default id) to a concrete
    /// claim, unwrapping aliases: the canary leg is chosen by `key`
    /// (deterministic per request), and a configured shadow target yields
    /// a second claim for the mirror. Resolution happens entirely under
    /// the registry lock, so a request either resolves before a retire or
    /// flip begins (and is drained under the old route) or sees the new
    /// route — never a half-flipped one.
    pub fn resolve_request(&self, id: Option<&str>, key: u64) -> Result<Resolution, ServeError> {
        let st = lock_recover(&self.state);
        let name = id.unwrap_or(self.default_id.as_str());
        let Some(route) = st.aliases.get(name) else {
            return Ok(Resolution {
                claim: Self::claim_in(&st, name)?,
                alias: None,
                shadow: None,
            });
        };
        let route = route.clone();
        let canary = route
            .canary
            .as_ref()
            .is_some_and(|(_, pct)| key % 100 < u64::from(*pct));
        let target = if canary {
            route.canary.as_ref().map(|(m, _)| m.as_str()).unwrap_or(&route.target)
        } else {
            route.target.as_str()
        };
        let claim = Self::claim_in(&st, target)?;
        // The mirror is best-effort by design: a shadow target that is
        // retiring or mid-probe drops this request's mirror, never the
        // primary.
        let shadow = route
            .shadow
            .as_deref()
            .and_then(|s| Self::claim_in(&st, s).ok());
        Ok(Resolution {
            claim,
            alias: Some((name.to_string(), canary)),
            shadow,
        })
    }

    /// Alias-aware single-claim resolution (primary leg only); the submit
    /// path uses [`ModelRegistry::resolve_request`].
    pub fn resolve(&self, id: Option<&str>) -> Result<ModelClaim, ServeError> {
        let st = lock_recover(&self.state);
        let name = id.unwrap_or(self.default_id.as_str());
        let target = match st.aliases.get(name) {
            Some(route) => route.target.clone(),
            None => name.to_string(),
        };
        Self::claim_in(&st, &target)
    }

    /// Validate `target` as an alias leg inside the lock: registered, not
    /// retiring, probe reported; when `like` is given (the alias's current
    /// primary spec), the leg must serve the same request geometry.
    fn check_target(
        st: &RegistryState,
        alias: &str,
        target: &str,
        like: Option<ModelSpec>,
    ) -> anyhow::Result<ModelSpec> {
        let entry = st.entries.get(target).ok_or_else(|| {
            anyhow::anyhow!("alias '{alias}': target model '{target}' is not registered")
        })?;
        anyhow::ensure!(
            !entry.retired.load(Ordering::Acquire),
            "alias '{alias}': target model '{target}' is being retired"
        );
        let spec = entry.spec().ok_or_else(|| {
            anyhow::anyhow!("alias '{alias}': target model '{target}' has not reported its geometry yet")
        })?;
        if let Some(like) = like {
            anyhow::ensure!(
                spec.in_dim == like.in_dim && spec.classes == like.classes,
                "alias '{alias}': '{target}' serves {}→{} but the current target serves {}→{}",
                spec.in_dim,
                spec.classes,
                like.in_dim,
                like.classes
            );
        }
        Ok(spec)
    }

    /// Create `alias` → `target`, or atomically re-point an existing
    /// alias. Re-pointing clears any canary/shadow staging: the flip ends
    /// the rollout experiment it belonged to.
    pub fn set_alias(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        anyhow::ensure!(!alias.is_empty(), "alias must be non-empty");
        let mut st = lock_recover(&self.state);
        anyhow::ensure!(
            !st.entries.contains_key(alias),
            "'{alias}' is a registered model id; model ids and aliases are disjoint namespaces"
        );
        Self::check_target(&st, alias, target, None)?;
        st.aliases.insert(
            alias.to_string(),
            AliasRoute {
                target: target.to_string(),
                canary: None,
                shadow: None,
            },
        );
        Ok(())
    }

    /// The atomic flip: re-point an *existing* alias at `target` and clear
    /// canary/shadow. The new target must serve the old target's request
    /// geometry — clients submitting through the alias never see a width
    /// change mid-rollout.
    pub fn promote(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        let mut st = lock_recover(&self.state);
        let like = st
            .aliases
            .get(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?
            .target
            .clone();
        let like_spec = st.entries.get(&like).and_then(|e| e.spec());
        Self::check_target(&st, alias, target, like_spec)?;
        let route = st
            .aliases
            .get_mut(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?;
        route.target = target.to_string();
        route.canary = None;
        route.shadow = None;
        Ok(())
    }

    pub fn remove_alias(&self, alias: &str) -> anyhow::Result<()> {
        let mut st = lock_recover(&self.state);
        anyhow::ensure!(
            st.aliases.remove(alias).is_some(),
            "'{alias}' is not an alias"
        );
        Ok(())
    }

    /// Route `percent`% (1–100) of the alias's traffic to `target`,
    /// selected by the deterministic per-request key.
    pub fn set_canary(&self, alias: &str, target: &str, percent: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=100).contains(&percent),
            "canary percent must be in 1..=100, got {percent}"
        );
        let mut st = lock_recover(&self.state);
        let primary = st
            .aliases
            .get(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?
            .target
            .clone();
        let like = st.entries.get(&primary).and_then(|e| e.spec());
        Self::check_target(&st, alias, target, like)?;
        st.aliases
            .get_mut(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?
            .canary = Some((target.to_string(), percent));
        Ok(())
    }

    pub fn clear_canary(&self, alias: &str) -> anyhow::Result<()> {
        let mut st = lock_recover(&self.state);
        let route = st
            .aliases
            .get_mut(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?;
        route.canary = None;
        Ok(())
    }

    /// Mirror the alias's requests to `target` on spare capacity; the
    /// mirror records logit divergence and never answers the client.
    pub fn set_shadow(&self, alias: &str, target: &str) -> anyhow::Result<()> {
        let mut st = lock_recover(&self.state);
        let primary = st
            .aliases
            .get(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?
            .target
            .clone();
        let like = st.entries.get(&primary).and_then(|e| e.spec());
        Self::check_target(&st, alias, target, like)?;
        st.aliases
            .get_mut(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?
            .shadow = Some(target.to_string());
        Ok(())
    }

    pub fn clear_shadow(&self, alias: &str) -> anyhow::Result<()> {
        let mut st = lock_recover(&self.state);
        let route = st
            .aliases
            .get_mut(alias)
            .ok_or_else(|| anyhow::anyhow!("'{alias}' is not an alias"))?;
        route.shadow = None;
        Ok(())
    }

    /// Every alias's current route, sorted by alias name.
    pub fn aliases(&self) -> Vec<AliasInfo> {
        let st = lock_recover(&self.state);
        let mut out: Vec<AliasInfo> = st
            .aliases
            .iter()
            .map(|(alias, r)| AliasInfo {
                alias: alias.clone(),
                target: r.target.clone(),
                canary: r.canary.clone(),
                shadow: r.shadow.clone(),
            })
            .collect();
        out.sort_by(|a, b| a.alias.cmp(&b.alias));
        out
    }

    /// The concrete model an alias currently targets, if `alias` is one.
    pub fn alias_target(&self, alias: &str) -> Option<String> {
        lock_recover(&self.state)
            .aliases
            .get(alias)
            .map(|r| r.target.clone())
    }

    /// Whether `id` currently has an entry (live or draining). Used to
    /// fail duplicate registrations *before* the expensive factory probe —
    /// a probe for a doomed registration would warm orphan plan namespaces
    /// into the shared cache that no entry (and so no unregister) owns.
    pub fn is_registered(&self, id: &str) -> bool {
        lock_recover(&self.state).entries.contains_key(id)
    }

    /// The entry for `id`, live or draining — the re-tune guard's lookup.
    pub fn entry(&self, id: &str) -> Option<Arc<ModelEntry>> {
        lock_recover(&self.state).entries.get(id).map(Arc::clone)
    }

    /// Every entry, including retired-but-draining ones (workers must keep
    /// serving those until the drain completes).
    pub fn snapshot(&self) -> Vec<Arc<ModelEntry>> {
        lock_recover(&self.state)
            .entries
            .values()
            .map(Arc::clone)
            .collect()
    }

    /// Live (non-retired) model ids, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut ids: Vec<String> = lock_recover(&self.state)
            .entries
            .values()
            .filter(|e| !e.retired.load(Ordering::Acquire))
            .map(|e| e.id.clone())
            .collect();
        ids.sort();
        ids
    }

    /// Phase 1 of unregistration: stop new submits resolving to `id`.
    /// Queued requests keep draining through the workers. An alias still
    /// pointing at `id` keeps resolving typed (`UnknownModel`), never a
    /// panic — `rollout` flips aliases away before retiring.
    pub fn begin_retire(&self, id: &str) -> anyhow::Result<Arc<ModelEntry>> {
        let st = lock_recover(&self.state);
        let entry = st
            .entries
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("model '{id}' is not registered"))?;
        anyhow::ensure!(
            !entry.retired.swap(true, Ordering::AcqRel),
            "model '{id}' is already being unregistered"
        );
        Ok(Arc::clone(entry))
    }

    /// Phase 2, after the drain: remove the entry (workers drop their
    /// instances at the next sync) and evict exactly the plan namespaces
    /// no surviving model still claims.
    pub fn finish_retire(&self, entry: &Arc<ModelEntry>) -> UnregisterReport {
        let live: Vec<u64> = {
            let mut st = lock_recover(&self.state);
            st.entries.remove(&entry.id);
            // Membership shrank: surviving fair-share caps widen to the
            // new split.
            self.reresolve_quotas(&st);
            st.entries
                .values()
                .filter_map(|e| e.info())
                .flat_map(|i| i.structures.iter().copied())
                .collect()
        };
        self.generation.fetch_add(1, Ordering::AcqRel);
        let mut report = UnregisterReport {
            model: entry.id.clone(),
            ..UnregisterReport::default()
        };
        if let Some(info) = entry.info() {
            for &s in &info.structures {
                if live.contains(&s) {
                    report.retained_structures.push(s);
                } else if let Some(cache) = &info.cache {
                    report.evicted_plans += cache.invalidate_structure(s);
                    report.evicted_structures.push(s);
                }
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noop_factory() -> ModelFactory {
        Arc::new(|| anyhow::bail!("never built in these tests"))
    }

    fn info(batch: usize, structures: Vec<u64>) -> ModelInfo {
        ModelInfo {
            spec: ModelSpec {
                batch,
                in_dim: 4,
                classes: 2,
            },
            structures,
            cache: None,
        }
    }

    #[test]
    fn register_resolve_and_duplicate_rejection() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        let gen0 = r.generation();
        r.register(DEFAULT_MODEL, noop_factory(), Some(info(8, vec![1])), ModelQuota::Unlimited)
            .unwrap();
        r.register("b", noop_factory(), Some(info(4, vec![2])), ModelQuota::Absolute(16))
            .unwrap();
        assert_eq!(r.generation(), gen0 + 2);
        assert!(r.register("b", noop_factory(), None, ModelQuota::Unlimited).is_err());
        assert_eq!(r.models(), vec!["b".to_string(), DEFAULT_MODEL.to_string()]);

        let claim = r.resolve(None).unwrap();
        assert_eq!(claim.id(), DEFAULT_MODEL);
        assert_eq!(claim.spec().batch, 8);
        assert_eq!(claim.quota_limit(), None, "default model: unlimited");
        let claim_b = r.resolve(Some("b")).unwrap();
        assert_eq!(claim_b.spec().batch, 4);
        assert_eq!(claim_b.quota_limit(), Some(16), "claims carry the resolved quota");
        match r.resolve(Some("nope")) {
            Err(ServeError::UnknownModel { model }) => assert_eq!(model, "nope"),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn resolving_before_the_probe_reports_is_typed_not_a_panic() {
        // Regression: a submit racing a registration whose probe had not
        // set `info` yet used to panic in `ModelEntry::spec()`; it must be
        // the typed ModelNotReady instead.
        let r = Arc::new(ModelRegistry::new(DEFAULT_MODEL, 64));
        let entry = r
            .register("late", noop_factory(), None, ModelQuota::Unlimited)
            .unwrap();
        match r.resolve(Some("late")) {
            Err(ServeError::ModelNotReady { model }) => assert_eq!(model, "late"),
            other => panic!("expected ModelNotReady, got {:?}", other.map(|_| ())),
        }
        // Hammer resolves from another thread across the set_info window:
        // every outcome is a claim or a typed error, never a panic.
        let racer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut saw_not_ready = false;
                let mut saw_ok = false;
                for _ in 0..10_000 {
                    match r.resolve(Some("late")) {
                        Ok(c) => {
                            assert_eq!(c.spec().batch, 2);
                            saw_ok = true;
                        }
                        Err(ServeError::ModelNotReady { .. }) => saw_not_ready = true,
                        Err(e) => panic!("unexpected error: {e:?}"),
                    }
                }
                (saw_not_ready, saw_ok)
            })
        };
        std::thread::yield_now();
        entry.set_info(info(2, vec![]));
        let (_, saw_ok) = racer.join().unwrap();
        assert!(saw_ok, "after set_info every resolve succeeds");
        assert!(r.resolve(Some("late")).is_ok());
    }

    #[test]
    fn claims_gate_the_drain_and_retire_blocks_resolves() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        let entry = r
            .register("m", noop_factory(), Some(info(2, vec![7, 9])), ModelQuota::Unlimited)
            .unwrap();
        let c1 = r.resolve(Some("m")).unwrap();
        let c2 = r.resolve(Some("m")).unwrap();
        assert_eq!(entry.in_flight(), 2);

        let retired = r.begin_retire("m").unwrap();
        assert!(r.resolve(Some("m")).is_err(), "retired: no new claims");
        assert!(r.begin_retire("m").is_err(), "double retire rejected");
        // Still visible to workers (snapshot) so the drain can be served,
        // but gone from the public model list.
        assert_eq!(r.snapshot().len(), 1);
        assert!(r.models().is_empty());

        // Drain completes from another thread while we wait.
        let h = std::thread::spawn(move || {
            drop(c1);
            drop(c2);
        });
        retired.wait_drained();
        h.join().unwrap();
        assert_eq!(retired.in_flight(), 0);

        let report = r.finish_retire(&retired);
        assert_eq!(report.model, "m");
        // No cache attached: nothing evictable, nothing retained.
        assert!(report.evicted_structures.is_empty());
        assert_eq!(report.evicted_plans, 0);
        assert!(r.snapshot().is_empty());
        // The id is free again.
        r.register("m", noop_factory(), Some(info(2, vec![7])), ModelQuota::Unlimited).unwrap();
    }

    #[test]
    fn finish_retire_spares_structures_shared_with_survivors() {
        use crate::kernels::plan::{PlanRequest, SparseMatrix};
        use crate::kernels::registry::KernelRegistry;

        let cache = Arc::new(PlanCache::new());
        let kernels = KernelRegistry::builtin();
        let shared = SparseMatrix::dense(vec![0.0; 8], 2, 4);
        let own = SparseMatrix::dense(vec![0.0; 12], 3, 4);
        let req = PlanRequest::new(4, 1);
        cache.plan_for(&kernels, &shared, &req).unwrap();
        cache.plan_for(&kernels, &own, &req).unwrap();

        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        let mk_info = |structures: Vec<u64>| ModelInfo {
            spec: ModelSpec {
                batch: 2,
                in_dim: 4,
                classes: 2,
            },
            structures,
            cache: Some(Arc::clone(&cache)),
        };
        r.register(
            "keep",
            noop_factory(),
            Some(mk_info(vec![shared.structure_hash()])),
            ModelQuota::Unlimited,
        )
        .unwrap();
        let retired = r
            .register(
                "kill",
                noop_factory(),
                Some(mk_info(vec![shared.structure_hash(), own.structure_hash()])),
                ModelQuota::Unlimited,
            )
            .unwrap();

        let entry = r.begin_retire("kill").unwrap();
        entry.wait_drained(); // nothing in flight
        let report = r.finish_retire(&retired);
        assert_eq!(report.evicted_structures, vec![own.structure_hash()]);
        assert_eq!(report.retained_structures, vec![shared.structure_hash()]);
        assert_eq!(report.evicted_plans, 1);
        assert_eq!(cache.structure_plan_count(own.structure_hash()), 0);
        assert_eq!(cache.structure_plan_count(shared.structure_hash()), 1);
    }

    #[test]
    fn alias_flip_is_atomic_and_namespaces_are_disjoint() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("v1", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        r.register("v2", noop_factory(), Some(info(4, vec![])), ModelQuota::Unlimited).unwrap();
        assert!(r.set_alias("prod", "ghost").is_err(), "unregistered target");
        r.set_alias("prod", "v1").unwrap();
        assert_eq!(r.alias_target("prod").as_deref(), Some("v1"));
        // Disjoint namespaces, both directions.
        assert!(r.set_alias("v2", "v1").is_err(), "alias may not shadow a model id");
        assert!(
            r.register("prod", noop_factory(), Some(info(2, vec![])), ModelQuota::Unlimited).is_err(),
            "model id may not shadow an alias"
        );
        // Alias resolution pins the concrete model.
        let res = r.resolve_request(Some("prod"), 42).unwrap();
        assert_eq!(res.claim.id(), "v1");
        assert_eq!(res.alias, Some(("prod".to_string(), false)));
        assert!(res.shadow.is_none());
        // Flip; canary/shadow staging (none here) is reset, resolves move.
        r.promote("prod", "v2").unwrap();
        assert_eq!(r.resolve_request(Some("prod"), 42).unwrap().claim.id(), "v2");
        assert_eq!(r.resolve(Some("prod")).unwrap().id(), "v2");
        r.remove_alias("prod").unwrap();
        assert!(r.resolve(Some("prod")).is_err());
        assert!(r.remove_alias("prod").is_err());
    }

    #[test]
    fn canary_split_is_deterministic_in_the_request_key() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("v1", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        r.register("v2", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        r.set_alias("prod", "v1").unwrap();
        assert!(r.set_canary("prod", "v2", 0).is_err(), "percent 0 rejected");
        assert!(r.set_canary("prod", "v2", 101).is_err());
        r.set_canary("prod", "v2", 30).unwrap();
        for key in 0..200u64 {
            let res = r.resolve_request(Some("prod"), key).unwrap();
            let want_canary = key % 100 < 30;
            assert_eq!(res.claim.id(), if want_canary { "v2" } else { "v1" });
            assert_eq!(res.alias, Some(("prod".to_string(), want_canary)));
            // Replay: the same key always lands on the same leg.
            let replay = r.resolve_request(Some("prod"), key).unwrap();
            assert_eq!(replay.claim.id(), res.claim.id());
        }
        // The request key itself is a pure function of payload + alias.
        let x = [0.25f32, -1.5, 3.0];
        assert_eq!(request_key(&x, "prod"), request_key(&x, "prod"));
        assert_ne!(request_key(&x, "prod"), request_key(&x, "staging"));
        r.clear_canary("prod").unwrap();
        assert_eq!(r.resolve_request(Some("prod"), 3).unwrap().claim.id(), "v1");
    }

    #[test]
    fn shadow_claims_ride_along_and_never_fail_the_primary() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("v1", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        r.register("v2", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        r.set_alias("prod", "v1").unwrap();
        r.set_shadow("prod", "v2").unwrap();
        let res = r.resolve_request(Some("prod"), 7).unwrap();
        assert_eq!(res.claim.id(), "v1");
        assert_eq!(res.shadow.as_ref().map(|c| c.id()), Some("v2"));
        drop(res);
        // Retiring the shadow target drops the mirror, not the primary.
        r.begin_retire("v2").unwrap();
        let res = r.resolve_request(Some("prod"), 7).unwrap();
        assert_eq!(res.claim.id(), "v1");
        assert!(res.shadow.is_none(), "retiring shadow target is skipped");
        // A promote to the still-live geometry-matched canary-style target
        // would now fail (v2 is retiring) — the flip validates its target.
        assert!(r.promote("prod", "v2").is_err());
    }

    #[test]
    fn alias_legs_must_match_the_primary_geometry() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("v1", noop_factory(), Some(info(8, vec![])), ModelQuota::Unlimited).unwrap();
        let wide = ModelInfo {
            spec: ModelSpec {
                batch: 8,
                in_dim: 9,
                classes: 2,
            },
            structures: vec![],
            cache: None,
        };
        r.register("wide", noop_factory(), Some(wide), ModelQuota::Unlimited).unwrap();
        r.set_alias("prod", "v1").unwrap();
        assert!(r.set_canary("prod", "wide", 10).is_err(), "in_dim mismatch");
        assert!(r.set_shadow("prod", "wide").is_err());
        assert!(r.promote("prod", "wide").is_err());
        assert_eq!(r.alias_target("prod").as_deref(), Some("v1"));
    }

    #[test]
    fn retune_guard_admits_exactly_one_worker_per_drift_event() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        let entry = r.register("m", noop_factory(), Some(info(2, vec![])), ModelQuota::Unlimited).unwrap();
        assert_eq!(entry.retune_epoch(), 0);
        assert!(entry.try_begin_retune(), "first claimant wins");
        assert!(!entry.try_begin_retune(), "second claimant must skip");
        entry.note_retuned();
        entry.end_retune();
        assert_eq!(entry.retune_epoch(), 1, "completed re-tune bumps the epoch");
        assert!(entry.try_begin_retune(), "guard is reusable after release");
        entry.end_retune();
    }

    #[test]
    fn duplicate_claims_share_one_entry_accounting() {
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("m", noop_factory(), Some(info(2, vec![])), ModelQuota::Unlimited).unwrap();
        let c1 = r.resolve(Some("m")).unwrap();
        let c2 = c1.duplicate();
        assert_eq!(c1.in_flight(), 2, "duplicate charges the same concrete entry");
        drop(c2);
        assert_eq!(c1.in_flight(), 1);
    }

    #[test]
    fn fairshare_cap_reresolves_on_membership_change() {
        // Regression: fair-share quotas used to be resolved to an absolute
        // number once at registration, so later registrations (and
        // retirements) left every other model's cap stale. The cap must
        // track *current* membership.
        let r = ModelRegistry::new(DEFAULT_MODEL, 64);
        r.register("hot", noop_factory(), Some(info(2, vec![])), ModelQuota::FairShare(0.5))
            .unwrap();
        let hot = r.resolve(Some("hot")).unwrap();
        assert_eq!(hot.quota_limit(), Some(32), "sole model: 0.5 × 64");

        r.register("b", noop_factory(), Some(info(2, vec![])), ModelQuota::Unlimited)
            .unwrap();
        assert_eq!(
            hot.quota_limit(),
            Some(16),
            "an existing claim observes the shrunk cap after a second model registers"
        );

        r.register("c", noop_factory(), Some(info(2, vec![])), ModelQuota::Absolute(5))
            .unwrap();
        assert_eq!(hot.quota_limit(), Some(10), "third model shrinks it again");
        // Fixed policies never move with membership.
        assert_eq!(r.resolve(Some("b")).unwrap().quota_limit(), None);
        assert_eq!(r.resolve(Some("c")).unwrap().quota_limit(), Some(5));

        // Retiring a member widens the survivors' shares again — the
        // re-resolve runs at retire *completion*, when the slot frees.
        let retiring = r.begin_retire("c").unwrap();
        retiring.wait_drained();
        r.finish_retire(&retiring);
        assert_eq!(hot.quota_limit(), Some(16), "membership shrank back to two");
    }
}
